"""Serving example: batched requests against a reduced gemma3-1b
(sliding-window + global attention caches, ring-buffered local layers).

    PYTHONPATH=src python examples/serve_e2e.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.serving import ServeConfig, ServeEngine  # noqa: E402


def main() -> None:
    cfg = reduced(get_config("gemma3-1b"))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name} (reduced): {model.n_params()/1e6:.1f}M params, "
          f"window={cfg.sliding_window} global_every={cfg.global_every}")

    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(1, cfg.vocab, size=int(n)))
               for n in rng.integers(8, 24, size=6)]
    eng = ServeEngine(model, params, ServeConfig(max_batch=3, temperature=0.7,
                                                 seed=7))
    t0 = time.perf_counter()
    outs = eng.generate(prompts, max_new_tokens=24)
    dt = time.perf_counter() - t0
    new_tokens = sum(len(o) - len(p) for o, p in zip(outs, prompts))
    print(f"[serve] {len(prompts)} requests -> {new_tokens} tokens "
          f"in {dt:.2f}s ({new_tokens/dt:.1f} tok/s)")
    for i, o in enumerate(outs[:3]):
        print(f"  req{i}: ...{o[-12:]}")
    print(f"[serve] stats: {eng.stats}")


if __name__ == "__main__":
    main()
