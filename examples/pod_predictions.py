"""Pod-scale step-time predictions from the dry-run artifacts.

Reads `results/dryrun/*.json` (run `python -m repro.launch.dryrun --all`
first), rebuilds the three roofline terms, refines the collective term with
Eidola's topology-aware ring algebra, and prints the predicted step-time
envelope (no-overlap vs. perfectly-overlapped) per architecture — the
framework's answer to "what will a step cost on the real pod?".

    PYTHONPATH=src python examples/pod_predictions.py [--shape train_4k]
"""

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

from repro.core.hlo_capture import CollectiveOp  # noqa: E402
from repro.core.predictor import predict_step, roofline  # noqa: E402
from repro.core.topology import Topology  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--dir", default="results/dryrun")
    args = ap.parse_args()

    topo = Topology((16, 16), ("data", "model"))
    print(f"predicted step envelope, shape={args.shape}, {topo.describe()}")
    print(f"{'arch':18s} {'bound_s':>9s} {'no-ovl_s':>9s} {'full-ovl_s':>10s} "
          f"{'exposed_s':>9s} {'dominant':>10s}")
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if os.path.basename(path).count("__") != 2:
            continue  # skip tagged perf variants
        r = json.load(open(path))
        if r.get("shape") != args.shape or r.get("mesh") != "single":
            continue
        if r.get("status") != "ok":
            continue
        terms = roofline(
            arch=r["arch"], shape=r["shape"], mesh=r["mesh"], topo=topo,
            hlo_flops_per_device=r["flops_per_device"],
            hlo_bytes_per_device=r["bytes_per_device"],
            collective_bytes_per_device=int(r["collective_bytes_per_device"]),
            model_flops_total=r["model_flops"],
        )
        # reconstruct a coarse collective schedule from the per-kind record
        ops = []
        for kind, cb in r.get("collectives", {}).items():
            n = max(int(cb["count"]), 1)
            per = int(cb["bytes"]) // n
            ops += [CollectiveOp(kind, per, per, 16)] * min(n, 64)
        pred = predict_step(terms, topo, ops)
        rows.append((r["arch"], terms.bound_s, pred.no_overlap_s,
                     pred.full_overlap_s, pred.exposed_comm_s, terms.dominant))
    for arch, bound, no, full, exp, dom in sorted(rows, key=lambda x: -x[1]):
        print(f"{arch:18s} {bound:9.3f} {no:9.3f} {full:10.3f} {exp:9.3f} "
              f"{dom:>10s}")
    if not rows:
        print("no records found — run the dry-run first")


if __name__ == "__main__":
    main()
