"""End-to-end training driver: a ~125M-parameter model for a few hundred
steps with checkpointing and an injected failure + restart.

    PYTHONPATH=src python examples/train_e2e.py [--steps 200]

(xlstm-125m at full width but 4 layers trains at a usable pace on CPU; pass
--full for the whole 12-layer stack if you have the patience.)
"""

import argparse
import sys
import tempfile
import time

sys.path.insert(0, "src")

import jax  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.data import DataConfig, SyntheticLMDataset, prefetch  # noqa: E402
from repro.ft import SimulatedFailure  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.optim import AdamWConfig  # noqa: E402
from repro.training import TrainConfig, Trainer  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m").with_(vocab=2048, max_seq_len=args.seq)
    if not args.full:
        cfg = cfg.with_(n_layers=4, xlstm_pattern="mmms")
    model = Model(cfg)
    print(f"[e2e] {cfg.name}: {model.n_params()/1e6:.1f}M params")

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = SyntheticLMDataset(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    fail_at = {args.steps // 2}

    def inject(step):
        if step in fail_at:
            fail_at.discard(step)
            raise SimulatedFailure(f"chaos-drill failure at step {step}")

    with tempfile.TemporaryDirectory() as ckpt_dir:
        trainer = Trainer(
            model,
            mesh,
            TrainConfig(
                optim=AdamWConfig(
                    lr=3e-3, warmup_steps=20, total_steps=args.steps
                )
            ),
            ckpt_dir=ckpt_dir,
            ckpt_every=25,
            failure_injector=inject,
        )
        trainer.init_state(jax.random.PRNGKey(0))
        t0 = time.perf_counter()
        hist = trainer.run(prefetch(iter(data)), args.steps, log_every=25)
        dt = time.perf_counter() - t0
    tokens = args.steps * args.batch * args.seq
    print(
        f"[e2e] {len(hist)} steps ({tokens / dt:,.0f} tok/s) "
        f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}; "
        f"survived 1 injected failure"
    )
    assert hist[-1]["loss"] < hist[0]["loss"]


if __name__ == "__main__":
    main()
