"""Quickstart: the Scenario API on the paper's fused GEMV+AllReduce experiment.

Runs the Table-1 configuration under both synchronization policies via the
unified ``simulate()`` entry point, prints the traffic comparison (Figs. 6/9
in one shot), renders the workgroup timeline (Figs. 1/2), and then shows the
same machinery driving a different registered traffic pattern.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

from repro.core import (  # noqa: E402
    EngineKind,
    GaussianPerturb,
    PeerDelayPerturb,
    SimConfig,
    SyncPolicy,
    list_scenarios,
    simulate,
)
from repro.core.timeline import ascii_timeline, to_chrome_trace  # noqa: E402


def main() -> None:
    delay_us = 20.0
    print("=" * 70)
    print(f"fused GEMV+AllReduce, Table-1 config, peer flag delay {delay_us} us")
    print("=" * 70)

    for sync in (SyncPolicy.SPIN, SyncPolicy.SYNCMON):
        cfg = SimConfig(sync=sync, engine=EngineKind.EVENT)
        r = simulate(
            "gemv_allreduce", cfg,
            flag_delays_ns=delay_us * 1000.0,
            perturb=GaussianPerturb(seed=1, write_sigma_ns=10.0),
        )
        print(f"\n--- {sync.value} ---")
        print(f"flag reads     : {r.flag_reads:>10,}")
        print(f"non-flag reads : {r.nonflag_reads:>10,}")
        print(f"kernel span    : {r.kernel_span_ns:>10,.0f} ns")
        if r.monitor_stats:
            print(f"monitor stats  : {r.monitor_stats}")

    print("\nideal vs contended timelines (paper Figs. 1/2):")
    cfg = SimConfig(sync=SyncPolicy.SPIN, engine=EngineKind.EVENT)
    ideal = simulate("gemv_allreduce", cfg, flag_delays_ns=0.0)
    slow = simulate(
        "gemv_allreduce", cfg, flag_delays_ns=0.0,
        perturb=PeerDelayPerturb({2: 25_000.0, 3: 25_000.0}),
    )
    print("\nideal (g/G compute, B flag write, r spin-wait, b reduce):")
    print(ascii_timeline(ideal.segments, max_rows=6))
    print("\nGPUs 2,3 delayed by transient congestion:")
    print(ascii_timeline(slow.segments, max_rows=6))

    with open("/tmp/eidola_trace.json", "w") as f:
        f.write(to_chrome_trace(slow.segments))
    print("\nperfetto trace written to /tmp/eidola_trace.json "
          "(open at ui.perfetto.dev)")

    # ------------------------------------------------------------------
    # the same device model, WTT, and sync policies drive every registered
    # traffic pattern — no per-scenario simulator code
    # ------------------------------------------------------------------
    print("\n" + "=" * 70)
    print(f"registered scenarios: {', '.join(list_scenarios())}")
    print("=" * 70)
    for name in ("ring_allreduce", "all_to_all", "pipeline_p2p"):
        for sync in (SyncPolicy.SPIN, SyncPolicy.SYNCMON):
            cfg = SimConfig(sync=sync, engine=EngineKind.EVENT)
            r = simulate(name, cfg, collect_segments=False)
            print(f"{name:15s} {sync.value:8s} flag_reads={r.flag_reads:>8,} "
                  f"nonflag={r.nonflag_reads:>8,} "
                  f"span={r.kernel_span_ns:>10,.0f} ns")


if __name__ == "__main__":
    main()
