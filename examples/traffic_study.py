"""Pod-scale traffic study: capture a real model's collective schedule from
its compiled HLO and replay it in Eidola at cycle fidelity.

This is the paper's Fig. 4 workflow end-to-end inside one process:
 (1) measurement: compile a sharded train step and capture its collective
     schedule (the framework's "profile");
 (2) instrumentation: lower the schedule to timestamped eidolon writes;
 (3) analysis: replay under spin vs. SyncMon synchronization and under
     perturbed (straggler) peers, and compare exposure.

    PYTHONPATH=src python examples/traffic_study.py
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
sys.path.insert(0, "src")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.core import (  # noqa: E402
    EngineKind,
    PeerDelayPerturb,
    SimConfig,
    SyncPolicy,
    Eidola,
)
from repro.core.hlo_capture import parse_collectives, schedule_to_trace, summarize  # noqa: E402
from repro.core.predictor import predict_step, roofline  # noqa: E402
from repro.core.topology import Topology  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.training import TrainConfig, build_train_step  # noqa: E402
from repro.optim import AdamWConfig, adamw_init  # noqa: E402


def main() -> None:
    # (1) capture: compile a sharded train step for a reduced gemma3-1b
    cfg = reduced(get_config("gemma3-1b")).with_(n_layers=4)
    mesh = jax.make_mesh((4, 4), ("data", "model"))
    model = Model(cfg, mesh=mesh)
    step_fn, shardings, _ = build_train_step(
        model, mesh, TrainConfig(optim=AdamWConfig())
    )
    tok = jax.ShapeDtypeStruct((8, 64), jnp.int32)
    state = jax.eval_shape(lambda p: adamw_init(p, AdamWConfig()),
                           model.abstract_params())
    with mesh:
        compiled = step_fn.lower(
            model.abstract_params(), state, tok, tok
        ).compile()
    ops = parse_collectives(compiled.as_text())
    print("captured collective schedule:")
    print(summarize(ops))

    # (2) lower to eidolon traces on the production topology
    topo = Topology((4, 4), ("data", "model"))
    trace = schedule_to_trace(ops, topo, compute_gap_ns=2000.0)
    print(f"\ntrace: {len(trace)} registered writes, "
          f"span {trace.span_ns():,.0f} ns")

    # (3) replay: spin vs syncmon; healthy vs one straggling peer
    for sync in (SyncPolicy.SPIN, SyncPolicy.SYNCMON):
        for label, perturb in (
            ("healthy", None),
            ("straggler +50us", PeerDelayPerturb({1: 50_000.0})),
        ):
            sim_cfg = SimConfig(sync=sync, engine=EngineKind.EVENT)
            r = Eidola(sim_cfg, trace, perturb=perturb).run()
            print(
                f"[{sync.value:8s} | {label:16s}] flag_reads={r.flag_reads:>8,} "
                f"kernel={r.kernel_span_ns:>12,.0f} ns"
            )

    print("\n(SyncMon keeps sync traffic bounded even with the straggler; "
          "spin-wait polling scales with the induced wait.)")


if __name__ == "__main__":
    main()
