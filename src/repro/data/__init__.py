"""Deterministic synthetic data pipeline with host sharding and prefetch."""

from .pipeline import DataConfig, SyntheticLMDataset, prefetch

__all__ = ["DataConfig", "SyntheticLMDataset", "prefetch"]
