"""Synthetic-but-learnable token pipeline.

Produces deterministic batches keyed by (step, host) — every host of a
multi-host job computes only its slice (``host_batch = global_batch /
n_hosts``), which is how a real cluster feeds a pjit'd train step.  Sequences
are drawn from a tiny induced Markov chain so models can actually reduce loss
(pure uniform noise has nothing to learn); document boundaries are packed with
separator tokens like a production LM pipeline.

``prefetch`` wraps any iterator with a background thread + bounded queue to
overlap host-side batch synthesis with device compute.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DataConfig", "SyntheticLMDataset", "prefetch"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int = 1024
    seq_len: int = 128
    global_batch: int = 8
    n_hosts: int = 1
    seed: int = 0
    markov_order: int = 1
    separator_token: int = 0
    mean_doc_len: int = 64


class SyntheticLMDataset:
    """Deterministic Markov-chain LM data, shardable by host."""

    def __init__(self, cfg: DataConfig):
        if cfg.global_batch % cfg.n_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sparse-ish transition structure => learnable bigram statistics
        logits = rng.normal(0.0, 2.0, size=(cfg.vocab, cfg.vocab))
        keep = rng.random((cfg.vocab, cfg.vocab)) < (16.0 / cfg.vocab)
        logits = np.where(keep, logits, -1e9)
        logits[:, 1 % cfg.vocab] = 0.0  # guarantee an escape transition
        p = np.exp(logits - logits.max(axis=1, keepdims=True))
        self._P = p / p.sum(axis=1, keepdims=True)
        self._cumP = np.cumsum(self._P, axis=1)

    @property
    def host_batch(self) -> int:
        return self.cfg.global_batch // self.cfg.n_hosts

    def batch(self, step: int, host: int = 0) -> Dict[str, np.ndarray]:
        """tokens/labels [host_batch, seq_len] for (step, host)."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 4099 + host
        )
        B, S = self.host_batch, cfg.seq_len
        toks = np.empty((B, S + 1), np.int64)
        state = rng.integers(0, cfg.vocab, size=B)
        doc_left = rng.geometric(1.0 / cfg.mean_doc_len, size=B)
        for t in range(S + 1):
            u = rng.random(B)
            state = (self._cumP[state] > u[:, None]).argmax(axis=1)
            end = doc_left <= 0
            if end.any():
                state = np.where(end, cfg.separator_token, state)
                doc_left = np.where(
                    end, rng.geometric(1.0 / cfg.mean_doc_len, size=B), doc_left
                )
            toks[:, t] = state
            doc_left -= 1
        return {
            "tokens": toks[:, :S].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Background-thread prefetch with a bounded queue."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
