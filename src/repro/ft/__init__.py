"""Fault tolerance: failure detection, straggler mitigation, elastic remesh."""

from .resilience import (
    ElasticMeshManager,
    HeartbeatMonitor,
    SimulatedFailure,
    StragglerMonitor,
    remesh_pytree,
)

__all__ = [
    "ElasticMeshManager",
    "HeartbeatMonitor",
    "SimulatedFailure",
    "StragglerMonitor",
    "remesh_pytree",
]
