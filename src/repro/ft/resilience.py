"""Fault-tolerance substrate for 1000+-node deployments.

Three mechanisms, each exercised by tests with *simulated* failures (this
container has one real device, so hardware behaviours are injected — the same
way the paper drives its simulator with recorded/perturbed event streams):

* :class:`HeartbeatMonitor` — per-host liveness with configurable timeout;
  a missed heartbeat marks the host dead and triggers checkpoint/restart.
* :class:`StragglerMonitor` — per-host step-time statistics; hosts slower
  than ``threshold x`` the rolling median are flagged, mirroring the paper's
  Fig. 2 variability characterization at cluster scale.  The mitigation hook
  returns the suggested action (drop to elastic remesh / rebalance data).
* :class:`ElasticMeshManager` + :func:`remesh_pytree` — shrink/grow the
  device mesh and re-place all state onto the new mesh (elastic scaling).
  Re-placement preserves values exactly (tested), so training resumes
  deterministically after losing a slice of the fleet.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "SimulatedFailure",
    "HeartbeatMonitor",
    "StragglerMonitor",
    "ElasticMeshManager",
    "remesh_pytree",
]


class SimulatedFailure(RuntimeError):
    """Injected node/step failure (tests and chaos drills)."""


# ---------------------------------------------------------------------------
# liveness
# ---------------------------------------------------------------------------


class HeartbeatMonitor:
    def __init__(self, hosts: Sequence[int], timeout_s: float = 30.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: Dict[int, float] = {h: now for h in hosts}
        self._dead: set = set()

    def beat(self, host: int, at: Optional[float] = None) -> None:
        if host in self._dead:
            return
        self._last[host] = self._clock() if at is None else at

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        t = self._clock() if now is None else now
        for h, last in self._last.items():
            if h not in self._dead and t - last > self.timeout_s:
                self._dead.add(h)
        return sorted(self._dead)

    def alive_hosts(self) -> List[int]:
        self.dead_hosts()
        return sorted(set(self._last) - self._dead)


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------


@dataclass
class StragglerReport:
    step: int
    stragglers: List[int]
    median_s: float
    worst_ratio: float


class StragglerMonitor:
    """Rolling per-host step-time stats with threshold flagging."""

    def __init__(self, threshold: float = 1.5, window: int = 16):
        self.threshold = threshold
        self.window = window
        self._hist: Dict[int, List[float]] = {}
        self._step = 0

    def record_step(self, host_times_s: Dict[int, float]) -> StragglerReport:
        self._step += 1
        for h, t in host_times_s.items():
            self._hist.setdefault(h, []).append(t)
            self._hist[h] = self._hist[h][-self.window :]
        med_per_host = {h: float(np.median(v)) for h, v in self._hist.items()}
        fleet_median = float(np.median(list(med_per_host.values())))
        stragglers = [
            h
            for h, m in med_per_host.items()
            if m > self.threshold * fleet_median
        ]
        worst = max(med_per_host.values()) / max(fleet_median, 1e-9)
        return StragglerReport(self._step, sorted(stragglers), fleet_median, worst)


# ---------------------------------------------------------------------------
# elastic scaling
# ---------------------------------------------------------------------------


def remesh_pytree(tree, shardings_fn: Callable[[Mesh], Any], new_mesh: Mesh):
    """Re-place every leaf of ``tree`` onto ``new_mesh``.

    ``shardings_fn(mesh)`` returns the sharding tree for a given mesh (so the
    same rules resolve against the new topology, including divisibility
    fallback).  Values are preserved exactly.
    """
    new_shard = shardings_fn(new_mesh)
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return jax.tree.map(
        lambda arr, s: jax.device_put(arr, s), host, new_shard
    )


class ElasticMeshManager:
    """Tracks the usable device set and rebuilds meshes after failures.

    The mesh shrinks along the data axis (model-parallel groups are atomic:
    losing one device removes its whole model-parallel replica), the standard
    elastic policy for 2D DP x TP meshes.
    """

    def __init__(self, devices, axis_names=("data", "model"), model_parallel: int = 1):
        self.all_devices = list(devices)
        self.axis_names = axis_names
        self.model_parallel = model_parallel
        self.failed: set = set()

    def fail_devices(self, idxs: Sequence[int]) -> None:
        self.failed.update(idxs)

    def current_mesh(self) -> Mesh:
        alive = [
            d for i, d in enumerate(self.all_devices) if i not in self.failed
        ]
        mp = self.model_parallel
        groups = len(alive) // mp
        if groups < 1:
            raise SimulatedFailure("not enough devices for one model replica")
        usable = alive[: groups * mp]
        arr = np.array(usable).reshape(groups, mp)
        return Mesh(arr, self.axis_names)

    def dp_size(self) -> int:
        return self.current_mesh().shape[self.axis_names[0]]
