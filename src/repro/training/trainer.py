"""Sharded training: pjit train step + fault-tolerant run loop.

``build_train_step`` assembles the full production step:
  - params sharded by logical-axis rules (divisibility fallback),
  - optimizer state ZeRO-1-sharded across the data(+pod) axes,
  - microbatched gradient accumulation (jax.lax.scan over microbatches),
  - remat policy by name,
  - loss in f32, params bf16, fp32 master weights.

``Trainer`` adds the large-scale-runnability story: checkpoint/restart on
(simulated) failures, straggler monitoring, and elastic remesh.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.distributed import (
    DEFAULT_RULES,
    ShardingRules,
    param_shardings,
    zero1_shardings,
)
from repro.distributed.compat import require_sharding_invariant_rng
from repro.distributed.zero import zero1_from_params
from repro.ft import SimulatedFailure, StragglerMonitor
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_step

__all__ = ["TrainConfig", "Trainer", "build_train_step"]


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    remat_policy: str = "none"       # none | full | dots | dots_no_batch
    moe_loss_weight: float = 0.01
    z_loss_weight: float = 1e-4
    optim: AdamWConfig = AdamWConfig()
    zero1_axes: Tuple[str, ...] = ("data",)
    zero1_model_dim: bool = False   # EXPERIMENTS.md §Perf H4 (superseded)
    zero1_param_aligned: bool = True  # §Perf H5: states follow param layout
    donate_state: bool = True


def _batch_sharding(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0] if axes else None))


def build_train_step(
    model: Model,
    mesh: Mesh,
    tcfg: TrainConfig,
    rules: ShardingRules = DEFAULT_RULES,
):
    """Returns (train_step_jitted, shardings dict, fallback log)."""
    # the trainer's contract is mesh-shape-invariant determinism (same seed,
    # same values on (1,1) and (2,4) meshes) — jax 0.4's legacy threefry
    # breaks that for sharded init, so force the partitionable RNG here
    require_sharding_invariant_rng()
    specs = model.param_specs()
    axes_tree = model.param_axes()
    abstract_params = model.abstract_params()
    p_shard, fallbacks = param_shardings(axes_tree, abstract_params, mesh, rules)

    # optimizer state shardings: step replicated; moments/master ZeRO-1
    abstract_state = jax.eval_shape(
        lambda p: adamw_init(p, tcfg.optim), abstract_params
    )
    zero_axes = tuple(a for a in (*tcfg.zero1_axes, "pod") if a in mesh.shape)

    def state_shardings():
        def shard_like(tree):
            if tcfg.zero1_param_aligned:
                return zero1_from_params(p_shard, tree, mesh, zero_axes)
            return zero1_shardings(
                tree, mesh, zero_axes, model_dim=tcfg.zero1_model_dim
            )

        s = {
            "step": NamedSharding(mesh, P()),
            "mu": shard_like(abstract_state["mu"]),
            "nu": shard_like(abstract_state["nu"]),
        }
        if "master" in abstract_state:
            s["master"] = shard_like(abstract_state["master"])
        return s

    s_shard = state_shardings()
    b_shard = _batch_sharding(mesh)

    def loss_for(params, tokens, labels, embeds):
        return model.loss_fn(
            params,
            tokens,
            labels,
            embeds=embeds,
            remat=tcfg.remat_policy != "none",
            remat_policy=tcfg.remat_policy
            if tcfg.remat_policy != "none" else "full",
            moe_loss_weight=tcfg.moe_loss_weight,
            z_loss_weight=tcfg.z_loss_weight,
        )

    def train_step(params, opt_state, tokens, labels, embeds=None):
        mb = tcfg.microbatches
        if mb > 1:
            B = tokens.shape[0] if tokens is not None else embeds.shape[0]
            assert B % mb == 0, "batch must divide microbatches"

            def re(x):
                return (
                    None
                    if x is None
                    else x.reshape(mb, B // mb, *x.shape[1:])
                )

            tks, lbs, ebs = re(tokens), re(labels), re(embeds)

            def micro(carry, xs):
                g_acc, loss_acc = carry
                tk = xs[0]
                lb = xs[1]
                eb = xs[2] if len(xs) > 2 else None
                (l, _), g = jax.value_and_grad(loss_for, has_aux=True)(
                    params, tk, lb, eb
                )
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, loss_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            xs = (tks, lbs) if ebs is None else (tks, lbs, ebs)
            (g, loss), _ = jax.lax.scan(micro, (g0, jnp.float32(0.0)), xs)
            g = jax.tree.map(lambda x: x / mb, g)
            loss = loss / mb
            metrics_aux: Dict[str, jax.Array] = {}
        else:
            (loss, metrics_aux), g = jax.value_and_grad(
                loss_for, has_aux=True
            )(params, tokens, labels, embeds)
        new_params, new_state, opt_metrics = adamw_step(
            params, g, opt_state, tcfg.optim
        )
        metrics = {"loss": loss, **opt_metrics}
        for k, v in (metrics_aux or {}).items():
            metrics[k] = v
        return new_params, new_state, metrics

    donate = (0, 1) if tcfg.donate_state else ()
    in_sh = [p_shard, s_shard, b_shard, b_shard]
    if model.cfg.frontend != "none":
        in_sh.append(b_shard)  # stub embeddings are batch-sharded too
    step_fn = jax.jit(
        train_step,
        in_shardings=tuple(in_sh),
        out_shardings=(p_shard, s_shard, None),
        donate_argnums=donate,
    )
    shardings = {"params": p_shard, "state": s_shard, "batch": b_shard}
    return step_fn, shardings, fallbacks


class Trainer:
    """Fault-tolerant training runner (checkpoint/restart + stragglers)."""

    def __init__(
        self,
        model: Model,
        mesh: Mesh,
        tcfg: TrainConfig,
        *,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 50,
        rules: ShardingRules = DEFAULT_RULES,
        failure_injector: Optional[Callable[[int], None]] = None,
    ):
        self.model = model
        self.mesh = mesh
        self.tcfg = tcfg
        self.rules = rules
        self.step_fn, self.shardings, self.fallbacks = build_train_step(
            model, mesh, tcfg, rules
        )
        self.ckpt = CheckpointManager(ckpt_dir, keep=2) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.stragglers = StragglerMonitor()
        self.failure_injector = failure_injector
        self.params = None
        self.opt_state = None
        self.step = 0

    def init_state(self, rng: jax.Array) -> None:
        with self.mesh:
            self.params = jax.jit(
                self.model.init, out_shardings=self.shardings["params"]
            )(rng)
            self.opt_state = jax.jit(
                lambda p: adamw_init(p, self.tcfg.optim),
                out_shardings=self.shardings["state"],
            )(self.params)
        self.step = 0

    def maybe_restore(self) -> bool:
        if self.ckpt is None:
            return False
        template = {
            "params": self.model.abstract_params(),
            "state": jax.eval_shape(
                lambda p: adamw_init(p, self.tcfg.optim),
                self.model.abstract_params(),
            ),
        }
        shardings = {
            "params": self.shardings["params"],
            "state": self.shardings["state"],
        }
        step, tree = self.ckpt.restore_latest(template, shardings)
        if step is None:
            return False
        self.params = tree["params"]
        self.opt_state = tree["state"]
        self.step = step
        return True

    def run(self, batches, n_steps: int, *, log_every: int = 10):
        """Run with automatic restart on SimulatedFailure."""
        history = []
        while self.step < n_steps:
            try:
                for _ in range(self.step, n_steps):
                    batch = next(batches)
                    if self.failure_injector is not None:
                        self.failure_injector(self.step)
                    t0 = time.perf_counter()
                    self.params, self.opt_state, metrics = self.step_fn(
                        self.params,
                        self.opt_state,
                        jnp.asarray(batch["tokens"]),
                        jnp.asarray(batch["labels"]),
                    )
                    loss = float(metrics["loss"])
                    dt = time.perf_counter() - t0
                    self.step += 1
                    history.append({"step": self.step, "loss": loss, "dt": dt})
                    if self.ckpt and self.step % self.ckpt_every == 0:
                        self.ckpt.save(
                            self.step,
                            {"params": self.params, "state": self.opt_state},
                        )
                    if log_every and self.step % log_every == 0:
                        print(
                            f"step {self.step:5d} loss {loss:.4f} "
                            f"({dt * 1e3:.0f} ms)"
                        )
            except SimulatedFailure as e:
                print(f"[ft] failure at step {self.step}: {e}; restarting")
                if not self.maybe_restore():
                    raise RuntimeError(
                        "failure before first checkpoint; cannot recover"
                    ) from e
        if self.ckpt:
            self.ckpt.wait()
        return history
