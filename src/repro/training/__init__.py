"""Training substrate: sharded train step builder + fault-tolerant runner."""

from .trainer import TrainConfig, Trainer, build_train_step

__all__ = ["TrainConfig", "Trainer", "build_train_step"]
