"""Assigned input shapes and (arch x shape) cell enumeration.

Four shapes per LM architecture (40 cells).  ``decode_*``/``long_*`` lower
``serve_step`` (one new token against a KV cache of seq_len), not
``train_step``; ``long_500k`` requires sub-quadratic context handling and is
skipped for pure full-attention archs (recorded as explicit skips — see
DESIGN.md §long_500k policy).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

__all__ = ["ShapeSpec", "SHAPES", "cells_for", "all_cells"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def cells_for(cfg) -> List[Tuple[str, Optional[str]]]:
    """(shape_name, skip_reason|None) for one architecture config."""
    out: List[Tuple[str, Optional[str]]] = []
    for name, spec in SHAPES.items():
        if name == "long_500k" and not cfg.supports_500k:
            out.append((name, "pure full attention: quadratic-context arch, "
                              "skipped per assignment (DESIGN.md)"))
        else:
            out.append((name, None))
    return out


def all_cells(registry) -> List[Tuple[str, str, Optional[str]]]:
    """(arch, shape, skip_reason) across the whole pool."""
    cells = []
    for arch_id, cfg_fn in registry.items():
        cfg = cfg_fn()
        for shape, skip in cells_for(cfg):
            cells.append((arch_id, shape, skip))
    return cells
