"""starcoder2-7b [dense] — GQA + RoPE code model. [arXiv:2402.19173; hf]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2402.19173", "tier": "hf", "family": "dense"}


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        n_layers=32,
        d_model=4608,
        n_heads=36,
        n_kv_heads=4,
        d_ff=18432,
        vocab=49152,
        attn_kind="full",
        mlp_act="gelu",
        supports_500k=False,
    )
