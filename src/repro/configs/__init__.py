"""Architecture registry: ``--arch <id>`` resolves here.

Each module defines ``config() -> ModelConfig`` with the exact assigned
hyperparameters, plus ``META`` (source + verification tier).  ``reduced()``
shrinks any config to a CPU-smoke-testable size while preserving its family
structure (MoE routing, MLA ranks, sliding windows, hybrid cadence, ...).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.models.common import ModelConfig

from . import (
    gemma3_1b,
    gemma3_27b,
    kimi_k2_1t_a32b,
    kimi_k2_mla,
    minicpm3_4b,
    musicgen_large,
    olmoe_1b_7b,
    qwen2_vl_7b,
    starcoder2_7b,
    xlstm_125m,
    zamba2_2_7b,
)
from .shapes import SHAPES, ShapeSpec, all_cells, cells_for

REGISTRY: Dict[str, Callable[[], ModelConfig]] = {
    "minicpm3-4b": minicpm3_4b.config,
    "gemma3-27b": gemma3_27b.config,
    "starcoder2-7b": starcoder2_7b.config,
    "gemma3-1b": gemma3_1b.config,
    "qwen2-vl-7b": qwen2_vl_7b.config,
    "zamba2-2.7b": zamba2_2_7b.config,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.config,
    "olmoe-1b-7b": olmoe_1b_7b.config,
    "xlstm-125m": xlstm_125m.config,
    "musicgen-large": musicgen_large.config,
    # beyond-pool variant (not an assigned cell; see its module docstring)
    "kimi-k2-1t-mla": kimi_k2_mla.config,
}

META = {
    "minicpm3-4b": minicpm3_4b.META,
    "gemma3-27b": gemma3_27b.META,
    "starcoder2-7b": starcoder2_7b.META,
    "gemma3-1b": gemma3_1b.META,
    "qwen2-vl-7b": qwen2_vl_7b.META,
    "zamba2-2.7b": zamba2_2_7b.META,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b.META,
    "olmoe-1b-7b": olmoe_1b_7b.META,
    "xlstm-125m": xlstm_125m.META,
    "musicgen-large": musicgen_large.META,
    "kimi-k2-1t-mla": kimi_k2_mla.META,
}


def get_config(arch: str) -> ModelConfig:
    if arch not in REGISTRY:
        raise KeyError(f"unknown arch {arch!r}; choose from {sorted(REGISTRY)}")
    return REGISTRY[arch]()


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving reduction for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab=256,
        head_dim=16,
        max_seq_len=256,
    )
    if cfg.attn_kind == "mla":
        kw.update(mla_kv_rank=32, mla_q_rank=48 if cfg.mla_q_rank else 0,
                  mla_rope_dim=8)
    if cfg.attn_kind == "sliding":
        kw.update(sliding_window=16, global_every=min(cfg.global_every, 2))
    if cfg.rope_kind == "mrope":
        kw.update(mrope_sections=(2, 3, 3))  # sums to reduced head_dim // 2
    if cfg.n_experts:
        kw.update(n_experts=8, experts_per_token=2,
                  first_dense_layers=min(cfg.first_dense_layers, 1))
    if cfg.family == "hybrid":
        kw.update(attn_block_every=2, ssm_state=16)
    if cfg.family == "ssm" and cfg.xlstm_pattern:
        kw.update(xlstm_pattern=cfg.xlstm_pattern[:4] or "msms")
    if cfg.frontend != "none":
        kw.update(frontend_dim=64)
    return cfg.with_(**kw)


__all__ = [
    "REGISTRY",
    "META",
    "SHAPES",
    "ShapeSpec",
    "get_config",
    "reduced",
    "cells_for",
    "all_cells",
]
