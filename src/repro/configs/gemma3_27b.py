"""gemma3-27b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.common import ModelConfig

META = {"source": "hf:google/gemma-3-1b-pt", "tier": "unverified", "family": "dense"}


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b",
        family="dense",
        n_layers=62,
        d_model=5376,
        n_heads=32,
        n_kv_heads=16,
        d_ff=21504,
        vocab=262144,
        head_dim=128,
        attn_kind="sliding",
        sliding_window=1024,
        global_every=6,          # 5 local : 1 global
        mlp_act="gelu",
        scale_embed=True,
        tie_embeddings=True,
        max_seq_len=131072,
        supports_500k=True,      # bounded-window KV for 5/6 of layers
    )
