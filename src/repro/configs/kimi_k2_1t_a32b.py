"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE, 384 experts top-8 with one
shared expert; first layer dense (paper-table config). [arXiv:2501.kimi2;
unverified]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2501.kimi2", "tier": "unverified", "family": "moe"}


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        d_ff=2048,              # per-expert FFN width
        vocab=163840,
        head_dim=112,
        attn_kind="full",
        n_experts=384,
        experts_per_token=8,
        n_shared_experts=1,
        first_dense_layers=1,
        supports_500k=False,
    )
