"""gemma3-1b [dense] — 5:1 local:global, 128k. [hf:google/gemma-3-1b-pt; unverified]"""

from repro.models.common import ModelConfig

META = {"source": "hf:google/gemma-3-1b-pt", "tier": "unverified", "family": "dense"}


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_ff=6912,
        vocab=262144,
        head_dim=256,
        attn_kind="sliding",
        sliding_window=512,
        global_every=6,
        mlp_act="gelu",
        scale_embed=True,
        tie_embeddings=True,
        max_seq_len=131072,
        supports_500k=True,
    )
