"""qwen2-vl-7b [vlm] — M-RoPE, dynamic-resolution vision (frontend stubbed:
input_specs() delivers precomputed patch embeddings). [arXiv:2409.12191; hf]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2409.12191", "tier": "hf", "family": "vlm"}


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b",
        family="vlm",
        n_layers=28,
        d_model=3584,
        n_heads=28,
        n_kv_heads=4,
        d_ff=18944,
        vocab=152064,
        head_dim=128,
        attn_kind="full",
        rope_kind="mrope",
        mrope_sections=(16, 24, 24),
        frontend="vision_stub",
        frontend_dim=3584,
        supports_500k=False,
    )
