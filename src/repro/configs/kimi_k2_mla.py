"""kimi-k2-1t-mla [moe] — BEYOND-POOL VARIANT (not an assigned cell).

The assigned kimi-k2 table row specifies GQA kv=8, but the real Kimi K2
inherits DeepSeek-V3's MLA.  This variant restores MLA (kv_rank 512, rope 64,
q_rank 1536) to quantify what the assigned GQA spec costs at decode: KV cache
per token drops from 8*112*2*2 B = 3,584 B/layer (GQA K+V) to
(512+64)*2 B = 1,152 B/layer (latent+rope) — 3.1x — and combined with the
absorbed-decode path (EXPERIMENTS.md §Perf H3) the decode cell's memory
term shrinks accordingly.
"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2501.kimi2 (+DeepSeek-V3 MLA)", "tier": "variant",
        "family": "moe"}


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-mla",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=64,
        d_ff=2048,
        vocab=163840,
        head_dim=128,
        attn_kind="mla",
        mla_kv_rank=512,
        mla_q_rank=1536,
        mla_rope_dim=64,
        n_experts=384,
        experts_per_token=8,
        n_shared_experts=1,
        first_dense_layers=1,
        supports_500k=False,
    )
