"""olmoe-1b-7b [moe] — 64 experts top-8, fully MoE. [arXiv:2409.02060; hf]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2409.02060", "tier": "hf", "family": "moe"}


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab=50304,
        attn_kind="full",
        n_experts=64,
        experts_per_token=8,
        supports_500k=False,
    )
