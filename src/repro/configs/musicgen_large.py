"""musicgen-large [audio] — decoder-only over EnCodec tokens; the EnCodec
frontend is stubbed (input_specs() provides frame embeddings).
[arXiv:2306.05284; hf]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2306.05284", "tier": "hf", "family": "audio"}


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        attn_kind="full",
        mlp_act="gelu",
        frontend="audio_stub",
        frontend_dim=2048,
        supports_500k=False,
    )
