"""xlstm-125m [ssm] — alternating mLSTM / sLSTM blocks (7:1-ish pattern,
d_ff=0: blocks carry internal projections). [arXiv:2405.04517; unverified]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2405.04517", "tier": "unverified", "family": "ssm"}


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        xlstm_pattern="mmmsmmmsmmms",
        supports_500k=True,     # O(1) recurrent state
    )
