"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every 6
layers. [arXiv:2411.15242; hf]"""

from repro.models.common import ModelConfig

META = {"source": "arXiv:2411.15242", "tier": "hf", "family": "hybrid"}


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        d_ff=10240,
        vocab=32000,
        attn_kind="full",
        ssm_state=64,
        ssm_conv=4,
        ssm_expand=2,
        attn_block_every=6,     # shared transformer block cadence
        supports_500k=True,     # O(1) SSM state
    )
