"""minicpm3-4b [dense] — MLA attention. [hf:openbmb/MiniCPM3-4B; hf]"""

from repro.models.common import ModelConfig

META = {"source": "hf:openbmb/MiniCPM3-4B", "tier": "hf", "family": "dense"}


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab=73448,
        head_dim=64,
        attn_kind="mla",
        mla_kv_rank=256,
        mla_q_rank=768,
        mla_rope_dim=32,
        supports_500k=False,  # MLA is full attention over the whole context
    )
