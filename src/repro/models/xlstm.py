"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrent memory mixing), with exponential gating and stabilizer state.

Layer pattern comes from ``cfg.xlstm_pattern`` ('m'/'s' per layer).  The
assigned xlstm-125m uses d_ff=0: blocks carry their own internal up/down
projections (projection factor 2) instead of a separate FFN, following the
xLSTM paper's block design.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec, rms_norm

__all__ = [
    "mlstm_specs",
    "slstm_specs",
    "mlstm_apply",
    "slstm_apply",
    "mlstm_decode",
    "slstm_decode",
    "init_mlstm_state",
    "init_slstm_state",
]


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    H = cfg.n_heads
    pd = cfg.param_dtype
    return {
        "w_up": ParamSpec((d, 2 * d), ("embed", "mlp"), pd),
        "w_q": ParamSpec((d, d), ("embed", "heads"), pd),
        "w_k": ParamSpec((d, d), ("embed", "heads"), pd),
        "w_v": ParamSpec((d, d), ("embed", "heads"), pd),
        "w_if": ParamSpec((d, 2 * H), ("embed", "heads"), jnp.float32),
        "w_down": ParamSpec((d, d), ("heads", "embed"), pd),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int):
    H = cfg.n_heads
    dh = cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, H, dh), jnp.float32),
        "m": jnp.full((batch, H), -1e30, jnp.float32),
    }


def _mlstm_gates(cfg, p, x_m):
    B, S, d = x_m.shape
    H = cfg.n_heads
    dh = d // H
    q = (x_m @ p["w_q"]).reshape(B, S, H, dh).astype(jnp.float32) * (dh**-0.5)
    k = (x_m @ p["w_k"]).reshape(B, S, H, dh).astype(jnp.float32) * (dh**-0.5)
    v = (x_m @ p["w_v"]).reshape(B, S, H, dh).astype(jnp.float32)
    gif = (x_m @ p["w_if"]).astype(jnp.float32).reshape(B, S, H, 2)
    return q, k, v, gif[..., 0], gif[..., 1]


def _mlstm_step(carry, inp):
    C, n, m = carry
    q, k, v, ig, fg = inp  # [B,H,dh] x3, [B,H] x2
    m_new = jnp.maximum(fg + m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(fg + m - m_new)
    C = f_p[..., None, None] * C + i_p[..., None, None] * (
        v[..., :, None] * k[..., None, :]
    )
    n = f_p[..., None] * n + i_p[..., None] * k
    num = jnp.einsum("bhvk,bhk->bhv", C, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = num / den[..., None]
    return (C, n, m_new), h


def mlstm_apply(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, *, return_state=False
):
    B, S, d = x.shape
    H = cfg.n_heads
    up = x @ p["w_up"]
    x_m, z = jnp.split(up, 2, axis=-1)
    q, k, v, ig, fg = _mlstm_gates(cfg, p, x_m)
    st0 = (
        jnp.zeros((B, H, d // H, d // H), jnp.float32),
        jnp.zeros((B, H, d // H), jnp.float32),
        jnp.full((B, H), -1e30, jnp.float32),
    )
    xs = tuple(a.swapaxes(0, 1) for a in (q, k, v, ig, fg))
    (C, n, m), hs = jax.lax.scan(_mlstm_step, st0, xs)
    h = hs.swapaxes(0, 1).reshape(B, S, d).astype(x.dtype)
    out = (h * jax.nn.silu(z)) @ p["w_down"]
    if return_state:
        return out, {"C": C, "n": n, "m": m}
    return out


def mlstm_decode(
    cfg: ModelConfig, p, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B, _, d = x.shape
    up = x @ p["w_up"]
    x_m, z = jnp.split(up, 2, axis=-1)
    q, k, v, ig, fg = _mlstm_gates(cfg, p, x_m)
    (C, n, m), h = _mlstm_step(
        (state["C"], state["n"], state["m"]),
        (q[:, 0], k[:, 0], v[:, 0], ig[:, 0], fg[:, 0]),
    )
    h = h.reshape(B, 1, d).astype(x.dtype)
    return (h * jax.nn.silu(z)) @ p["w_down"], {"C": C, "n": n, "m": m}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    pd = cfg.param_dtype
    return {
        "w_zifo": ParamSpec((d, 4 * d), ("embed", "mlp"), pd),
        # recurrent memory mixing (block-diagonal in the paper; dense here,
        # noted in DESIGN.md simplifications)
        "r_zifo": ParamSpec((d, 4 * d), ("embed", "mlp"), pd, scale=0.1),
        "w_out": ParamSpec((d, d), ("embed", "embed2"), pd),
    }


def init_slstm_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.ones((batch, d), jnp.float32),
        "m": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def _slstm_step(p, carry, wx):
    c, n, m, h_prev = carry
    rec = (h_prev.astype(wx.dtype) @ p["r_zifo"]).astype(jnp.float32)
    z_r, i_r, f_r, o_r = jnp.split(wx.astype(jnp.float32) + rec, 4, axis=-1)
    z = jnp.tanh(z_r)
    o = jax.nn.sigmoid(o_r)
    m_new = jnp.maximum(f_r + m, i_r)
    i_p = jnp.exp(i_r - m_new)
    f_p = jnp.exp(f_r + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h = o * (c / jnp.maximum(jnp.abs(n), 1.0))
    return (c, n, m_new, h), h


def slstm_apply(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array, *, return_state=False
):
    B, S, d = x.shape
    wx = x @ p["w_zifo"]  # [B,S,4d]
    st0 = (
        jnp.zeros((B, d), jnp.float32),
        jnp.ones((B, d), jnp.float32),
        jnp.zeros((B, d), jnp.float32),
        jnp.zeros((B, d), jnp.float32),
    )

    def step(carry, wxt):
        return _slstm_step(p, carry, wxt)

    (c, n, m, h_last), hs = jax.lax.scan(step, st0, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1).astype(x.dtype)
    out = h @ p["w_out"]
    if return_state:
        return out, {"c": c, "n": n, "m": m, "h": h_last}
    return out


def slstm_decode(
    cfg: ModelConfig, p, x: jax.Array, state: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    wx = (x @ p["w_zifo"])[:, 0]
    (c, n, m, h), _ = _slstm_step(
        p, (state["c"], state["n"], state["m"], state["h"]), wx
    )
    y = h[:, None, :].astype(x.dtype) @ p["w_out"]
    return y, {"c": c, "n": n, "m": m, "h": h}
