"""Model zoo: shared components + the ten assigned architectures."""

from .common import ModelConfig, ParamSpec, abstract, count_params, logical_axes, materialize
from .model import Model, Stage, build_plan

__all__ = [
    "ModelConfig", "ParamSpec", "abstract", "count_params",
    "logical_axes", "materialize", "Model", "Stage", "build_plan",
]
