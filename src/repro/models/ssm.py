"""Mamba2-style selective SSM block (zamba2's recurrent backbone).

A simplified SSD formulation with ngroups=1 (B/C shared across heads, the
Mamba2 default): input projection produces (z, x, B, C, dt); a depthwise
causal conv primes x/B/C; the state-space recurrence

    h_t = exp(-softplus(a) * dt_t) * h_{t-1} + dt_t * (x_t outer B_t)
    y_t = h_t @ C_t + D * x_t

runs as a jax.lax.scan over time for training/prefill and as a single fused
update for decode.  State shape per layer: [B, heads, d_head, d_state].
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec

__all__ = ["mamba_specs", "mamba_apply", "mamba_decode", "init_ssm_state"]


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = max(1, d_inner // 64)  # 64-wide heads (Mamba2 convention)
    d_head = d_inner // n_heads
    return d_inner, n_heads, d_head


def _proj_cols(cfg: ModelConfig):
    d_inner, n_heads, _ = _dims(cfg)
    ds = cfg.ssm_state
    # z, x, B, C, dt
    return 2 * d_inner + 2 * ds + n_heads


def mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    d_inner, n_heads, d_head = _dims(cfg)
    ds = cfg.ssm_state
    pd = cfg.param_dtype
    return {
        "w_in": ParamSpec((d, _proj_cols(cfg)), ("embed", "mlp"), pd),
        "conv_w": ParamSpec(
            (cfg.ssm_conv, d_inner + 2 * ds), ("conv", "mlp"), pd
        ),
        "a_log": ParamSpec((n_heads,), ("heads",), jnp.float32, init="zeros"),
        "d_skip": ParamSpec((n_heads,), ("heads",), jnp.float32, init="ones"),
        "dt_bias": ParamSpec((n_heads,), ("heads",), jnp.float32, init="zeros"),
        "w_out": ParamSpec((d_inner, d), ("mlp", "embed"), pd),
        "norm_z": ParamSpec((d_inner,), ("mlp",), pd, init="zeros"),
    }


def _split_proj(cfg: ModelConfig, proj: jax.Array):
    d_inner, n_heads, d_head = _dims(cfg)
    ds = cfg.ssm_state
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv along time. xbc: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(K):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :]
    return jax.nn.silu(out)


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    d_inner, n_heads, d_head = _dims(cfg)
    return {
        "h": jnp.zeros((batch, n_heads, d_head, cfg.ssm_state), dtype),
        "conv": jnp.zeros(
            (batch, cfg.ssm_conv - 1, d_inner + 2 * cfg.ssm_state), dtype
        ),
    }


def _ssm_scan(cfg, x, Bm, Cm, dt, a, d_skip):
    """x: [B,S,H,Dh]; Bm/Cm: [B,S,ds]; dt: [B,S,H] -> y [B,S,H,Dh]."""

    def step(h, inp):
        xt, bt, ct, dtt = inp  # [B,H,Dh], [B,ds], [B,ds], [B,H]
        decay = jnp.exp(-a[None, :] * dtt)[..., None, None]          # [B,H,1,1]
        upd = (dtt[..., None, None] * xt[..., :, None]) * bt[:, None, None, :]
        h = h * decay + upd
        yt = jnp.einsum("bhds,bs->bhd", h, ct) + d_skip[None, :, None] * xt
        return h, yt

    B = x.shape[0]
    _, n_heads, d_head = _dims(cfg)
    h0 = jnp.zeros((B, n_heads, d_head, cfg.ssm_state), jnp.float32)
    xs = (
        x.swapaxes(0, 1).astype(jnp.float32),
        Bm.swapaxes(0, 1).astype(jnp.float32),
        Cm.swapaxes(0, 1).astype(jnp.float32),
        dt.swapaxes(0, 1).astype(jnp.float32),
    )
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1), h  # [B,S,H,Dh], final state


def _prep(cfg: ModelConfig, p, u: jax.Array):
    d_inner, n_heads, d_head = _dims(cfg)
    ds = cfg.ssm_state
    proj = u @ p["w_in"]
    z, xbc, dt_raw = _split_proj(cfg, proj)
    return z, xbc, dt_raw, (d_inner, n_heads, d_head, ds)


def mamba_apply(
    cfg: ModelConfig, p: Dict[str, jax.Array], u: jax.Array, *, return_state=False
):
    """u: [B, S, d_model] -> y: [B, S, d_model] (training / prefill)."""
    B, S, _ = u.shape
    z, xbc_raw, dt_raw, (d_inner, n_heads, d_head, ds) = _prep(cfg, p, u)
    xbc = _causal_conv(xbc_raw, p["conv_w"])
    x, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + ds], axis=-1)
    x = x.reshape(B, S, n_heads, d_head)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None, :])
    a = jnp.exp(p["a_log"].astype(jnp.float32))
    y, h_final = _ssm_scan(cfg, x, Bm, Cm, dt, a, p["d_skip"].astype(jnp.float32))
    y = y.reshape(B, S, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z + p["norm_z"][None, None, :])
    out = y @ p["w_out"]
    if return_state:
        K = cfg.ssm_conv
        pad = jnp.pad(xbc_raw, ((0, 0), (K - 1, 0), (0, 0)))
        conv_tail = pad[:, pad.shape[1] - (K - 1) :, :].astype(jnp.float32)
        return out, {"h": h_final, "conv": conv_tail}
    return out


def mamba_decode(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    u: jax.Array,                  # [B, 1, d_model]
    state: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = u.shape[0]
    z, xbc_t, dt_raw, (d_inner, n_heads, d_head, ds) = _prep(cfg, p, u)
    # streaming depthwise conv: window = [conv_state, current]
    win = jnp.concatenate(
        [state["conv"], xbc_t[:, 0:1, :].astype(state["conv"].dtype)], axis=1
    )  # [B,K,C]
    conv_out = jax.nn.silu(
        jnp.einsum(
            "bkc,kc->bc", win.astype(jnp.float32), p["conv_w"].astype(jnp.float32)
        )
    ).astype(u.dtype)
    x, Bm, Cm = jnp.split(conv_out, [d_inner, d_inner + ds], axis=-1)
    x = x.reshape(B, n_heads, d_head).astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None, :])
    a = jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(-a[None, :] * dt)[..., None, None]
    h = (
        state["h"] * decay
        + (dt[..., None, None] * x[..., :, None]) * Bm[:, None, None, :]
    )
    y = jnp.einsum("bhds,bs->bhd", h, Cm) + p["d_skip"].astype(jnp.float32)[
        None, :, None
    ] * x
    y = y.reshape(B, 1, d_inner).astype(u.dtype)
    y = y * jax.nn.silu(z + p["norm_z"][None, None, :])
    new_state = {"h": h, "conv": win[:, 1:, :]}
    return y @ p["w_out"], new_state
