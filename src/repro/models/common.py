"""Shared model-definition machinery.

Every architecture is described by a :class:`ModelConfig`; parameters are
declared as :class:`ParamSpec` trees (shape + dtype + *logical axis names* +
initializer) and materialized three ways:

* ``materialize(spec, rng)``        -> real arrays (training / smoke tests)
* ``abstract(spec)``                -> ShapeDtypeStructs (multi-pod dry-run)
* ``logical_axes(spec)``            -> axis-name tuples (sharding rules)

Logical axis names are resolved to mesh axes by ``repro.distributed.sharding``
with automatic divisibility fallback, so tiny smoke configs and the production
mesh share one model definition.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ModelConfig",
    "ParamSpec",
    "materialize",
    "abstract",
    "logical_axes",
    "stack_specs",
    "tree_slice",
    "rms_norm",
    "count_params",
    "DEFAULT_PARAM_DTYPE",
]

DEFAULT_PARAM_DTYPE = jnp.bfloat16


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Superset config covering all ten assigned architectures."""

    name: str = "model"
    family: str = "dense"          # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 2
    n_kv_heads: int = 2
    d_ff: int = 256
    vocab: int = 1024
    head_dim: Optional[int] = None  # defaults to d_model // n_heads
    max_seq_len: int = 8192
    rope_theta: float = 10_000.0

    # attention structure
    attn_kind: str = "full"        # full | sliding | mla
    sliding_window: int = 1024
    global_every: int = 0          # e.g. 6 => layers 5, 11, ... are global
    rope_kind: str = "rope"        # rope | mrope | none
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)

    # MLA (minicpm3 / kimi-k2)
    mla_kv_rank: int = 256
    mla_q_rank: int = 0            # 0 => no q compression
    mla_rope_dim: int = 32

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    n_shared_experts: int = 0
    moe_every: int = 1             # every k-th layer is MoE (1 = all)
    first_dense_layers: int = 0    # leading dense layers (kimi-k2 style)
    capacity_factor: float = 1.25

    # SSM / hybrid
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    attn_block_every: int = 0      # zamba2: shared attn block cadence

    # xLSTM
    xlstm_pattern: str = ""        # e.g. "msms..." per layer; empty = n/a

    # frontends (vlm / audio): backbone consumes precomputed embeddings
    frontend: str = "none"         # none | vision_stub | audio_stub
    frontend_dim: int = 0          # embedding dim delivered by the stub

    # numerics
    scale_embed: bool = False      # gemma-style sqrt(d) embedding scaling
    mlp_act: str = "silu"          # silu | gelu
    param_dtype: Any = DEFAULT_PARAM_DTYPE
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # long-context policy (assignment: skip long_500k for pure full attention)
    supports_500k: bool = False

    # --- perf options (EXPERIMENTS.md §Perf; defaults = paper-faithful
    # baseline, flags flipped per hillclimb iteration) ---
    attn_sharding_constraints: bool = False  # anchor q/k/v + chunk-scan carry
    mla_absorbed_decode: bool = False        # score/output in latent space

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(1, self.n_kv_heads)

    def layer_kind(self, i: int) -> str:
        """What block sits at depth i (resolves hybrid/moe/sliding patterns)."""
        if self.family == "ssm" and self.xlstm_pattern:
            return "xlstm_" + self.xlstm_pattern[i % len(self.xlstm_pattern)]
        if self.family == "hybrid":
            return "mamba"
        if self.n_experts > 0:
            if i < self.first_dense_layers or (i % self.moe_every) != (
                self.moe_every - 1
            ):
                # note: with moe_every=1 every layer is MoE after the leading
                # dense layers
                if self.moe_every == 1 and i >= self.first_dense_layers:
                    return "moe"
                return "dense"
            return "moe"
        return "dense"

    def is_global_attn(self, i: int) -> bool:
        if self.attn_kind != "sliding" or self.global_every <= 0:
            return True
        return (i % self.global_every) == (self.global_every - 1)

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(1, self.n_kv_heads) == 0
        if self.n_experts:
            assert 0 < self.experts_per_token <= self.n_experts
        return self

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = DEFAULT_PARAM_DTYPE
    init: str = "normal"     # normal | zeros | ones | embed
    scale: Optional[float] = None  # None => 1/sqrt(fan_in)

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes {self.axes} do not match shape {self.shape}")


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def materialize(spec_tree, rng: jax.Array):
    """Instantiate real parameters from a spec tree."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            v = jnp.zeros(s.shape, s.dtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, s.dtype)
        else:
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            scale = s.scale if s.scale is not None else 1.0 / math.sqrt(fan_in)
            if s.init == "embed":
                scale = s.scale if s.scale is not None else 1.0
            v = (jax.random.normal(k, s.shape, jnp.float32) * scale).astype(s.dtype)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def abstract(spec_tree):
    """ShapeDtypeStruct tree (no allocation) — the dry-run path."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree, is_leaf=_is_spec
    )


def logical_axes(spec_tree):
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda s: tuple(s.axes), spec_tree, is_leaf=_is_spec)


def stack_specs(spec_tree, n: int, axis_name: str = "layers"):
    """Prepend a stacked-layers dim to every spec (for jax.lax.scan)."""
    return jax.tree.map(
        lambda s: ParamSpec(
            shape=(n, *s.shape),
            axes=(axis_name, *s.axes),
            dtype=s.dtype,
            init=s.init,
            scale=s.scale,
        ),
        spec_tree,
        is_leaf=_is_spec,
    )


def tree_slice(tree, i):
    """Slice layer ``i`` out of a stacked param tree (inside scan bodies)."""
    return jax.tree.map(lambda x: x[i], tree)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=_is_spec)
    total = 0
    for s in leaves:
        n = 1
        for d in (s.shape if _is_spec(s) else s.shape):
            n *= d
        total += n
    return total


# ---------------------------------------------------------------------------
# numerics helpers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(dt)
