"""Mixture-of-Experts layer (OLMoE / Kimi-K2-style top-k routing).

Default compute path is sort + grouped-GEMM via ``jax.lax.ragged_dot``
(dropless; no (T, E, C) one-hot dispatch tensors, which do not fit memory at
production scale).  Expert weights carry the ``experts`` logical axis so the
sharding rules place them expert-parallel on the mesh's model axis; token
routing across expert shards then lowers to all-to-alls — precisely the
GEMM+All-to-All pattern the paper names as Eidola's MoE use case.

Router aux losses (load-balance + z-loss) are returned for the train loop.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec

__all__ = ["moe_specs", "moe_apply"]


def moe_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    pd = cfg.param_dtype
    specs = {
        "router": ParamSpec((d, E), ("embed", "experts_logits"), jnp.float32),
        "w_gate": ParamSpec((E, d, ff), ("experts", "embed", "expert_mlp"), pd),
        "w_up": ParamSpec((E, d, ff), ("experts", "embed", "expert_mlp"), pd),
        "w_down": ParamSpec((E, ff, d), ("experts", "expert_mlp", "embed"), pd),
    }
    if cfg.n_shared_experts:
        sff = ff * cfg.n_shared_experts
        specs.update(
            {
                "sh_gate": ParamSpec((d, sff), ("embed", "mlp"), pd),
                "sh_up": ParamSpec((d, sff), ("embed", "mlp"), pd),
                "sh_down": ParamSpec((sff, d), ("mlp", "embed"), pd),
            }
        )
    return specs


def _router(cfg: ModelConfig, p, x2d: jax.Array):
    """top-k routing: returns (indices [T,k], weights [T,k], aux losses)."""
    logits = (x2d.astype(jnp.float32)) @ p["router"]  # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    # load-balance loss (Switch-style): E * sum(f_e * p_e)
    E = cfg.n_experts
    density = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    density = density / jnp.maximum(density.sum(), 1.0)
    p_mean = probs.mean(axis=0)
    lb_loss = E * jnp.sum(density * p_mean)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return idx, weights, {"moe_load_balance": lb_loss, "moe_z": z_loss}


def _grouped_ffn(cfg: ModelConfig, p, xs: jax.Array, group_sizes: jax.Array):
    """Per-expert gated MLP on expert-sorted tokens via grouped GEMM."""
    act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    return jax.lax.ragged_dot((act(g) * u).astype(xs.dtype), p["w_down"], group_sizes)


def moe_apply(
    cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """x: [B, S, d] -> (y, aux_losses)."""
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    T = x2d.shape[0]
    k = cfg.experts_per_token
    idx, weights, aux = _router(cfg, p, x2d)

    # sort token-expert assignments by expert id -> grouped GEMM
    flat_expert = idx.reshape(-1)                      # [T*k]
    order = jnp.argsort(flat_expert)
    token_of = order // k                              # originating token
    xs = x2d[token_of]                                 # [T*k, d] expert-sorted
    group_sizes = jnp.zeros((cfg.n_experts,), jnp.int32).at[flat_expert].add(1)
    ys = _grouped_ffn(cfg, p, xs, group_sizes)         # [T*k, d]

    # combine: scatter-add back with routing weights
    w_sorted = weights.reshape(-1)[order].astype(ys.dtype)
    y2d = jnp.zeros((T, d), ys.dtype).at[token_of].add(ys * w_sorted[:, None])

    if cfg.n_shared_experts:
        act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
        sh = (act(x2d @ p["sh_gate"]) * (x2d @ p["sh_up"])) @ p["sh_down"]
        y2d = y2d + sh
    return y2d.reshape(B, S, d).astype(x.dtype), aux
