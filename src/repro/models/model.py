"""Model assembly: block definitions, layer-stack plans, forward/prefill/decode.

A model is a sequence of *stages* derived from its :class:`ModelConfig`:

* ``scan``   — N homogeneous layers, parameters stacked on a leading
               ``layers`` dim and executed with ``jax.lax.scan`` (keeps HLO
               compact for 62-layer production configs);
* ``single`` — one layer with its own parameters (xLSTM's m/s alternation);
* ``shared`` — one layer whose parameters live once at the top level and are
               re-applied at several depths (zamba2's shared attention block).

Train-time forward uses the scan path; serving (prefill + decode) walks
layers in a Python loop so per-layer caches may be heterogeneous (full KV,
ring-buffered sliding KV, MLA latents, SSM/xLSTM states).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import (
    attention_apply,
    attention_decode,
    attention_specs,
    init_kv_cache,
)
from .common import (
    ModelConfig,
    ParamSpec,
    abstract,
    count_params,
    logical_axes,
    materialize,
    rms_norm,
    stack_specs,
    tree_slice,
)
from .mlp import mlp_apply, mlp_specs
from .moe import moe_apply, moe_specs
from .ssm import init_ssm_state, mamba_apply, mamba_decode, mamba_specs
from .xlstm import (
    init_mlstm_state,
    init_slstm_state,
    mlstm_apply,
    mlstm_decode,
    mlstm_specs,
    slstm_apply,
    slstm_decode,
    slstm_specs,
)

__all__ = ["Stage", "build_plan", "Model"]


@dataclass(frozen=True)
class Stage:
    kind: str          # scan | single | shared
    block: str         # dense | moe | mamba | xlstm_m | xlstm_s
    n: int             # layers in this stage (1 for single/shared)
    layer_offset: int  # absolute index of the first layer in this stage


# ---------------------------------------------------------------------------
# plans
# ---------------------------------------------------------------------------


def build_plan(cfg: ModelConfig) -> List[Stage]:
    L = cfg.n_layers
    if cfg.family == "hybrid" and cfg.attn_block_every > 0:
        stages: List[Stage] = []
        off = 0
        while off < L:
            n = min(cfg.attn_block_every, L - off)
            stages.append(Stage("scan", "mamba", n, off))
            off += n
            if off < L or n == cfg.attn_block_every:
                # zamba2: the SAME transformer block after every mamba group
                stages.append(Stage("shared", "dense", 1, off))
        return stages
    if cfg.family == "ssm" and cfg.xlstm_pattern:
        return [
            Stage("single", "xlstm_" + cfg.xlstm_pattern[i % len(cfg.xlstm_pattern)], 1, i)
            for i in range(L)
        ]
    if cfg.n_experts > 0:
        fd = cfg.first_dense_layers
        stages = []
        if fd:
            stages.append(Stage("scan", "dense", fd, 0))
        stages.append(Stage("scan", "moe", L - fd, fd))
        return stages
    return [Stage("scan", "dense", L, 0)]


def _block_specs(cfg: ModelConfig, block: str) -> Dict[str, Any]:
    d = cfg.d_model
    pd = cfg.param_dtype
    ln = lambda: ParamSpec((d,), ("embed",), pd, init="zeros")  # noqa: E731
    if block == "dense":
        return {"ln1": ln(), "attn": attention_specs(cfg), "ln2": ln(),
                "mlp": mlp_specs(cfg)}
    if block == "moe":
        return {"ln1": ln(), "attn": attention_specs(cfg), "ln2": ln(),
                "moe": moe_specs(cfg)}
    if block == "mamba":
        return {"ln1": ln(), "mamba": mamba_specs(cfg)}
    if block == "xlstm_m":
        return {"ln1": ln(), "cell": mlstm_specs(cfg)}
    if block == "xlstm_s":
        return {"ln1": ln(), "cell": slstm_specs(cfg)}
    raise ValueError(f"unknown block {block!r}")


_ZERO_AUX = {"moe_load_balance": 0.0, "moe_z": 0.0, "moe_dropped": 0.0}


def _moe_dispatch(cfg, p, h, mesh):
    from .moe_ep import ep_applicable, moe_apply_ep

    if ep_applicable(cfg, mesh):
        return moe_apply_ep(cfg, p, h, mesh)
    y, aux = moe_apply(cfg, p, h)
    aux.setdefault("moe_dropped", jnp.float32(0.0))
    return y, aux


def _block_apply(cfg, block, p, x, positions, is_global, mesh=None):
    aux = {k: jnp.float32(0.0) for k in _ZERO_AUX}
    if block in ("dense", "moe"):
        a, _ = attention_apply(
            cfg, p["attn"], rms_norm(x, p["ln1"], cfg.norm_eps), positions,
            is_global=is_global, mesh=mesh,
        )
        x = x + a
        h = rms_norm(x, p["ln2"], cfg.norm_eps)
        if block == "moe":
            y, aux = _moe_dispatch(cfg, p["moe"], h, mesh)
            aux = {**{k: jnp.float32(0.0) for k in _ZERO_AUX}, **aux}
        else:
            y = mlp_apply(cfg, p["mlp"], h)
        return x + y, aux
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if block == "mamba":
        return x + mamba_apply(cfg, p["mamba"], h), aux
    if block == "xlstm_m":
        return x + mlstm_apply(cfg, p["cell"], h), aux
    if block == "xlstm_s":
        return x + slstm_apply(cfg, p["cell"], h), aux
    raise ValueError(block)


# ---------------------------------------------------------------------------
# the model object
# ---------------------------------------------------------------------------


class Model:
    """Functional model wrapper: specs + pure apply functions.

    ``mesh`` (optional) enables manual-collective paths (expert-parallel MoE
    via shard_map); without it everything lowers through GSPMD alone.
    """

    def __init__(self, cfg: ModelConfig, mesh=None):
        self.cfg = cfg.validate()
        self.mesh = mesh
        self.plan = build_plan(cfg)

    # -- parameters -----------------------------------------------------------

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "embed": ParamSpec(
                (cfg.vocab, cfg.d_model), ("vocab", "embed"), cfg.param_dtype,
                init="embed", scale=0.02,
            ),
            "final_norm": ParamSpec(
                (cfg.d_model,), ("embed",), cfg.param_dtype, init="zeros"
            ),
            "stages": [],
        }
        need_shared = False
        for st in self.plan:
            if st.kind == "scan":
                specs["stages"].append(stack_specs(_block_specs(cfg, st.block), st.n))
            elif st.kind == "single":
                specs["stages"].append(_block_specs(cfg, st.block))
            else:  # shared
                specs["stages"].append({})  # parameters live under "shared"
                need_shared = True
        if need_shared:
            specs["shared"] = _block_specs(cfg, "dense")
        if not cfg.tie_embeddings:
            specs["lm_head"] = ParamSpec(
                (cfg.d_model, cfg.vocab), ("embed", "vocab"), cfg.param_dtype,
                scale=0.02,
            )
        return specs

    def init(self, rng: jax.Array):
        return materialize(self.param_specs(), rng)

    def abstract_params(self):
        return abstract(self.param_specs())

    def param_axes(self):
        return logical_axes(self.param_specs())

    def n_params(self) -> int:
        return count_params(self.param_specs())

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        cfg = self.cfg
        total = self.n_params()
        if cfg.n_experts == 0:
            return total
        moe_layers = cfg.n_layers - cfg.first_dense_layers
        per_expert = 3 * cfg.d_model * cfg.d_ff
        inactive = moe_layers * (cfg.n_experts - cfg.experts_per_token) * per_expert
        return total - inactive

    # -- embedding ------------------------------------------------------------

    def _embed(self, params, tokens=None, embeds=None):
        cfg = self.cfg
        if embeds is not None:
            x = embeds.astype(cfg.param_dtype)
        else:
            x = params["embed"][tokens]
        if cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        return x

    def _head(self, params, x):
        cfg = self.cfg
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ w).astype(jnp.float32)
        if cfg.logit_softcap > 0:
            c = cfg.logit_softcap
            logits = jnp.tanh(logits / c) * c
        return logits

    # -- training / full forward ----------------------------------------------

    def forward(
        self,
        params,
        tokens: Optional[jax.Array] = None,
        *,
        embeds: Optional[jax.Array] = None,
        remat: bool = False,
        remat_policy: str = "full",
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Full causal forward -> (logits [B,S,V], aux losses)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        aux_total = {k: jnp.float32(0.0) for k in _ZERO_AUX}

        for st, p_st in zip(self.plan, params["stages"]):
            if st.kind == "scan":
                flags = jnp.array(
                    [cfg.is_global_attn(st.layer_offset + i) for i in range(st.n)]
                )

                import functools

                base = functools.partial(
                    _block_apply, cfg, st.block, mesh=self.mesh
                )
                if remat:
                    from repro.distributed.remat import get_policy

                    fn = jax.checkpoint(base, policy=get_policy(remat_policy))
                else:
                    fn = base

                def body(carry, xs, fn=fn):
                    x_c, aux_c = carry
                    p_l, flag = xs
                    x_c, aux = fn(p_l, x_c, positions, flag)
                    aux_c = {k: aux_c[k] + aux[k] for k in aux_c}
                    return (x_c, aux_c), None

                (x, aux_total), _ = jax.lax.scan(
                    body, (x, aux_total), (p_st, flags)
                )
            else:
                p_l = params["shared"] if st.kind == "shared" else p_st
                x, aux = _block_apply(
                    cfg, st.block, p_l, x, positions,
                    cfg.is_global_attn(st.layer_offset), mesh=self.mesh,
                )
                aux_total = {k: aux_total[k] + aux[k] for k in aux_total}
        return self._head(params, x), aux_total

    def loss_fn(
        self,
        params,
        tokens: Optional[jax.Array] = None,
        labels: Optional[jax.Array] = None,
        *,
        embeds: Optional[jax.Array] = None,
        remat: bool = False,
        remat_policy: str = "full",
        moe_loss_weight: float = 0.01,
        z_loss_weight: float = 1e-4,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        logits, aux = self.forward(
            params, tokens, embeds=embeds, remat=remat,
            remat_policy=remat_policy,
        )
        if labels is None:
            labels = jnp.pad(tokens[:, 1:], ((0, 0), (0, 1)))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = jnp.ones_like(nll)
        ce = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        total = (
            ce
            + moe_loss_weight * aux["moe_load_balance"]
            + z_loss_weight * aux["moe_z"]
        )
        metrics = {"ce": ce, **aux}
        return total, metrics

    # -- serving ----------------------------------------------------------------

    def _layer_blocks(self) -> List[Tuple[str, Stage, int]]:
        """(block_kind, stage, index_within_stage) per absolute layer."""
        out = []
        for st in self.plan:
            for i in range(st.n):
                out.append((st.block, st, i))
        return out

    def _layer_params(self, params, st: Stage, i: int):
        p_st = params["stages"][self.plan.index(st)]
        if st.kind == "scan":
            return tree_slice(p_st, i)
        if st.kind == "shared":
            return params["shared"]
        return p_st

    def init_caches(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        caches = []
        for li, (block, st, i) in enumerate(self._layer_blocks()):
            if block in ("dense", "moe"):
                caches.append(init_kv_cache(cfg, batch, max_len, li, dtype))
            elif block == "mamba":
                caches.append(init_ssm_state(cfg, batch))
            elif block == "xlstm_m":
                caches.append(init_mlstm_state(cfg, batch))
            elif block == "xlstm_s":
                caches.append(init_slstm_state(cfg, batch))
        return caches

    def abstract_caches(self, batch: int, max_len: int, dtype=None):
        return jax.eval_shape(lambda: self.init_caches(batch, max_len, dtype))

    def prefill(self, params, tokens=None, *, embeds=None):
        """Process a full prompt; returns (last-token logits, caches)."""
        cfg = self.cfg
        x = self._embed(params, tokens, embeds)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
        caches = []
        for li, (block, st, i) in enumerate(self._layer_blocks()):
            p_l = self._layer_params(params, st, i)
            h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
            if block in ("dense", "moe"):
                a, kv = attention_apply(
                    cfg, p_l["attn"], h, positions,
                    is_global=cfg.is_global_attn(li), mesh=self.mesh,
                )
                caches.append(self._prefill_cache(kv, li, S))
                x = x + a
                h2 = rms_norm(x, p_l["ln2"], cfg.norm_eps)
                if block == "moe":
                    y, _ = _moe_dispatch(cfg, p_l["moe"], h2, self.mesh)
                else:
                    y = mlp_apply(cfg, p_l["mlp"], h2)
                x = x + y
            elif block == "mamba":
                y, state = mamba_apply(cfg, p_l["mamba"], h, return_state=True)
                caches.append(state)
                x = x + y
            elif block == "xlstm_m":
                y, state = mlstm_apply(cfg, p_l["cell"], h, return_state=True)
                caches.append(state)
                x = x + y
            elif block == "xlstm_s":
                y, state = slstm_apply(cfg, p_l["cell"], h, return_state=True)
                caches.append(state)
                x = x + y
        logits = self._head(params, x[:, -1:, :])
        return logits[:, 0, :], caches

    def _prefill_cache(self, kv, layer_idx: int, S: int):
        cfg = self.cfg
        if cfg.attn_kind == "mla":
            c_kv, k_pe = kv
            return {"c_kv": c_kv, "k_pe": k_pe}
        k, v = kv
        if cfg.attn_kind == "sliding" and not cfg.is_global_attn(layer_idx):
            w = min(cfg.sliding_window, S)
            idx = (jnp.arange(S - w, S)) % cfg.sliding_window
            kc = jnp.zeros((k.shape[0], min(cfg.sliding_window, S), *k.shape[2:]),
                           k.dtype).at[:, idx].set(k[:, S - w :])
            vc = jnp.zeros_like(kc).at[:, idx].set(v[:, S - w :])
            return {"k": kc, "v": vc}
        return {"k": k, "v": v}

    def decode_step(self, params, caches, tokens, pos, *, embeds=None):
        """One token for every sequence in the batch.

        tokens: i32[B]; pos: i32[] tokens already in the cache.
        Returns (logits [B, V], new caches).
        """
        cfg = self.cfg
        x = self._embed(
            params,
            tokens[:, None] if tokens is not None else None,
            embeds,
        )
        new_caches = []
        for li, (block, st, i) in enumerate(self._layer_blocks()):
            p_l = self._layer_params(params, st, i)
            h = rms_norm(x, p_l["ln1"], cfg.norm_eps)
            if block in ("dense", "moe"):
                a, cache = attention_decode(
                    cfg, p_l["attn"], h, caches[li], pos, layer_idx=li
                )
                x = x + a
                h2 = rms_norm(x, p_l["ln2"], cfg.norm_eps)
                if block == "moe":
                    y, _ = _moe_dispatch(cfg, p_l["moe"], h2, self.mesh)
                else:
                    y = mlp_apply(cfg, p_l["mlp"], h2)
                x = x + y
            elif block == "mamba":
                y, cache = mamba_decode(cfg, p_l["mamba"], h, caches[li])
                x = x + y
            elif block == "xlstm_m":
                y, cache = mlstm_decode(cfg, p_l["cell"], h, caches[li])
                x = x + y
            elif block == "xlstm_s":
                y, cache = slstm_decode(cfg, p_l["cell"], h, caches[li])
                x = x + y
            new_caches.append(cache)
        logits = self._head(params, x)
        return logits[:, 0, :], new_caches
