"""Expert-parallel MoE via shard_map all-to-all (the production path).

GSPMD cannot partition ``ragged_dot`` over tokens/experts — it all-gathers
every token to every device and computes densely against local experts
(~500x FLOPs at olmoe scale; measured in EXPERIMENTS.md §Perf).  This module
routes tokens explicitly instead, which is also precisely the paper's MoE
workload ("embedding pooling + All-to-All and GEMM + All-to-All ... can be
evaluated using Eidola without modification"):

scatter path (training / large token counts, ``T_loc % msz == 0``):
  1. each model-axis rank takes its 1/msz slice of the data-shard's tokens,
  2. routes top-k pairs into per-destination capacity buffers (overflow
     drops, counted in aux metrics),
  3. ``all_to_all`` over the model axis delivers pairs to expert owners,
  4. local grouped GEMM (``ragged_dot``) over the rank's E/msz experts,
  5. ``all_to_all`` back + weighted combine + ``all_gather`` of token slices.

gather path (decode / tiny token counts):
  every rank computes only the pairs owned by its local experts on the full
  (small) token set and a ``psum`` over the model axis combines.

Both paths are differentiable (sort/scatter/all_to_all all have transposes)
and validated against the dense local oracle in tests.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.compat import SHARD_MAP_NO_CHECK, axis_size, shard_map

from .common import ModelConfig

__all__ = ["moe_apply_ep", "ep_applicable"]


def ep_applicable(cfg: ModelConfig, mesh: Optional[Mesh]) -> bool:
    if mesh is None or "model" not in mesh.shape:
        return False
    msz = mesh.shape["model"]
    return msz > 1 and cfg.n_experts % msz == 0


def _act(cfg):
    return jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu


def _route(cfg: ModelConfig, p, xm):
    """top-k routing on a token slice. xm: [T, d]."""
    logits = xm.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    weights, idx = jax.lax.top_k(probs, cfg.experts_per_token)
    weights = weights / jnp.maximum(weights.sum(-1, keepdims=True), 1e-9)
    E = cfg.n_experts
    density = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    density = density / jnp.maximum(density.sum(), 1.0)
    lb = E * jnp.sum(density * probs.mean(axis=0))
    zl = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return idx, weights, lb, zl


def _grouped_ffn(cfg, p, xs, group_sizes):
    act = _act(cfg)
    g = jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)
    u = jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    return jax.lax.ragged_dot(
        (act(g) * u).astype(xs.dtype), p["w_down"], group_sizes
    )


def _shared_ffn(cfg, p, x2):
    if not cfg.n_shared_experts:
        return jnp.zeros_like(x2)
    act = _act(cfg)
    return ((act(x2 @ p["sh_gate"]) * (x2 @ p["sh_up"])) @ p["sh_down"]).astype(
        x2.dtype
    )


def _pmean_axes(v, axes):
    for a in axes:
        v = jax.lax.pmean(v, a)
    return v


# ---------------------------------------------------------------------------
# scatter path (training)
# ---------------------------------------------------------------------------


def _ep_scatter_body(cfg: ModelConfig, reduce_axes, ff_axis, p, x_blk):
    """Inside shard_map: x_blk [B_loc, S, d] identical across model ranks."""
    B_loc, S, d = x_blk.shape
    if ff_axis:
        # FSDP-style per-layer gather of the ff-sharded expert weights
        p = dict(p)
        p["w_gate"] = jax.lax.all_gather(p["w_gate"], ff_axis, axis=2, tiled=True)
        p["w_up"] = jax.lax.all_gather(p["w_up"], ff_axis, axis=2, tiled=True)
        p["w_down"] = jax.lax.all_gather(p["w_down"], ff_axis, axis=1, tiled=True)
    msz = axis_size("model")
    midx = jax.lax.axis_index("model")
    E_loc = cfg.n_experts // msz
    k = cfg.experts_per_token
    T = B_loc * S
    Tm = T // msz
    x2 = x_blk.reshape(T, d)
    xm = jax.lax.dynamic_slice_in_dim(x2, midx * Tm, Tm)

    idx, weights, lb, zl = _route(cfg, p, xm)
    flat_e = idx.reshape(-1)                    # [Tm*k] global expert ids
    pair_tok = jnp.arange(Tm * k) // k
    dest = flat_e // E_loc                      # owning model rank
    C = int(math.ceil(Tm * k / msz * cfg.capacity_factor))

    # position of each pair within its destination buffer (sorted by dest)
    order = jnp.argsort(dest)
    sdest = dest[order]
    run_start = jnp.searchsorted(sdest, jnp.arange(msz), side="left")
    pos_sorted = jnp.arange(Tm * k) - run_start[sdest]
    keep = pos_sorted < C
    dropped = (~keep).sum().astype(jnp.float32)
    pos_clamped = jnp.where(keep, pos_sorted, C)  # OOB scatter rows drop

    send_x = jnp.zeros((msz, C, d), x2.dtype)
    send_le = jnp.full((msz, C), E_loc, jnp.int32)   # E_loc = dummy group
    gathered = xm[pair_tok[order]]
    send_x = send_x.at[sdest, pos_clamped].set(
        jnp.where(keep[:, None], gathered, 0.0)
    )
    send_le = send_le.at[sdest, pos_clamped].set(
        jnp.where(keep, flat_e[order] % E_loc, E_loc)
    )

    recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=False)
    recv_le = jax.lax.all_to_all(send_le, "model", 0, 0, tiled=False)
    flat_x = recv_x.reshape(msz * C, d)
    flat_le = recv_le.reshape(msz * C)

    order2 = jnp.argsort(flat_le)
    xs = flat_x[order2]
    gs = jnp.zeros((E_loc + 1,), jnp.int32).at[flat_le].add(1)
    ys = _grouped_ffn(cfg, p, xs, gs[:-1])       # dummy-group rows -> 0
    y_flat = jnp.zeros_like(flat_x).at[order2].set(ys.astype(flat_x.dtype))
    y_buf = y_flat.reshape(msz, C, d)

    ret = jax.lax.all_to_all(y_buf, "model", 0, 0, tiled=False)
    # gather my pairs' results back out of the buffers
    pair_y = ret[sdest, pos_clamped % C]          # clamped rows get weight 0
    pair_w = jnp.where(keep, weights.reshape(-1)[order], 0.0)
    y_m = jnp.zeros((Tm, d), jnp.float32).at[pair_tok[order]].add(
        pair_y.astype(jnp.float32) * pair_w[:, None]
    )
    y_m = y_m.astype(x2.dtype) + _shared_ffn(cfg, p, xm)
    y_full = jax.lax.all_gather(y_m, "model", axis=0, tiled=True)  # [T, d]

    aux = jnp.stack([lb, zl, dropped])
    aux = _pmean_axes(aux, ("model", *reduce_axes))
    return y_full.reshape(B_loc, S, d), aux


# ---------------------------------------------------------------------------
# gather path (decode / tiny T)
# ---------------------------------------------------------------------------


def _ep_gather_body(cfg: ModelConfig, reduce_axes, ff_axis, p, x_blk):
    B_loc, S, d = x_blk.shape
    msz = axis_size("model")
    midx = jax.lax.axis_index("model")
    E_loc = cfg.n_experts // msz
    k = cfg.experts_per_token
    T_loc = B_loc * S
    x_loc = x_blk.reshape(T_loc, d)
    if ff_axis:
        # tokens are few at decode: gather them across the ff-sharding axis
        # and compute PARTIAL expert outputs on the local ff slice
        x2 = jax.lax.all_gather(x_loc, ff_axis, axis=0, tiled=True)
    else:
        x2 = x_loc
    T = x2.shape[0]

    idx, weights, lb, zl = _route(cfg, p, x2)
    flat_e = idx.reshape(-1)
    pair_tok = jnp.arange(T * k) // k
    mine = (flat_e // E_loc) == midx
    le = jnp.where(mine, flat_e % E_loc, E_loc)    # dummy group for others

    order = jnp.argsort(le)
    xs = x2[pair_tok[order]]
    gs = jnp.zeros((E_loc + 1,), jnp.int32).at[le].add(1)
    ys = _grouped_ffn(cfg, p, xs, gs[:-1])          # partial over ff slice
    w_sorted = jnp.where(mine, weights.reshape(-1), 0.0)[order]
    y2 = jnp.zeros((T, d), jnp.float32).at[pair_tok[order]].add(
        ys.astype(jnp.float32) * w_sorted[:, None]
    )
    y2 = jax.lax.psum(y2, "model")
    if ff_axis:
        y2 = jax.lax.psum(y2, ff_axis)              # sum ff-slice partials
        aidx = jax.lax.axis_index(ff_axis)
        y2 = jax.lax.dynamic_slice_in_dim(y2, aidx * T_loc, T_loc)
    y2 = y2.astype(x_loc.dtype) + _shared_ffn(cfg, p, x_loc)
    aux = jnp.stack([lb, zl, jnp.float32(0.0)])
    aux = _pmean_axes(aux, ("model", *reduce_axes))
    return y2.reshape(B_loc, S, d), aux


# ---------------------------------------------------------------------------
# public entry
# ---------------------------------------------------------------------------


def moe_apply_ep(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,
    mesh: Mesh,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Expert-parallel MoE layer. x: [B, S, d], B sharded on (pod, data)."""
    msz = mesh.shape["model"]
    B, S, d = x.shape
    batch_axes = []
    div = 1
    for a in ("pod", "data"):
        if a in mesh.shape and B % (div * mesh.shape[a]) == 0:
            batch_axes.append(a)
            div *= mesh.shape[a]
    T_loc = (B // div) * S
    use_scatter = T_loc % msz == 0 and (T_loc // msz) >= 8

    x_spec = P(tuple(batch_axes) if batch_axes else None, None, None)
    # expert FFN width shards across data when divisible (FSDP-style storage)
    dsz_m = mesh.shape.get("data", 1)
    ff_axis = "data" if (dsz_m > 1 and cfg.d_ff % dsz_m == 0) else None
    ff_spec = ff_axis
    param_specs = {
        "router": P(None, None),
        "w_gate": P("model", None, ff_spec),
        "w_up": P("model", None, ff_spec),
        "w_down": P("model", ff_spec, None),
    }
    for key in ("sh_gate", "sh_up", "sh_down"):
        if key in p:
            param_specs[key] = P(None, None)
    p_used = {k: p[k] for k in param_specs}

    body = _ep_scatter_body if use_scatter else _ep_gather_body
    fn = shard_map(
        partial(body, cfg, tuple(batch_axes), ff_axis),
        mesh=mesh,
        in_specs=(param_specs, x_spec),
        out_specs=(x_spec, P(None)),
        **SHARD_MAP_NO_CHECK,
    )
    y, aux = fn(p_used, x)
    return y, {
        "moe_load_balance": aux[0],
        "moe_z": aux[1],
        "moe_dropped": aux[2],
    }
