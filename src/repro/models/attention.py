"""Attention variants: GQA (full / sliding-window), MLA, RoPE / M-RoPE.

All attention is computed chunked over the KV axis (flash-attention style
running log-sum-exp) so prefill at 32k and training at 4k never materialize
S x S score matrices; decode (q_len==1) uses the direct path, which shards
cleanly over a sequence-parallel KV cache (GSPMD inserts the partial-softmax
reductions).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec, rms_norm

__all__ = [
    "attention_specs",
    "attention_apply",
    "attention_decode",
    "init_kv_cache",
    "rope_cos_sin",
    "apply_rope",
]

_NEG_INF = -2.0e38


def _anchor(x, mesh, *parts):
    """with_sharding_constraint with axis-presence + divisibility guards.

    Anchors activation layouts so GSPMD keeps one layout through the chunked
    attention scan instead of resharding the carry every iteration (measured:
    one all-reduce per chunk per layer without this — EXPERIMENTS.md §Perf).
    """
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P

    clean = []
    for dim, ax in zip(x.shape, parts):
        if ax is None or ax not in mesh.shape or dim % mesh.shape[ax] != 0:
            clean.append(None)
        else:
            clean.append(ax)
    clean += [None] * (x.ndim - len(clean))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*clean))
    )


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE and Qwen2-VL style M-RoPE)
# ---------------------------------------------------------------------------


def rope_cos_sin(
    positions: jax.Array,  # i32[B, S] or i32[3, B, S] for mrope
    head_dim: int,
    theta: float,
    mrope_sections: Optional[Tuple[int, int, int]] = None,
) -> Tuple[jax.Array, jax.Array]:
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    else:
        # M-RoPE: rotary dims are split into (temporal, h, w) sections; each
        # section rotates with its own position stream.  With identical
        # streams (text tokens) this reduces to standard RoPE.
        assert mrope_sections is not None and sum(mrope_sections) == half
        parts = []
        off = 0
        for sec, pos in zip(mrope_sections, positions):
            parts.append(pos[..., None].astype(jnp.float32) * freqs[off : off + sec])
            off += sec
        ang = jnp.concatenate(parts, axis=-1)  # [B,S,half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [B, S, H, D]; cos/sin: [B, S, D/2] (broadcast over heads)."""
    d = x.shape[-1]
    x1, x2 = x[..., : d // 2], x[..., d // 2 :]
    c = cos[:, :, None, :].astype(x.dtype)
    s = sin[:, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------


def attention_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, hd = cfg.d_model, cfg.hd
    pd = cfg.param_dtype
    if cfg.attn_kind == "mla":
        r, rd = cfg.mla_kv_rank, cfg.mla_rope_dim
        specs: Dict[str, ParamSpec] = {
            "w_dkv": ParamSpec((d, r + rd), ("embed", "rank"), pd),
            "w_uk": ParamSpec((r, cfg.n_heads * hd), ("rank", "heads"), pd),
            "w_uv": ParamSpec((r, cfg.n_heads * hd), ("rank", "heads"), pd),
            "w_o": ParamSpec((cfg.n_heads * hd, d), ("heads", "embed"), pd),
            "norm_kv": ParamSpec((r,), ("rank",), pd, init="zeros"),
        }
        if cfg.mla_q_rank:
            specs["w_dq"] = ParamSpec((d, cfg.mla_q_rank), ("embed", "rank"), pd)
            specs["w_uq"] = ParamSpec(
                (cfg.mla_q_rank, cfg.n_heads * (hd + rd)), ("rank", "heads"), pd
            )
            specs["norm_q"] = ParamSpec(
                (cfg.mla_q_rank,), ("rank",), pd, init="zeros"
            )
        else:
            specs["w_q"] = ParamSpec(
                (d, cfg.n_heads * (hd + rd)), ("embed", "heads"), pd
            )
        return specs
    return {
        "w_q": ParamSpec((d, cfg.n_heads * hd), ("embed", "heads"), pd),
        "w_k": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv"), pd),
        "w_v": ParamSpec((d, cfg.n_kv_heads * hd), ("embed", "kv"), pd),
        "w_o": ParamSpec((cfg.n_heads * hd, d), ("heads", "embed"), pd),
    }


# ---------------------------------------------------------------------------
# chunked (flash-style) causal attention
# ---------------------------------------------------------------------------


def _chunked_attention(
    q: jax.Array,  # [B, S, H, D]
    k: jax.Array,  # [B, S, KV, D]
    v: jax.Array,  # [B, S, KV, D]
    *,
    window: int = 0,  # 0 => full causal; >0 => sliding window
    chunk: int = 1024,
    mesh=None,
) -> jax.Array:
    B, S, H, Dk = q.shape
    KV = k.shape[2]
    Dv = v.shape[-1]
    rep = H // KV
    scale = 1.0 / math.sqrt(Dk)
    chunk = min(chunk, S)
    n_chunks = math.ceil(S / chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, n_chunks, chunk, KV, Dk)
    vc = v.reshape(B, n_chunks, chunk, KV, Dv)
    q_pos = jnp.arange(S)

    qh = (q * scale).reshape(B, S, KV, rep, Dk)

    def body(carry, inputs):
        m, l, o = carry
        kci, vci, ci = inputs
        k_pos = ci * chunk + jnp.arange(chunk)
        # scores: [B, S, KV, rep, chunk]
        s = jnp.einsum(
            "bsgrd,bcgd->bsgrc", qh, kci, preferred_element_type=jnp.float32
        )
        causal = k_pos[None, :] <= q_pos[:, None]
        valid = k_pos[None, :] < S
        mask = causal & valid
        if window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bsgrc,bcgd->bsgrd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, S, KV, rep), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, S, KV, rep), jnp.float32)
    o0 = jnp.zeros((B, S, KV, rep, Dv), jnp.float32)
    if mesh is not None and globals().get("_ANCHOR_CARRY", True):
        m0 = _anchor(m0, mesh, "data", None, "model")
        l0 = _anchor(l0, mesh, "data", None, "model")
        o0 = _anchor(o0, mesh, "data", None, "model")
    (m, l, o), _ = jax.lax.scan(
        body,
        (m0, l0, o0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)),
    )
    out = o / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(B, S, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, p, x, positions):
    B, S, _ = x.shape
    hd = cfg.hd
    if cfg.attn_kind == "mla":
        r, rd = cfg.mla_kv_rank, cfg.mla_rope_dim
        ckv_pe = x @ p["w_dkv"]
        c_kv = rms_norm(ckv_pe[..., :r], p["norm_kv"], cfg.norm_eps)
        k_pe = ckv_pe[..., r:]
        if cfg.mla_q_rank:
            cq = rms_norm(x @ p["w_dq"], p["norm_q"], cfg.norm_eps)
            q_full = (cq @ p["w_uq"]).reshape(B, S, cfg.n_heads, hd + rd)
        else:
            q_full = (x @ p["w_q"]).reshape(B, S, cfg.n_heads, hd + rd)
        q_nope, q_pe = q_full[..., :hd], q_full[..., hd:]
        cos, sin = rope_cos_sin(positions, rd, cfg.rope_theta)
        q_pe = apply_rope(q_pe, cos, sin)
        k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)
        # expand latent to per-head K/V (naive MLA; the absorbed form is a
        # perf optimization recorded in EXPERIMENTS.md)
        k_nope = (c_kv @ p["w_uk"]).reshape(B, S, cfg.n_heads, hd)
        v = (c_kv @ p["w_uv"]).reshape(B, S, cfg.n_heads, hd)
        q = jnp.concatenate([q_nope, q_pe], axis=-1)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_pe, (B, S, cfg.n_heads, rd))], axis=-1)
        return q, k, v, (c_kv, k_pe[:, :, 0, :])
    q = (x @ p["w_q"]).reshape(B, S, cfg.n_heads, hd)
    k = (x @ p["w_k"]).reshape(B, S, cfg.n_kv_heads, hd)
    v = (x @ p["w_v"]).reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.rope_kind == "mrope":
        pos3 = jnp.broadcast_to(positions[None], (3, *positions.shape))
        cos, sin = rope_cos_sin(pos3, hd, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_kind == "rope":
        cos, sin = rope_cos_sin(positions, hd, cfg.rope_theta)
    else:
        cos = sin = None
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v, (k, v)


def attention_apply(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,          # [B, S, d_model]
    positions: jax.Array,  # i32[B, S]
    *,
    is_global: jax.Array | bool = True,
    chunk: int = 1024,
    mesh=None,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Returns (attn_out [B,S,d], cache_entry (k,v) or (c_kv,k_pe))."""
    q, k, v, cache = _project_qkv(cfg, p, x, positions)
    amesh = mesh if cfg.attn_sharding_constraints else None
    if amesh is not None:
        if globals().get("_ANCHOR_Q", True):
            q = _anchor(q, amesh, "data", None, "model")
        k = _anchor(k, amesh, "data", None, "model")
        v = _anchor(v, amesh, "data", None, "model")
    if cfg.attn_kind == "sliding":
        # traced flag: compute both windowed and full, select (keeps the layer
        # scan homogeneous; the unused branch is DCE'd when the flag is static)
        if isinstance(is_global, bool):
            out = _chunked_attention(
                q, k, v, window=0 if is_global else cfg.sliding_window,
                chunk=chunk, mesh=amesh,
            )
        else:
            out_local = _chunked_attention(
                q, k, v, window=cfg.sliding_window, chunk=chunk, mesh=amesh
            )
            out_global = _chunked_attention(q, k, v, window=0, chunk=chunk,
                                            mesh=amesh)
            out = jnp.where(is_global, out_global, out_local)
    else:
        out = _chunked_attention(q, k, v, window=0, chunk=chunk, mesh=amesh)
    B, S = x.shape[:2]
    y = out.reshape(B, S, -1) @ p["w_o"]
    return y, cache


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, layer_idx: int, dtype=None
) -> Dict[str, jax.Array]:
    dtype = dtype or cfg.param_dtype
    if cfg.attn_kind == "mla":
        return {
            "c_kv": jnp.zeros((batch, max_len, cfg.mla_kv_rank), dtype),
            "k_pe": jnp.zeros((batch, max_len, cfg.mla_rope_dim), dtype),
        }
    if cfg.attn_kind == "sliding" and not cfg.is_global_attn(layer_idx):
        max_len = min(max_len, cfg.sliding_window)
    return {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.hd), dtype),
    }


def _cache_write(cache_arr: jax.Array, new: jax.Array, pos: jax.Array) -> jax.Array:
    """Write one token at (ring-buffered) position ``pos``."""
    L = cache_arr.shape[1]
    idx = jnp.mod(pos, L)
    return cache_arr.at[:, idx].set(new.astype(cache_arr.dtype))


def attention_decode(
    cfg: ModelConfig,
    p: Dict[str, jax.Array],
    x: jax.Array,      # [B, 1, d_model]
    cache: Dict[str, jax.Array],
    pos: jax.Array,    # i32[] current position (tokens already in cache)
    *,
    layer_idx: int = 0,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = x.shape[0]
    hd = cfg.hd
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new, extras = _project_qkv(cfg, p, x, positions)
    scale = 1.0 / math.sqrt(q.shape[-1])

    if cfg.attn_kind == "mla":
        c_kv_new, k_pe_new = extras
        cache = {
            "c_kv": _cache_write(cache["c_kv"], c_kv_new[:, 0], pos),
            "k_pe": _cache_write(cache["k_pe"], k_pe_new[:, 0], pos),
        }
        S = cache["c_kv"].shape[1]
        if cfg.mla_absorbed_decode:
            # absorbed MLA (EXPERIMENTS.md §Perf H3): score and attend in
            # LATENT space — w_uk folds into the query, w_uv into the output;
            # the per-token (B,S,H,hd) K/V expansion never materializes.
            r, rd = cfg.mla_kv_rank, cfg.mla_rope_dim
            H = cfg.n_heads
            q_nope, q_pe = q[..., :hd], q[..., hd:]
            w_uk = p["w_uk"].reshape(r, H, hd)
            w_uv = p["w_uv"].reshape(r, H, hd)
            q_abs = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_uk)
            sc = 1.0 / math.sqrt(hd + rd)
            s = (
                jnp.einsum("bqhr,bsr->bhqs", q_abs, cache["c_kv"],
                           preferred_element_type=jnp.float32)
                + jnp.einsum("bqhp,bsp->bhqs", q_pe, cache["k_pe"],
                             preferred_element_type=jnp.float32)
            ) * sc
            valid = jnp.arange(S)[None, :] <= pos
            s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
            w = jax.nn.softmax(s, axis=-1)
            # accumulate the attention output in fp32 (matching the forward
            # path's chunked accumulator) before casting back for w_o
            o_lat = jnp.einsum(
                "bhqs,bsr->bqhr", w.astype(cache["c_kv"].dtype), cache["c_kv"],
                preferred_element_type=jnp.float32,
            )
            o = jnp.einsum(
                "bqhr,rhd->bqhd", o_lat, w_uv,
                preferred_element_type=jnp.float32,
            ).astype(q.dtype)
            y = o.reshape(B, 1, -1) @ p["w_o"]
            return y, cache
        k_nope = (cache["c_kv"] @ p["w_uk"]).reshape(B, S, cfg.n_heads, hd)
        v = (cache["c_kv"] @ p["w_uv"]).reshape(B, S, cfg.n_heads, hd)
        k_pe = jnp.broadcast_to(
            cache["k_pe"][:, :, None, :], (B, S, cfg.n_heads, cfg.mla_rope_dim)
        )
        k = jnp.concatenate([k_nope, k_pe], axis=-1)
        valid = jnp.arange(S)[None, :] <= pos
        win = 0
    else:
        kc = _cache_write(cache["k"], k_new[:, 0], pos)
        vc = _cache_write(cache["v"], v_new[:, 0], pos)
        cache = {"k": kc, "v": vc}
        k, v = kc, vc
        S = k.shape[1]
        is_local = cfg.attn_kind == "sliding" and not cfg.is_global_attn(layer_idx)
        if is_local:
            # ring buffer: every resident slot with slot-age < window is valid
            slot = jnp.arange(S)
            written = jnp.where(pos + 1 < S, slot <= pos, True)
            valid = written[None, :]
        else:
            valid = (jnp.arange(S)[None, :] <= pos)
        win = 0

    rep = q.shape[2] // k.shape[2]
    qh = q.reshape(B, 1, k.shape[2], rep, q.shape[-1]) * scale
    s = jnp.einsum("bqgrd,bsgd->bgrqs", qh, k, preferred_element_type=jnp.float32)
    s = jnp.where(valid[:, None, None, None, :], s, _NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    # fp32 accumulation to mirror _chunked_attention's running fp32 output
    # (the forward path); cast back to the activation dtype before w_o
    o = jnp.einsum(
        "bgrqs,bsgd->bqgrd", w.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    ).astype(q.dtype)
    y = o.reshape(B, 1, -1) @ p["w_o"]
    return y, cache
