"""Gated MLP (SwiGLU / GeGLU) used by every dense block."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import ModelConfig, ParamSpec

__all__ = ["mlp_specs", "mlp_apply"]


def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> Dict[str, ParamSpec]:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    pd = cfg.param_dtype
    return {
        "w_gate": ParamSpec((d, ff), ("embed", "mlp"), pd),
        "w_up": ParamSpec((d, ff), ("embed", "mlp"), pd),
        "w_down": ParamSpec((ff, d), ("mlp", "embed"), pd),
    }


def mlp_apply(cfg: ModelConfig, p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    act = jax.nn.gelu if cfg.mlp_act == "gelu" else jax.nn.silu
    g = act(x @ p["w_gate"])
    return (g * (x @ p["w_up"])) @ p["w_down"]
