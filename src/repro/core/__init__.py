"""Eidola core: multi-device communication-traffic simulation (the paper's
contribution), plus the compiled-HLO capture bridge that makes it a
first-class feature of the training framework."""

from .cluster import Cluster, ClusterNode
from .config import EngineKind, SimConfig, SyncPolicy
from .events import PHASES, RegisteredWrite, Segment, TraceBundle, register_phase
from .interconnect import (
    InterconnectSpec,
    Leg,
    LinkClass,
    RoutingPolicy,
    build_fabric,
    get_fabric,
    list_fabrics,
    register_fabric,
    resolve_fabric,
)
from .memory import AddressMap, DirectoryMemory, TrafficCounters
from .monitor import MonitorEntry, MonitorLog
from .perturb import GaussianPerturb, NullPerturb, PeerDelayPerturb
from .scenario import (
    EmitOp,
    PhaseSpec,
    Scenario,
    SweepPoint,
    SweepRunner,
    TrafficOp,
    WGProgram,
    get_scenario,
    list_scenarios,
    register_scenario,
    simulate,
)
from .simulator import Eidola, Report, run_gemv_allreduce
from .target import EidolaDeadlock, TargetDevice
from .topology import FabricModel, HardwareSpec, Topology
from .workload import GemvAllReduceWorkload, make_gemv_allreduce_traces
from .wtt import WriteTrackingTable

__all__ = [
    "EngineKind", "SimConfig", "SyncPolicy",
    "PHASES", "RegisteredWrite", "Segment", "TraceBundle", "register_phase",
    "AddressMap", "DirectoryMemory", "TrafficCounters",
    "MonitorEntry", "MonitorLog",
    "GaussianPerturb", "NullPerturb", "PeerDelayPerturb",
    "EmitOp", "PhaseSpec", "Scenario", "SweepPoint", "SweepRunner",
    "TrafficOp", "WGProgram", "get_scenario", "list_scenarios",
    "register_scenario", "simulate",
    "Eidola", "Report", "run_gemv_allreduce",
    "EidolaDeadlock", "TargetDevice",
    "Cluster", "ClusterNode",
    "FabricModel", "HardwareSpec", "Topology",
    "InterconnectSpec", "LinkClass", "Leg", "RoutingPolicy",
    "build_fabric", "get_fabric", "list_fabrics", "register_fabric",
    "resolve_fabric",
    "GemvAllReduceWorkload", "make_gemv_allreduce_traces",
    "WriteTrackingTable",
    "verify_scenario",
]


def __getattr__(name):
    # PEP 562 lazy re-export: repro.analysis imports repro.core.cluster, so
    # a top-level import here would be circular
    if name == "verify_scenario":
        from repro.analysis import verify_scenario

        return verify_scenario
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
