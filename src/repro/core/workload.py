"""Fused GEMV+AllReduce workload model (paper Fig. 3) and trace generation.

The GEMV ``y = A @ x`` (A: M x K) is partitioned column-parallel: device ``d``
owns the K-slice ``[d*K/n, (d+1)*K/n)`` and computes a *partial* for every
output row; output rows are partitioned by *owner* (device ``r`` owns rows
``[r*M/n, (r+1)*M/n)``) so each device reduces its own rows after receiving
peer partials.  That is exactly the structure of the fused kernel's phases:

  remote_tiles : partials for rows owned by peers  -> xGMI-written to owners
  flag_write   : flags[my_gpu] <- 1 on every peer
  local_tiles  : partials for rows owned locally   -> local writes
  wait_flags   : spin/monitor until every peer's flag is set locally
  reduce       : sum the n partials for each owned row
  broadcast    : push final rows to all peers

The *detailed* device is always device 0; devices 1..n-1 are eidolons whose
only simulated effect is the registered writes they replay (partials + flags).
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .config import SimConfig
from .events import TraceBundle
from .memory import AddressMap

__all__ = ["WGPlan", "GemvAllReduceWorkload", "make_gemv_allreduce_traces"]


@dataclass(frozen=True)
class WGPlan:
    """Static per-workgroup execution plan (durations in cycles)."""

    wg: int
    cu: int
    dispatch_cycle: int
    n_remote_rows: int
    n_local_rows: int
    remote_cycles: int
    flag_write_cycles: int
    local_cycles: int
    reduce_cycles: int
    broadcast_cycles: int
    # traffic attributable to this WG's closed-form phases
    remote_sector_reads: int
    local_sector_reads: int
    remote_xgmi_writes: int   # partial-tile pushes to peers
    local_partial_writes: int
    reduce_reads: int         # peer-partial reads during reduction
    broadcast_xgmi_writes: int
    broadcast_local_writes: int


class GemvAllReduceWorkload:
    """Builds per-WG plans + peer traces for the fused GEMV+AllReduce kernel."""

    def __init__(self, cfg: SimConfig, amap: Optional[AddressMap] = None):
        cfg.validate()
        self.cfg = cfg
        self.amap = amap or AddressMap(n_devices=cfg.n_devices)
        self.plans: List[WGPlan] = self._build_plans()

    # ------------------------------------------------------------------
    # row -> workgroup assignment
    # ------------------------------------------------------------------

    def _row_counts(self) -> Tuple[List[int], List[int]]:
        """Per-WG counts of (remote, local) rows, round-robin assigned."""
        cfg = self.cfg
        n_remote = cfg.M - cfg.rows_per_device
        n_local = cfg.rows_per_device
        remote = [0] * cfg.workgroups
        local = [0] * cfg.workgroups
        for i in range(n_remote):
            remote[i % cfg.workgroups] += 1
        for i in range(n_local):
            local[i % cfg.workgroups] += 1
        return remote, local

    def _build_plans(self) -> List[WGPlan]:
        cfg = self.cfg
        remote_rows, local_rows = self._row_counts()
        n_peers = cfg.n_egpus
        plans: List[WGPlan] = []
        for wg in range(cfg.workgroups):
            cu = wg % cfg.n_cus
            wave = wg // cfg.n_cus
            rr, lr = remote_rows[wg], local_rows[wg]
            plans.append(
                WGPlan(
                    wg=wg,
                    cu=cu,
                    dispatch_cycle=wave * cfg.dispatch_stagger_cycles,
                    n_remote_rows=rr,
                    n_local_rows=lr,
                    remote_cycles=rr * cfg.row_cycles,
                    flag_write_cycles=n_peers * cfg.flag_write_cycles,
                    local_cycles=lr * cfg.row_cycles,
                    reduce_cycles=lr * cfg.reduce_cycles_per_row,
                    broadcast_cycles=lr * cfg.broadcast_cycles_per_row,
                    remote_sector_reads=rr * cfg.sectors_per_row,
                    local_sector_reads=lr * cfg.sectors_per_row,
                    remote_xgmi_writes=rr,  # one partial push per remote row
                    local_partial_writes=lr,
                    # reduce reads the n_devices partials of each owned row;
                    # partials for one row fit in <= one sector each read burst
                    reduce_reads=lr * cfg.n_devices,
                    broadcast_xgmi_writes=lr * n_peers,
                    broadcast_local_writes=lr,
                )
            )
        return plans

    # ------------------------------------------------------------------
    # aggregate expectations (used by tests and the vector engine)
    # ------------------------------------------------------------------

    def expected_nonflag_reads(self) -> int:
        """Closed-form non-flag read count (matrix sectors + reduce reads).

        With Table-1 parameters this evaluates to 65,536 matrix sector reads
        + 256 reduce reads = 65,792 ~ the paper's "approximately 66K".
        """
        cfg = self.cfg
        matrix = cfg.M * cfg.sectors_per_row
        reduce = cfg.rows_per_device * cfg.n_devices
        return matrix + reduce

    def flag_order(self) -> List[int]:
        """Peer polling order (paper Fig. 3 line 14: ascending rgpu)."""
        return list(range(1, self.cfg.n_devices))

    # ------------------------------------------------------------------
    # eidolon trace generation
    # ------------------------------------------------------------------

    def make_traces(
        self,
        flag_delays_ns: Sequence[float] | float,
    ) -> TraceBundle:
        return make_gemv_allreduce_traces(self.cfg, flag_delays_ns, self.amap)


def make_gemv_allreduce_traces(
    cfg: SimConfig,
    flag_delays_ns: Sequence[float] | float,
    amap: Optional[AddressMap] = None,
) -> TraceBundle:
    """Registered-write trace for the eidolons of a fused GEMV+AllReduce launch.

    ``flag_delays_ns`` gives, per eidolon, the wakeupTime of its flag write
    relative to main-kernel launch (the paper's swept parameter).  A scalar
    applies the same delay to every eidolon.  When
    ``cfg.include_data_writes`` each eidolon also pushes its partial tiles for
    the target-owned rows shortly before its flag (the kernel writes data, then
    the flag) — those land in the partial region and are counted as incoming
    xGMI traffic but never as flag traffic.
    """
    amap = amap or AddressMap(n_devices=cfg.n_devices)
    if isinstance(flag_delays_ns, (int, float)):
        delays = [float(flag_delays_ns)] * cfg.n_egpus
    else:
        delays = [float(d) for d in flag_delays_ns]
        if len(delays) != cfg.n_egpus:
            raise ValueError(
                f"need {cfg.n_egpus} delays, got {len(delays)}"
            )

    bundle = TraceBundle(
        meta={
            "workload": "fused_gemv_allreduce",
            "M": cfg.M,
            "K": cfg.K,
            "N": cfg.N,
            "n_devices": cfg.n_devices,
            "flag_delays_ns": delays,
        }
    )
    rows_for_target = cfg.rows_per_device
    for g in range(1, cfg.n_devices):
        delay = delays[g - 1]
        if cfg.include_data_writes:
            # Partial tiles for the target's owned rows: one write per row.
            # They are spread across a short window ending data_write_lead_ns
            # before the flag (clamped at 0) — data must precede the flag.
            lead = cfg.data_write_lead_ns
            t0 = max(0.0, delay - lead)
            span = max(1.0, lead * 0.5)
            for r in range(rows_for_target):
                t = min(t0 + span * (r + 1) / rows_for_target, max(0.0, delay))
                bundle.add(
                    wakeup_ns=t,
                    addr=amap.partial_base
                    + (g * rows_for_target + r) * cfg.elem_bytes * cfg.N,
                    data=0xA0 + g,
                    size=min(8, cfg.elem_bytes * cfg.N),
                    src=g,
                )
        bundle.add(
            wakeup_ns=delay,
            addr=amap.flag_addr(g),
            data=1,
            size=8,
            src=g,
        )
    return bundle
