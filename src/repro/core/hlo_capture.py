"""Compiled-HLO capture: the bridge from the JAX framework to Eidola.

The paper's workflow (Fig. 4) starts from *profiles of real applications*.
Our framework's analogue of a profile is the compiled artifact of the
multi-pod dry-run: the post-SPMD HLO text contains every collective the step
will execute, with exact per-device operand shapes.  This module parses those
collectives, computes the roofline collective bytes, and lowers the schedule
into an Eidola :class:`TraceBundle` — each collective's ring steps become
timestamped semaphore (flag) writes that eidolon peers replay, exactly like
the paper's ``register_write`` setup kernel.

Parsing is deliberately tolerant: it supports post-SPMD HLO text (what
``compiled.as_text()`` emits, e.g. ``%all-reduce.2 = f32[8,128]{1,0}
all-reduce(%dot), replica_groups=[2,4]<=[8]``), including async
``-start/-done`` forms, and StableHLO MLIR from ``lowered.as_text()``.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .events import TraceBundle
from .memory import AddressMap
from .topology import Topology

__all__ = [
    "CollectiveOp",
    "parse_collectives",
    "collective_bytes",
    "schedule_to_trace",
    "summarize",
]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_KINDS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.  f32[8,128]{1,0}   bf16[]   s32[4]{0}
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]*(?:e[0-9a-z]+)?)\[([0-9,]*)\]")
# e.g.  replica_groups=[2,4]<=[8]   replica_groups={{0,1},{2,3}}
_IOTA_RG_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_BRACE_RG_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
# HLO op line:  %name = TYPE kind(...)  or  %name = (T1, T2) kind-start(...)
_HLO_OP_RE = re.compile(
    r"=\s+(\(?[a-z0-9\[\]{},() ]+?\)?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
# StableHLO MLIR:  stablehlo.all_reduce ... : tensor<16x64xbf16>
_MLIR_OP_RE = re.compile(
    r"(?:stablehlo|mhlo)\.(all_reduce|all_gather|reduce_scatter|all_to_all|"
    r"collective_permute)"
)
_MLIR_TENSOR_RE = re.compile(r"tensor<([0-9x]*)x?([a-z]+[0-9]*)>")


@dataclass(frozen=True)
class CollectiveOp:
    kind: str                 # one of _KINDS
    result_bytes: int         # per-device result size
    operand_bytes: int        # per-device operand size (roofline numerator)
    group_size: int           # participants per replica group (1 if unknown)
    dtype: str = ""
    line: str = ""

    @property
    def is_cross_device(self) -> bool:
        return self.group_size != 1


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * nb


def _first_tensor_bytes(type_str: str) -> Tuple[int, str]:
    """Bytes of the first (largest, for tuples) tensor in an HLO type string."""
    best, dt = 0, ""
    for m in _SHAPE_RE.finditer(type_str):
        b = _shape_bytes(m.group(1), m.group(2))
        if b > best:
            best, dt = b, m.group(1)
    return best, dt


def parse_collectives(text: str) -> List[CollectiveOp]:
    """Extract collective ops (with per-device sizes) from HLO/StableHLO text."""
    ops: List[CollectiveOp] = []
    for raw in text.splitlines():
        line = raw.strip()
        m = _HLO_OP_RE.search(line)
        if m:
            kind = m.group(2)
            is_start = bool(m.group(3))
            if line.find(f"{kind}-done") != -1 and not is_start:
                continue  # -done carries no new traffic
            rbytes, dtype = _first_tensor_bytes(m.group(1))
            gsize = 1
            gm = _IOTA_RG_RE.search(line)
            if gm:
                gsize = int(gm.group(2))
            else:
                bm = _BRACE_RG_RE.search(line)
                if bm:
                    gsize = len([x for x in bm.group(1).split(",") if x.strip()])
            ops.append(
                CollectiveOp(
                    kind=kind,
                    result_bytes=rbytes,
                    operand_bytes=_operand_bytes(kind, rbytes, gsize),
                    group_size=gsize,
                    dtype=dtype,
                    line=line[:240],
                )
            )
            continue
        m = _MLIR_OP_RE.search(line)
        if m:
            kind = m.group(1).replace("_", "-")
            tensors = _MLIR_TENSOR_RE.findall(line)
            rbytes, dtype = 0, ""
            if tensors:
                dims, dt = tensors[-1]
                n = 1
                for d in dims.split("x"):
                    if d:
                        n *= int(d)
                rbytes = n * _DTYPE_BYTES.get(dt, 0)
                dtype = dt
            ops.append(
                CollectiveOp(
                    kind=kind,
                    result_bytes=rbytes,
                    operand_bytes=rbytes,
                    group_size=0,  # unknown at StableHLO level
                    dtype=dtype,
                    line=line[:240],
                )
            )
    return ops


def _operand_bytes(kind: str, result_bytes: int, group_size: int) -> int:
    """Per-device operand size implied by the result size."""
    g = max(1, group_size)
    if kind == "all-gather":
        return result_bytes // g
    if kind == "reduce-scatter":
        return result_bytes * g
    return result_bytes


def collective_bytes(ops: Sequence[CollectiveOp]) -> int:
    """Roofline numerator: sum of per-device operand sizes of cross-device
    collectives (group_size 1 ops move no bytes)."""
    return sum(o.operand_bytes for o in ops if o.group_size != 1)


def by_kind(ops: Sequence[CollectiveOp]) -> Dict[str, Tuple[int, int]]:
    out: Dict[str, Tuple[int, int]] = {}
    for o in ops:
        c, b = out.get(o.kind, (0, 0))
        out[o.kind] = (c + 1, b + o.operand_bytes)
    return out


def summarize(ops: Sequence[CollectiveOp]) -> str:
    rows = [f"{k}: n={c} bytes={b:,}" for k, (c, b) in sorted(by_kind(ops).items())]
    rows.append(f"TOTAL collective bytes (operand sum): {collective_bytes(ops):,}")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# schedule -> Eidola trace
# ---------------------------------------------------------------------------


def schedule_to_trace(
    ops: Sequence[CollectiveOp],
    topo: Topology,
    *,
    axis_for_group: Optional[Dict[int, str]] = None,
    compute_gap_ns: float = 0.0,
    n_egpu_peers: int = 3,
) -> TraceBundle:
    """Lower a collective schedule into eidolon semaphore-write traces.

    Each collective contributes its ring-step completion times; step ``i``'s
    completion is one 8-byte flag write from peer ``1 + i % n_egpu_peers``.
    ``compute_gap_ns`` inserts the compute time between consecutive
    collectives (from cost_analysis FLOPs / peak, supplied by the caller).
    The result replays at cycle fidelity in the standard Eidola engines,
    closing the loop between the production framework and the simulator.
    """
    amap = AddressMap(n_devices=n_egpu_peers + 1)
    bundle = TraceBundle(
        meta={
            "pattern": "hlo_capture",
            "n_collectives": len(ops),
            "topology": topo.describe(),
        }
    )
    t_ns = 0.0
    axis_for_group = axis_for_group or {}
    default_axis = topo.axis_names[-1]
    for i, op in enumerate(ops):
        if op.group_size == 1:
            continue
        axis = axis_for_group.get(op.group_size, default_axis)
        # fall back to the axis whose size matches the replica group
        for name, size in zip(topo.axis_names, topo.axis_sizes):
            if size == op.group_size:
                axis = name
                break
        cost = topo.collective(op.kind, op.operand_bytes, axis)
        t_ns += compute_gap_ns
        for j, arr_s in enumerate(cost.arrival_times_s(t_ns * 1e-9)):
            src = 1 + (j % n_egpu_peers)
            bundle.add(
                wakeup_ns=arr_s * 1e9,
                addr=amap.partial_base + 64 * ((i * 64 + j) % 65536),
                data=j,
                size=8,
                src=src,
            )
        t_ns = cost.arrival_times_s(t_ns * 1e-9)[-1] * 1e9
        # final completion: the collective's semaphore flag
        bundle.add(
            wakeup_ns=t_ns,
            addr=amap.flag_addr(1 + (i % n_egpu_peers)),
            data=1,
            size=8,
            src=1 + (i % n_egpu_peers),
        )
    # end-of-step barrier: every peer signals its flag so any waiting
    # workload (the GEMV+AllReduce wait loop included) can terminate
    for g in range(1, n_egpu_peers + 1):
        bundle.add(
            wakeup_ns=t_ns, addr=amap.flag_addr(g), data=1, size=8, src=g
        )
    return bundle
