"""Detailed target-device model: a per-workgroup phase-program interpreter.

The paper simulates exactly one device in detailed timing mode; its figures
measure (a) per-workgroup phase timelines (Figs. 1/2) and (b) memory-read
traffic split into flag vs. non-flag categories (Figs. 6/9).  This module
models the target at that granularity, but — unlike the seed's hardcoded
remote -> flag -> local -> wait -> reduce -> broadcast machine — it interprets
*phase programs as data* (:class:`repro.core.scenario.WGProgram`): each
workgroup advances through an ordered list of timed phases (closed-form
traffic accounted at completion) and wait phases.  A wait phase observes a
sequence of flag addresses under one of two synchronization policies:

* ``SPIN``    — sequential per-address polling loop; one flag read per poll
                tick while the current flag is unset, one observe read once
                set.
* ``SYNCMON`` — check once; if unset, arm a Monitor Log entry and mwait
                (descheduled, zero reads while waiting); on wake, a validation
                read that may coalesce with other wavefronts woken in the same
                cycle on the same CU (the fill triggered by the waking write
                serves adjacent waiters).

Any scenario therefore inherits the full synchronization model: ring
all-reduce steps, all-to-all incast barriers, and pipeline microbatch
hand-offs wait exactly the way the fused kernel's wait_flags phase does.

The model is engine-agnostic: cycle-poll and event-queue engines drive the
same transitions and therefore produce bit-identical traffic and timelines.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .config import SimConfig, SyncPolicy
from .events import RegisteredWrite, Segment
from .memory import DirectoryMemory
from .monitor import MonitorLog
from .scenario import PhaseSpec, Scenario, WGProgram

__all__ = ["TargetDevice", "EidolaDeadlock"]


class EidolaDeadlock(RuntimeError):
    """Raised when all workgroups are blocked and no pending writes remain."""


@dataclass
class _WG:
    program: WGProgram
    phase_idx: int = -1           # -1 = not yet dispatched
    phase_start: int = 0          # cycle the current phase began
    done: bool = False
    # wait-phase bookkeeping
    in_wait: bool = False
    flag_idx: int = 0
    t_cursor: int = 0             # next poll/check tick (cycles)
    blocked_on: Optional[int] = None   # flag address we spin/mwait on
    in_mwait: bool = False
    t_arm: int = 0                # cycle the current monitor was armed
    wait_start: int = 0
    segments: List[Segment] = field(default_factory=list)
    desched_segments: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def current(self) -> Optional[PhaseSpec]:
        if 0 <= self.phase_idx < len(self.program.phases):
            return self.program.phases[self.phase_idx]
        return None


class TargetDevice:
    """One detailed device of an Eidola simulation.

    In the classic open-loop configuration this is the single device 0; in a
    closed-loop :class:`repro.core.cluster.Cluster` every device is one of
    these, each with its own ``device_id``, :class:`DirectoryMemory`,
    :class:`MonitorLog`, and Write Tracking Table.  ``emit_sink`` (set by the
    cluster) receives phase-completion :class:`repro.core.scenario.EmitOp`
    notifications; without a sink, emits are inert (open-loop degenerate
    case).

    ``scenario`` provides the phase programs via ``programs_for(device_id)``;
    for back-compat a :class:`repro.core.workload.GemvAllReduceWorkload` is
    also accepted and wrapped in the registered ``gemv_allreduce`` scenario.
    """

    def __init__(
        self,
        cfg: SimConfig,
        scenario,
        memory: DirectoryMemory,
        monitor_log: Optional[MonitorLog] = None,
        perturb=None,
        *,
        device_id: int = 0,
        emit_sink: Optional[Callable[[int, int, int, "PhaseSpec", int], None]] = None,
    ):
        if not isinstance(scenario, Scenario):
            from .scenarios.gemv_allreduce import GemvAllReduceScenario

            scenario = GemvAllReduceScenario.from_workload(cfg, scenario)
        self.cfg = cfg
        self.scenario = scenario
        self.amap = scenario.amap
        self.memory = memory
        self.monitor_log = monitor_log
        if cfg.sync == SyncPolicy.SYNCMON and monitor_log is None:
            raise ValueError("SYNCMON policy requires a MonitorLog")
        self.perturb = perturb
        self.device_id = int(device_id)
        self.emit_sink = emit_sink

        programs = sorted(scenario.programs_for(self.device_id), key=lambda p: p.wg)
        if [p.wg for p in programs] != list(range(len(programs))):
            raise ValueError("WGProgram ids must be contiguous from 0")
        self.wgs = [_WG(program=p) for p in programs]

        # every flag address some program may wait on
        self._watched: Set[int] = set()
        for p in programs:
            self._watched.update(p.wait_addresses())
        self.flag_set_cycle: Dict[int, int] = {}
        # spin mode: flag addr -> set of blocked wg ids
        self._spin_waiters: Dict[int, Set[int]] = {}
        # syncmon: wg -> monitor entry currently armed
        self._armed: Dict[int, object] = {}

        # transition list managed by the engine via (cycle, wg) pairs
        self._ready: List[Tuple[int, int]] = []
        for p in programs:
            self._push(p.dispatch_cycle, p.wg)
        self.done_count = 0
        self.kernel_end_cycle = 0

    # ------------------------------------------------------------------
    # transition queue (a tiny heap the engines drain)
    # ------------------------------------------------------------------

    def _push(self, cycle: int, wg_id: int) -> None:
        heapq.heappush(self._ready, (int(cycle), wg_id))

    def next_transition_cycle(self) -> Optional[int]:
        return self._ready[0][0] if self._ready else None

    def process_until(self, cycle: int) -> None:
        """Fire all transitions scheduled at or before ``cycle``."""
        while self._ready and self._ready[0][0] <= cycle:
            t, wg_id = heapq.heappop(self._ready)
            self._advance(self.wgs[wg_id], t)

    @property
    def all_done(self) -> bool:
        return self.done_count == len(self.wgs)

    def blocked_count(self) -> int:
        return sum(1 for w in self.wgs if w.in_wait and w.blocked_on is not None)

    def blocked_waits(self) -> Dict[int, List[int]]:
        """Unsatisfied flag address -> sorted blocked workgroup ids.

        Deadlock diagnostics: these are the flags no pending write will ever
        set (decode them with ``self.amap.decode_flag``).
        """
        out: Dict[int, List[int]] = {}
        for w in self.wgs:
            if w.in_wait and w.blocked_on is not None:
                out.setdefault(w.blocked_on, []).append(w.program.wg)
        return {addr: sorted(wgs) for addr, wgs in out.items()}

    # ------------------------------------------------------------------
    # phase durations (perturbable)
    # ------------------------------------------------------------------

    def _dur(self, wg: _WG, spec: PhaseSpec) -> int:
        base = spec.duration_cycles
        if self.perturb is not None and base > 0:
            base = self.perturb.scale_phase(wg.program.wg, spec.name, base)
        return base

    # ------------------------------------------------------------------
    # phase completion accounting
    # ------------------------------------------------------------------

    def _complete_phase(self, wg: _WG, spec: PhaseSpec, start: int, end: int) -> None:
        ns = self.cfg.cycles_to_ns
        # timed phases always get a timeline segment (even zero-length, as the
        # seed's state machine did); wait phases only when time actually passed
        if end > start or not spec.is_wait:
            wg.segments.append(
                Segment(
                    wg=wg.program.wg,
                    phase=spec.name,
                    start_ns=ns(start),
                    end_ns=ns(end),
                    device=self.device_id,
                )
            )
        for op in spec.traffic:
            op.apply(self.memory)
        if spec.emits and self.emit_sink is not None:
            self.emit_sink(self.device_id, wg.program.wg, wg.phase_idx, spec, end)

    # ------------------------------------------------------------------
    # the program interpreter
    # ------------------------------------------------------------------

    def _advance(self, wg: _WG, now: int) -> None:
        if wg.done:
            return
        if wg.in_wait:
            self._run_wait(wg, now)
            return
        # completing the current timed phase (if dispatched)
        spec = wg.current
        if spec is not None:
            self._complete_phase(wg, spec, wg.phase_start, now)
        self._enter_next_phase(wg, now)

    def _enter_next_phase(self, wg: _WG, now: int) -> None:
        wg.phase_idx += 1
        wg.phase_start = now
        spec = wg.current
        if spec is None:
            self._finish(wg, now)
            return
        if spec.is_wait:
            wg.in_wait = True
            wg.flag_idx = 0
            wg.t_cursor = now
            wg.wait_start = now
            self._run_wait(wg, now)
        else:
            self._push(now + self._dur(wg, spec), wg.program.wg)

    def _finish(self, wg: _WG, now: int) -> None:
        wg.done = True
        self.done_count += 1
        self.kernel_end_cycle = max(self.kernel_end_cycle, now)

    # ------------------------------------------------------------------
    # WAIT phase: spin / syncmon
    # ------------------------------------------------------------------

    def _run_wait(self, wg: _WG, now: int) -> None:
        cfg = self.cfg
        spec = wg.current
        assert spec is not None and spec.wait_addrs is not None
        addrs = spec.wait_addrs
        wg.blocked_on = None
        while wg.flag_idx < len(addrs):
            addr = addrs[wg.flag_idx]
            set_c = self.flag_set_cycle.get(addr)
            if set_c is not None and set_c <= wg.t_cursor:
                # observe-and-advance: a single read sees the flag set
                self.memory.bulk_reads(1, bytes_each=8, flag=True)
                wg.t_cursor += cfg.flag_check_cycles
                wg.flag_idx += 1
                continue
            if cfg.sync == SyncPolicy.SPIN:
                if set_c is not None:
                    # flag will be visible at set_c > t_cursor: poll until then
                    nticks = math.ceil(
                        (set_c - wg.t_cursor) / cfg.poll_interval_cycles
                    )
                    self.memory.bulk_reads(nticks + 1, bytes_each=8, flag=True)
                    wg.t_cursor += (
                        nticks * cfg.poll_interval_cycles + cfg.flag_check_cycles
                    )
                    wg.flag_idx += 1
                    continue
                # unset with unknown set time: block until notify
                wg.blocked_on = addr
                self._spin_waiters.setdefault(addr, set()).add(wg.program.wg)
                return
            else:  # SYNCMON
                # one check read (sees unset or not-yet-visible)
                self.memory.bulk_reads(1, bytes_each=8, flag=True)
                t_arm = wg.t_cursor + cfg.monitor_arm_cycles
                if set_c is not None and set_c <= t_arm:
                    # race window: write landed between check and mwait; the
                    # mwait returns immediately after its own validation read
                    self.memory.bulk_reads(1, bytes_each=8, flag=True)
                    if self.monitor_log is not None:
                        self.monitor_log.stats["immediate_mwait_returns"] += 1
                    wg.t_cursor = t_arm + cfg.flag_check_cycles
                    wg.flag_idx += 1
                    continue
                # arm + deschedule
                entry = self.monitor_log.monitor(addr, 8, 1)
                entry.waiting_wfs.add(wg.program.wg)
                self._armed[wg.program.wg] = entry
                wg.blocked_on = addr
                wg.in_mwait = True
                wg.t_arm = t_arm
                wg.desched_segments.append((t_arm, -1))  # end filled on wake
                return
        # all flags observed — wait phase completes at the poll cursor
        end = wg.t_cursor
        self._complete_phase(wg, spec, wg.wait_start, end)
        wg.in_wait = False
        self._enter_next_phase(wg, end)

    # ------------------------------------------------------------------
    # peer-write enactment hooks (called by the engines)
    # ------------------------------------------------------------------

    def on_writes_enacted(self, writes: List[RegisteredWrite], cycle: int) -> None:
        """Process a batch of WTT writes that were enacted at ``cycle``.

        The DirectoryMemory has already applied them (and fired Monitor Log
        observers).  Here we resolve flag visibility for blocked workgroups.
        """
        cfg = self.cfg
        for w in writes:
            if w.addr not in self._watched:
                continue
            if w.addr not in self.flag_set_cycle:
                self.flag_set_cycle[w.addr] = cycle
            if cfg.sync == SyncPolicy.SPIN:
                waiters = self._spin_waiters.pop(w.addr, set())
                for wg_id in sorted(waiters):
                    wg = self.wgs[wg_id]
                    # account the polls from t_cursor up to the observation tick
                    nticks = math.ceil(
                        max(0, cycle - wg.t_cursor) / cfg.poll_interval_cycles
                    )
                    self.memory.bulk_reads(nticks + 1, bytes_each=8, flag=True)
                    wg.t_cursor += (
                        nticks * cfg.poll_interval_cycles + cfg.flag_check_cycles
                    )
                    wg.flag_idx += 1
                    wg.blocked_on = None
                    self._push(wg.t_cursor, wg_id)
        if cfg.sync == SyncPolicy.SYNCMON and self.monitor_log is not None:
            pending = self.monitor_log.pop_wakes_until(
                cycle + cfg.wake_latency_cycles
            )
            # group simultaneous wakes by (wake_cycle, cu) for the coalesced
            # validation read accounting
            groups: Dict[Tuple[int, int], List[int]] = {}
            for wg_id, wake_c in pending:
                wg = self.wgs[wg_id]
                if not wg.in_mwait:
                    continue
                if cycle <= wg.t_arm:
                    # race window: the write landed between the check read and
                    # the monitor arming; the mwait returns immediately after
                    # its own (uncoalesced) validation read at arm time
                    self.memory.bulk_reads(1, bytes_each=8, flag=True)
                    wg.in_mwait = False
                    self._armed.pop(wg_id, None)
                    if wg.desched_segments and wg.desched_segments[-1][1] == -1:
                        wg.desched_segments.pop()  # never actually descheduled
                    if self.monitor_log is not None:
                        self.monitor_log.stats["immediate_mwait_returns"] += 1
                    wg.blocked_on = None
                    wg.flag_idx += 1
                    wg.t_cursor = wg.t_arm + cfg.flag_check_cycles
                    self._push(wg.t_cursor, wg_id)
                    continue
                groups.setdefault((wake_c, wg.program.cu), []).append(wg_id)
            for (wake_c, _cu), members in sorted(groups.items()):
                n_reads = math.ceil(len(members) / max(1, cfg.wake_coalesce_width))
                self.memory.bulk_reads(n_reads, bytes_each=8, flag=True)
                for wg_id in members:
                    wg = self.wgs[wg_id]
                    wg.in_mwait = False
                    self._armed.pop(wg_id, None)
                    # close the descheduled segment
                    if wg.desched_segments and wg.desched_segments[-1][1] == -1:
                        st = wg.desched_segments[-1][0]
                        wg.desched_segments[-1] = (st, wake_c)
                    jitter = wg.program.wg % max(1, cfg.requeue_jitter_mod)
                    resume = wake_c + jitter
                    # the coalesced validation read observed the blocking flag;
                    # if it is (now) set, advance past it without another read
                    addr = wg.blocked_on
                    set_c = self.flag_set_cycle.get(addr)
                    if set_c is not None and set_c <= resume:
                        wg.flag_idx += 1
                    wg.blocked_on = None
                    wg.t_cursor = resume + cfg.flag_check_cycles
                    self._push(wg.t_cursor, wg.program.wg)

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------

    def collect_segments(self) -> List[Segment]:
        segs: List[Segment] = []
        ns = self.cfg.cycles_to_ns
        for wg in self.wgs:
            segs.extend(wg.segments)
            for st, en in wg.desched_segments:
                if en >= st >= 0:
                    segs.append(
                        Segment(
                            wg=wg.program.wg,
                            phase="descheduled",
                            start_ns=ns(st),
                            end_ns=ns(en),
                            device=self.device_id,
                        )
                    )
        return sorted(segs, key=lambda s: (s.wg, s.start_ns))
