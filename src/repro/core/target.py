"""Detailed target-device model: a cohort-batched phase-program interpreter.

The paper simulates exactly one device in detailed timing mode; its figures
measure (a) per-workgroup phase timelines (Figs. 1/2) and (b) memory-read
traffic split into flag vs. non-flag categories (Figs. 6/9).  This module
models the target at that granularity, but — unlike the seed's hardcoded
remote -> flag -> local -> wait -> reduce -> broadcast machine — it interprets
*phase programs as data* (:class:`repro.core.scenario.WGProgram`): each
workgroup advances through an ordered list of timed phases (closed-form
traffic accounted at completion) and wait phases.  A wait phase observes a
sequence of flag addresses under one of two synchronization policies:

* ``SPIN``    — sequential per-address polling loop; one flag read per poll
                tick while the current flag is unset, one observe read once
                set.
* ``SYNCMON`` — check once; if unset, arm a Monitor Log entry and mwait
                (descheduled, zero reads while waiting); on wake, a validation
                read that may coalesce with other wavefronts woken in the same
                cycle on the same CU (the fill triggered by the waking write
                serves adjacent waiters).

Cohorts
-------
Under SPIN with no perturbation, every workgroup of one dispatch wave runs the
same program from the same start cycle and observes the same flag-visibility
times, so their interpreter states are *identical forever* — the per-workgroup
transition loop redundantly recomputes the same advance ``n_cus`` times per
wave.  The interpreter therefore advances **counted cohorts**: maximal runs of
consecutive workgroups sharing (dispatch cycle, phase program).  One transition
advances the whole cohort; traffic is accounted in closed form (each bulk
counter multiplied by the member count — exactly how ``vector_engine.py``
already scores spin waits across all workgroups at once), and timeline segments
are stored once per cohort and stamped per member only at collection time.
Under SyncMon the only member-keyed *state* is the deterministic requeue
jitter (``wg % requeue_jitter_mod``), so cohorts split by jitter class —
workgroups sharing (dispatch cycle, phase program, jitter class) advance as
one counted unit even when their ids interleave.  The CU (``wg % n_cus`` in
every built-in scenario) never diverges member state; it only shapes the
coalesced validation-read *accounting* on wake, which is scored from the
cohort's per-member CU list, grouped across cohorts exactly as the
per-workgroup interpreter groups individual workgroups.  A perturbation
(keyed by wg id) still forces singleton cohorts, which is bit-for-bit the old
per-workgroup interpreter.

The model is engine-agnostic: cycle-poll and event-queue engines drive the
same transitions and therefore produce bit-identical traffic and timelines.
"""

from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set, Tuple

from .config import SimConfig, SyncPolicy
from .events import RegisteredWrite, Segment
from .memory import DirectoryMemory
from .monitor import MonitorLog
from .scenario import PhaseSpec, Scenario, WGProgram, as_symbolic

__all__ = ["TargetDevice", "EidolaDeadlock"]


class _WatchSet:
    """Flag addresses some program may wait on, as literals + arithmetic runs.

    Symbolic programs summarize their wait addresses as ``(start, stride,
    count)`` runs in O(#segments) (:meth:`SymbolicProgram.wait_runs`), so the
    watch set never materializes O(steps) addresses; membership stays O(1) in
    the literal set plus O(#runs) run checks (a handful per program shape).
    """

    __slots__ = ("literal", "runs")

    def __init__(self) -> None:
        self.literal: Set[int] = set()
        self.runs: Set[Tuple[int, int, int]] = set()

    def add_program(self, phases) -> None:
        sp = as_symbolic(phases)
        if sp is not None:
            lits, runs = sp.wait_runs()
            self.literal.update(lits)
            self.runs.update(runs)
            return
        for ph in phases:
            if ph.wait_addrs:
                self.literal.update(ph.wait_addrs)

    def __contains__(self, addr: int) -> bool:
        if addr in self.literal:
            return True
        for start, stride, count in self.runs:
            off = addr - start
            if stride:
                if off >= 0 and off % stride == 0 and off // stride < count:
                    return True
            elif off == 0:
                return True
        return False


class EidolaDeadlock(RuntimeError):
    """Raised when all workgroups are blocked and no pending writes remain.

    ``diagnosis`` carries the static analyzer's explanation of the wait-for
    cycle (blame chains from :func:`repro.analysis.diagnose_deadlock`) when
    one could be computed; it is appended to the message.
    """

    def __init__(self, message: str, *, diagnosis: "str | None" = None):
        self.diagnosis = diagnosis
        if diagnosis:
            message = f"{message}\n{diagnosis}"
        super().__init__(message)


@dataclass
class _Cohort:
    """A maximal run of consecutive workgroups in identical interpreter state.

    ``program`` is the first member's :class:`WGProgram`; all members share its
    ``phases`` and ``dispatch_cycle`` (singleton cohorts additionally make
    ``program.wg``/``program.cu`` exact).  Segments are stored as
    ``(phase, start_cycle, end_cycle)`` tuples shared by every member and
    expanded per workgroup only in :meth:`TargetDevice.collect_segments`.
    """

    program: WGProgram
    members: Tuple[int, ...]      # wg ids sharing this state (consecutive
                                  # under SPIN; same jitter class under
                                  # SyncMon, where they may interleave)
    idx: int = 0                  # position in TargetDevice.cohorts
    count: int = 1                # len(members), denormalized for the hot path
    member_cus: Tuple[int, ...] = ()    # per-member CU (SyncMon wake
                                        # coalescing accounts reads per CU)
    phases: Tuple[PhaseSpec, ...] = ()  # program.phases, denormalized
    phase_idx: int = -1           # -1 = not yet dispatched
    phase_start: int = 0          # cycle the current phase began
    done: bool = False
    # wait-phase bookkeeping
    in_wait: bool = False
    flag_idx: int = 0
    t_cursor: int = 0             # next poll/check tick (cycles)
    blocked_on: Optional[int] = None   # flag address we spin/mwait on
    in_mwait: bool = False
    t_arm: int = 0                # cycle the current monitor was armed
    wait_start: int = 0
    segments: List[Tuple[str, int, int]] = field(default_factory=list)
    desched_segments: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def current(self) -> Optional[PhaseSpec]:
        if 0 <= self.phase_idx < len(self.phases):
            return self.phases[self.phase_idx]
        return None


class TargetDevice:
    """One detailed device of an Eidola simulation.

    In the classic open-loop configuration this is the single device 0; in a
    closed-loop :class:`repro.core.cluster.Cluster` every device is one of
    these, each with its own ``device_id``, :class:`DirectoryMemory`,
    :class:`MonitorLog`, and Write Tracking Table.  ``emit_sink`` (set by the
    cluster) receives phase-completion :class:`repro.core.scenario.EmitOp`
    notifications — called once per cohort with the member ``count`` so the
    sink can replay per-workgroup semantics in closed form; without a sink,
    emits are inert (open-loop degenerate case).

    ``scenario`` provides the phase programs via ``programs_for(device_id)``;
    for back-compat a :class:`repro.core.workload.GemvAllReduceWorkload` is
    also accepted and wrapped in the registered ``gemv_allreduce`` scenario.

    ``cohorts=False`` forces singleton cohorts (the pre-batching per-workgroup
    interpreter); the equivalence tests drive both modes against each other.
    """

    def __init__(
        self,
        cfg: SimConfig,
        scenario,
        memory: DirectoryMemory,
        monitor_log: Optional[MonitorLog] = None,
        perturb=None,
        *,
        device_id: int = 0,
        emit_sink: Optional[
            Callable[[int, int, int, "PhaseSpec", int, int], None]
        ] = None,
        cohorts: bool = True,
    ):
        if not isinstance(scenario, Scenario):
            from .scenarios.gemv_allreduce import GemvAllReduceScenario

            scenario = GemvAllReduceScenario.from_workload(cfg, scenario)
        self.cfg = cfg
        self.scenario = scenario
        self.amap = scenario.amap
        self.memory = memory
        self.monitor_log = monitor_log
        if cfg.sync == SyncPolicy.SYNCMON and monitor_log is None:
            raise ValueError("SYNCMON policy requires a MonitorLog")
        self.perturb = perturb
        self.device_id = int(device_id)
        self.emit_sink = emit_sink

        programs = sorted(scenario.programs_for(self.device_id), key=lambda p: p.wg)
        if [p.wg for p in programs] != list(range(len(programs))):
            raise ValueError("WGProgram ids must be contiguous from 0")
        self.n_wgs = len(programs)
        # Cohort batching is valid only when no per-member state can diverge.
        # A perturbation scales phases by wg id — singletons.  Under SPIN,
        # nothing is member-keyed: maximal runs of consecutive workgroups
        # sharing (dispatch cycle, phases) batch.  Under SyncMon, the only
        # state divergence is the deterministic requeue jitter (wg %
        # requeue_jitter_mod), so workgroups of the same *jitter class* (and
        # dispatch cycle and phases) batch even when interleaved; the CU only
        # affects the coalesced-validation-read accounting, which is scored
        # from the per-member CU list at wake time.
        batch = cohorts and perturb is None
        # (first_program, member_wgs, member_cus) triples, frozen below
        groups: List[Tuple[WGProgram, List[int], List[int]]] = []
        if batch and cfg.sync == SyncPolicy.SPIN:
            for p in programs:
                g = groups[-1] if groups else None
                if (
                    g is not None
                    and g[0].dispatch_cycle == p.dispatch_cycle
                    and (g[0].phases is p.phases or g[0].phases == p.phases)
                ):
                    g[1].append(p.wg)
                    g[2].append(p.cu)
                else:
                    groups.append((p, [p.wg], [p.cu]))
        elif batch and cfg.sync == SyncPolicy.SYNCMON:
            mod = max(1, cfg.requeue_jitter_mod)
            token: Dict[int, int] = {}  # id(phases) -> small int
            index: Dict[Tuple[int, int, int], int] = {}
            for p in programs:
                t = token.setdefault(id(p.phases), len(token))
                key = (p.dispatch_cycle, t, p.wg % mod)
                gi = index.get(key)
                if gi is None:
                    index[key] = len(groups)
                    groups.append((p, [p.wg], [p.cu]))
                else:
                    g = groups[gi]
                    g[1].append(p.wg)
                    g[2].append(p.cu)
        else:
            groups = [(p, [p.wg], [p.cu]) for p in programs]
        self.cohorts: List[_Cohort] = [
            _Cohort(
                program=p,
                members=tuple(wgs),
                member_cus=tuple(cus),
                idx=i,
                count=len(wgs),
                phases=p.phases,
            )
            for i, (p, wgs, cus) in enumerate(groups)
        ]
        # wg id -> cohort index (monitor wakes are keyed by wg id)
        self._by_wg: Dict[int, int] = {
            wg: c.idx for c in self.cohorts for wg in c.members
        }
        # Per-spec unit traffic deltas, keyed by spec identity and filled
        # *lazily* by _tdelta_for (symbolic programs materialize phases on
        # demand; an up-front walk would re-expand O(steps) specs).  A phase
        # completion then costs six integer adds instead of re-walking the
        # TrafficOp list; the arithmetic is identical to op.apply() per member.
        # SymbolicProgram memoizes materialization, so spec ids are stable and
        # stay alive as long as the program does.
        self._tdelta: Dict[int, Optional[Tuple[int, int, int, int, int, int]]] = {}

        # every flag address some program may wait on, as literals plus
        # (start, stride, count) runs (one walk per distinct phases object)
        self._watched = _WatchSet()
        seen_phase_tuples: Set[int] = set()
        for c in self.cohorts:
            pid = id(c.phases)
            if pid in seen_phase_tuples:
                continue
            seen_phase_tuples.add(pid)
            self._watched.add_program(c.phases)
        self.flag_set_cycle: Dict[int, int] = {}
        # spin mode: flag addr -> set of blocked cohort indexes
        self._spin_waiters: Dict[int, Set[int]] = {}
        # syncmon: wg -> monitor entry currently armed
        self._armed: Dict[int, object] = {}

        # transition queue managed via (cycle, first_member, cohort_idx);
        # first_member is the tie-break that reproduces per-workgroup pop
        # order (cohorts are consecutive id runs, so ordering by the first
        # member orders every member)
        self._ready: List[Tuple[int, int, int]] = []
        for ci, c in enumerate(self.cohorts):
            self._push(c.program.dispatch_cycle, ci)
        self.done_count = 0
        self.kernel_end_cycle = 0

    # ------------------------------------------------------------------
    # transition queue (a tiny heap the engines drain)
    # ------------------------------------------------------------------

    def _push(self, cycle: int, ci: int) -> None:
        heapq.heappush(self._ready, (int(cycle), self.cohorts[ci].members[0], ci))

    def next_transition_cycle(self) -> Optional[int]:
        return self._ready[0][0] if self._ready else None

    def process_until(self, cycle: int) -> None:
        """Fire all transitions scheduled at or before ``cycle``."""
        while self._ready and self._ready[0][0] <= cycle:
            t, _, ci = heapq.heappop(self._ready)
            self._advance(self.cohorts[ci], t)

    @property
    def all_done(self) -> bool:
        return self.done_count == self.n_wgs

    def blocked_count(self) -> int:
        return sum(
            c.count for c in self.cohorts if c.in_wait and c.blocked_on is not None
        )

    def blocked_waits(self) -> Dict[int, List[int]]:
        """Unsatisfied flag address -> sorted blocked workgroup ids.

        Deadlock diagnostics: these are the flags no pending write will ever
        set (decode them with ``self.amap.decode_flag``).
        """
        out: Dict[int, List[int]] = {}
        for c in self.cohorts:
            if c.in_wait and c.blocked_on is not None:
                out.setdefault(c.blocked_on, []).extend(c.members)
        return {addr: sorted(wgs) for addr, wgs in out.items()}

    # ------------------------------------------------------------------
    # phase completion accounting
    # ------------------------------------------------------------------

    def _tdelta_for(
        self, spec: PhaseSpec
    ) -> Optional[Tuple[int, int, int, int, int, int]]:
        """Unit traffic delta of ``spec``, memoized by spec identity."""
        key = id(spec)
        try:
            return self._tdelta[key]
        except KeyError:
            pass
        if not spec.traffic:
            self._tdelta[key] = None
            return None
        nonflag = rbytes = local = wbytes = xout = xbytes = 0
        for op in spec.traffic:
            if op.kind == "reads":
                nonflag += op.n
                rbytes += op.n * op.bytes_each
            elif op.kind == "local_writes":
                local += op.n
                wbytes += op.n * op.bytes_each
            else:  # xgmi_out
                xout += op.n
                xbytes += op.n * op.bytes_each
        d = (nonflag, rbytes, local, wbytes, xout, xbytes)
        self._tdelta[key] = d
        return d

    def _complete_phase(self, c: _Cohort, spec: PhaseSpec, start: int, end: int) -> None:
        # timed phases always get a timeline segment (even zero-length, as the
        # seed's state machine did); wait phases only when time actually passed
        if end > start or spec.wait_addrs is None:
            c.segments.append((spec.name, start, end))
        d = self._tdelta_for(spec)
        if d is not None:
            # closed-form cohort accounting: identical arithmetic to
            # TrafficOp.apply(memory, times=count), precomputed per spec
            t = self.memory.traffic
            n = c.count
            t.nonflag_reads += d[0] * n
            t.read_bytes += d[1] * n
            t.local_writes += d[2] * n
            t.write_bytes += d[3] * n
            t.xgmi_writes_out += d[4] * n
            t.xgmi_bytes_out += d[5] * n
        if spec.emits and self.emit_sink is not None:
            self.emit_sink(
                self.device_id, c.program.wg, c.phase_idx, spec, end, c.count
            )

    # ------------------------------------------------------------------
    # the program interpreter
    # ------------------------------------------------------------------

    def _advance(self, c: _Cohort, now: int) -> None:
        if c.done:
            return
        if c.in_wait:
            self._run_wait(c, now)
            return
        # completing the current timed phase (if dispatched)
        if c.phase_idx >= 0:
            self._complete_phase(c, c.phases[c.phase_idx], c.phase_start, now)
        self._enter_next_phase(c, now)

    def _enter_next_phase(self, c: _Cohort, now: int) -> None:
        c.phase_idx += 1
        c.phase_start = now
        if c.phase_idx >= len(c.phases):
            self._finish(c, now)
            return
        spec = c.phases[c.phase_idx]
        if spec.wait_addrs is not None:
            c.in_wait = True
            c.flag_idx = 0
            c.t_cursor = now
            c.wait_start = now
            self._run_wait(c, now)
        else:
            dur = spec.duration_cycles
            if self.perturb is not None and dur > 0:
                dur = self.perturb.scale_phase(c.program.wg, spec.name, dur)
            self._push(now + dur, c.idx)

    def _finish(self, c: _Cohort, now: int) -> None:
        c.done = True
        self.done_count += c.count
        self.kernel_end_cycle = max(self.kernel_end_cycle, now)

    # ------------------------------------------------------------------
    # WAIT phase: spin / syncmon
    # ------------------------------------------------------------------

    def _run_wait(self, c: _Cohort, now: int) -> None:
        cfg = self.cfg
        spec = c.phases[c.phase_idx]
        assert spec.wait_addrs is not None
        addrs = spec.wait_addrs
        n_addrs = len(addrs)
        n = c.count
        traffic = self.memory.traffic
        flag_set = self.flag_set_cycle
        check = cfg.flag_check_cycles
        poll = cfg.poll_interval_cycles
        spin = cfg.sync == SyncPolicy.SPIN
        c.blocked_on = None
        while c.flag_idx < n_addrs:
            addr = addrs[c.flag_idx]
            set_c = flag_set.get(addr)
            if set_c is not None and set_c <= c.t_cursor:
                # observe-and-advance: a single read (per member) sees the
                # flag set (inline of memory.bulk_reads(n, 8, flag=True))
                traffic.flag_reads += n
                traffic.read_bytes += 8 * n
                c.t_cursor += check
                c.flag_idx += 1
                continue
            if spin:
                if set_c is not None:
                    # flag will be visible at set_c > t_cursor: poll until
                    # then — every member polls the same ticks, so the cohort
                    # accounts nticks+1 reads per member in closed form
                    nticks = -((set_c - c.t_cursor) // -poll)
                    traffic.flag_reads += n * (nticks + 1)
                    traffic.read_bytes += 8 * n * (nticks + 1)
                    c.t_cursor += nticks * poll + check
                    c.flag_idx += 1
                    continue
                # unset with unknown set time: block until notify
                c.blocked_on = addr
                self._spin_waiters.setdefault(addr, set()).add(c.idx)
                return
            # SYNCMON (members share jitter class -> identical state):
            # one check read per member (sees unset or not-yet-visible)
            self.memory.bulk_reads(n, bytes_each=8, flag=True)
            t_arm = c.t_cursor + cfg.monitor_arm_cycles
            if set_c is not None and set_c <= t_arm:
                # race window: write landed between check and mwait; the
                # mwait returns immediately after its own validation read
                self.memory.bulk_reads(n, bytes_each=8, flag=True)
                if self.monitor_log is not None:
                    self.monitor_log.stats["immediate_mwait_returns"] += n
                c.t_cursor = t_arm + cfg.flag_check_cycles
                c.flag_idx += 1
                continue
            # arm + deschedule: every member arms its own monitor (one
            # Monitor Log row each in the per-workgroup interpreter; a
            # multi-member cohort shares one row but accounts the same
            # number of armings, and all members wake together)
            entry = self.monitor_log.monitor(addr, 8, 1)
            for wg in c.members:
                entry.waiting_wfs.add(wg)
                self._armed[wg] = entry
            if n > 1:
                self.monitor_log.stats["monitors_armed"] += n - 1
            c.blocked_on = addr
            c.in_mwait = True
            c.t_arm = t_arm
            c.desched_segments.append((t_arm, -1))  # end filled on wake
            return
        # all flags observed — wait phase completes at the poll cursor
        end = c.t_cursor
        self._complete_phase(c, spec, c.wait_start, end)
        c.in_wait = False
        self._enter_next_phase(c, end)

    # ------------------------------------------------------------------
    # peer-write enactment hooks (called by the engines)
    # ------------------------------------------------------------------

    def on_writes_enacted(self, writes: List[RegisteredWrite], cycle: int) -> None:
        """Process a batch of WTT writes that were enacted at ``cycle``.

        The DirectoryMemory has already applied them (and fired Monitor Log
        observers).  Here we resolve flag visibility for blocked workgroups.
        """
        cfg = self.cfg
        poll = cfg.poll_interval_cycles
        check = cfg.flag_check_cycles
        traffic = self.memory.traffic
        for w in writes:
            if w.addr not in self._watched:
                continue
            if w.addr not in self.flag_set_cycle:
                self.flag_set_cycle[w.addr] = cycle
            if cfg.sync == SyncPolicy.SPIN:
                waiters = self._spin_waiters.pop(w.addr, set())
                for ci in sorted(waiters):
                    c = self.cohorts[ci]
                    # account the polls from t_cursor up to the observation
                    # tick, closed-form across the cohort's members
                    gap = cycle - c.t_cursor
                    nticks = -(gap // -poll) if gap > 0 else 0
                    m = c.count * (nticks + 1)
                    traffic.flag_reads += m
                    traffic.read_bytes += 8 * m
                    c.t_cursor += nticks * poll + check
                    c.flag_idx += 1
                    c.blocked_on = None
                    self._push(c.t_cursor, ci)
        if cfg.sync == SyncPolicy.SYNCMON and self.monitor_log is not None:
            pending = self.monitor_log.pop_wakes_until(
                cycle + cfg.wake_latency_cycles
            )
            # A cohort's members armed one entry together and wake together,
            # so scan the pending wakes once per cohort.  The coalesced
            # validation read accounting stays *member*-granular: simultaneous
            # wakes group by (wake_cycle, cu) ACROSS cohorts, exactly as the
            # per-workgroup interpreter groups individual workgroups.
            race: List[_Cohort] = []
            woken: List[Tuple[int, _Cohort]] = []
            groups: Dict[Tuple[int, int], int] = {}
            seen: Set[int] = set()
            for wg_id, wake_c in pending:
                ci = self._by_wg[wg_id]
                if ci in seen:
                    continue
                c = self.cohorts[ci]
                if not c.in_mwait:
                    continue
                seen.add(ci)
                if cycle <= c.t_arm:
                    race.append(c)
                    continue
                for cu in (c.member_cus or (c.program.cu,) * c.count):
                    key = (wake_c, cu)
                    groups[key] = groups.get(key, 0) + 1
                woken.append((wake_c, c))
            for c in race:
                # race window: the write landed between the check read and
                # the monitor arming; the mwait returns immediately after
                # its own (uncoalesced) validation read at arm time
                self.memory.bulk_reads(c.count, bytes_each=8, flag=True)
                c.in_mwait = False
                for wg in c.members:
                    self._armed.pop(wg, None)
                if c.desched_segments and c.desched_segments[-1][1] == -1:
                    c.desched_segments.pop()  # never actually descheduled
                self.monitor_log.stats["immediate_mwait_returns"] += c.count
                c.blocked_on = None
                c.flag_idx += 1
                c.t_cursor = c.t_arm + cfg.flag_check_cycles
                self._push(c.t_cursor, c.idx)
            width = max(1, cfg.wake_coalesce_width)
            for (wake_c, _cu), n_members in sorted(groups.items()):
                self.memory.bulk_reads(
                    math.ceil(n_members / width), bytes_each=8, flag=True
                )
            for wake_c, c in woken:
                c.in_mwait = False
                for wg in c.members:
                    self._armed.pop(wg, None)
                # close the descheduled segment
                if c.desched_segments and c.desched_segments[-1][1] == -1:
                    st = c.desched_segments[-1][0]
                    c.desched_segments[-1] = (st, wake_c)
                jitter = c.program.wg % max(1, cfg.requeue_jitter_mod)
                resume = wake_c + jitter
                # the coalesced validation read observed the blocking flag;
                # if it is (now) set, advance past it without another read
                addr = c.blocked_on
                set_c = self.flag_set_cycle.get(addr)
                if set_c is not None and set_c <= resume:
                    c.flag_idx += 1
                c.blocked_on = None
                c.t_cursor = resume + cfg.flag_check_cycles
                self._push(c.t_cursor, c.idx)

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------

    def collect_segments(self) -> List[Segment]:
        segs: List[Segment] = []
        ns = self.cfg.cycles_to_ns
        for c in self.cohorts:
            for wg in c.members:
                for phase, st, en in c.segments:
                    segs.append(
                        Segment(
                            wg=wg,
                            phase=phase,
                            start_ns=ns(st),
                            end_ns=ns(en),
                            device=self.device_id,
                        )
                    )
                for st, en in c.desched_segments:
                    if en >= st >= 0:
                        segs.append(
                            Segment(
                                wg=wg,
                                phase="descheduled",
                                start_ns=ns(st),
                                end_ns=ns(en),
                                device=self.device_id,
                            )
                        )
        return sorted(segs, key=lambda s: (s.wg, s.start_ns))
