"""Detailed target-device model: per-workgroup phase state machines.

The paper simulates exactly one device in detailed timing mode; its figures
measure (a) per-workgroup phase timelines (Figs. 1/2) and (b) memory-read
traffic split into flag vs. non-flag categories (Figs. 6/9).  This module
models the target at that granularity: each workgroup advances through the
fused-kernel phases with durations from its :class:`WGPlan`; compute/memory
phase traffic is accounted in closed form at phase completion; the *wait*
phase interacts with the WTT-enacted peer flag writes under one of two
synchronization policies:

* ``SPIN``    — sequential per-peer polling loop; one flag read per poll tick
                while the current flag is unset, one observe read once set.
* ``SYNCMON`` — check once; if unset, arm a Monitor Log entry and mwait
                (descheduled, zero reads while waiting); on wake, a validation
                read that may coalesce with other wavefronts woken in the same
                cycle on the same CU (the fill triggered by the waking write
                serves adjacent waiters).

The model is engine-agnostic: cycle-poll and event-queue engines drive the
same transitions and therefore produce bit-identical traffic and timelines.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .config import SimConfig, SyncPolicy
from .events import RegisteredWrite, Segment
from .memory import AddressMap, DirectoryMemory
from .monitor import MonitorLog
from .workload import GemvAllReduceWorkload, WGPlan

__all__ = ["TargetDevice", "EidolaDeadlock"]


class EidolaDeadlock(RuntimeError):
    """Raised when all workgroups are blocked and no pending writes remain."""


# Workgroup lifecycle states.
_PENDING = "pending"
_REMOTE = "remote_tiles"
_FLAGW = "flag_write"
_LOCAL = "local_tiles"
_WAIT = "wait"
_REDUCE = "reduce"
_BCAST = "broadcast"
_DONE = "done"

_PHASE_AFTER = {
    _PENDING: _REMOTE,
    _REMOTE: _FLAGW,
    _FLAGW: _LOCAL,
    _LOCAL: _WAIT,
    _WAIT: _REDUCE,
    _REDUCE: _BCAST,
    _BCAST: _DONE,
}


@dataclass
class _WG:
    plan: WGPlan
    state: str = _PENDING
    phase_start: int = 0          # cycle the current phase began
    # wait-phase bookkeeping
    flag_idx: int = 0
    t_cursor: int = 0             # next poll/check tick (cycles)
    blocked_on: Optional[int] = None   # peer id we are spinning/mwaiting on
    in_mwait: bool = False
    t_arm: int = 0                # cycle the current monitor was armed
    wait_start: int = 0
    segments: List[Segment] = field(default_factory=list)
    desched_segments: List[Tuple[int, int]] = field(default_factory=list)


class TargetDevice:
    """The single detailed device (device 0) of an Eidola simulation."""

    def __init__(
        self,
        cfg: SimConfig,
        workload: GemvAllReduceWorkload,
        memory: DirectoryMemory,
        monitor_log: Optional[MonitorLog] = None,
        perturb=None,
    ):
        self.cfg = cfg
        self.workload = workload
        self.amap = workload.amap
        self.memory = memory
        self.monitor_log = monitor_log
        if cfg.sync == SyncPolicy.SYNCMON and monitor_log is None:
            raise ValueError("SYNCMON policy requires a MonitorLog")
        self.perturb = perturb
        self.flag_order = workload.flag_order()
        self.flag_set_cycle: Dict[int, int] = {}
        self._addr_to_peer = {
            self.amap.flag_addr(g): g for g in range(1, cfg.n_devices)
        }
        # spin mode: peer -> set of blocked wg ids
        self._spin_waiters: Dict[int, Set[int]] = {}
        # syncmon: wg -> monitor entry currently armed
        self._armed: Dict[int, object] = {}
        self.wgs = [_WG(plan=p) for p in workload.plans]
        # transition list managed by the engine via (cycle, wg) pairs
        self._ready: List[Tuple[int, int]] = []
        for wg in self.wgs:
            d = self._dur(wg, _PENDING)
            self._push(wg.plan.dispatch_cycle, wg.plan.wg)
        self.done_count = 0
        self.kernel_end_cycle = 0

    # ------------------------------------------------------------------
    # transition queue (a tiny heap the engines drain)
    # ------------------------------------------------------------------

    def _push(self, cycle: int, wg_id: int) -> None:
        import heapq

        heapq.heappush(self._ready, (int(cycle), wg_id))

    def next_transition_cycle(self) -> Optional[int]:
        return self._ready[0][0] if self._ready else None

    def process_until(self, cycle: int) -> None:
        """Fire all transitions scheduled at or before ``cycle``."""
        import heapq

        while self._ready and self._ready[0][0] <= cycle:
            t, wg_id = heapq.heappop(self._ready)
            self._advance(self.wgs[wg_id], t)

    @property
    def all_done(self) -> bool:
        return self.done_count == len(self.wgs)

    def blocked_count(self) -> int:
        return sum(1 for w in self.wgs if w.state == _WAIT and w.blocked_on is not None)

    # ------------------------------------------------------------------
    # phase durations (perturbable)
    # ------------------------------------------------------------------

    def _dur(self, wg: _WG, state: str) -> int:
        p = wg.plan
        base = {
            _PENDING: 0,
            _REMOTE: p.remote_cycles,
            _FLAGW: p.flag_write_cycles,
            _LOCAL: p.local_cycles,
            _REDUCE: p.reduce_cycles,
            _BCAST: p.broadcast_cycles,
        }.get(state, 0)
        if self.perturb is not None and base > 0:
            base = self.perturb.scale_phase(p.wg, state, base)
        return base

    # ------------------------------------------------------------------
    # phase completion accounting
    # ------------------------------------------------------------------

    def _complete_phase(self, wg: _WG, state: str, start: int, end: int) -> None:
        cfg, p = self.cfg, wg.plan
        ns = cfg.cycles_to_ns
        if end > start or state in (_REMOTE, _LOCAL, _FLAGW, _REDUCE, _BCAST):
            name = {
                _REMOTE: "remote_tiles",
                _FLAGW: "flag_write",
                _LOCAL: "local_tiles",
                _WAIT: "wait_flags",
                _REDUCE: "reduce",
                _BCAST: "broadcast",
            }.get(state)
            if name and end >= start:
                wg.segments.append(
                    Segment(wg=p.wg, phase=name, start_ns=ns(start), end_ns=ns(end))
                )
        if state == _REMOTE:
            self.memory.bulk_reads(
                p.remote_sector_reads, bytes_each=cfg.sector_bytes
            )
            self.memory.issue_xgmi_out(
                p.remote_xgmi_writes, bytes_each=cfg.elem_bytes * cfg.N
            )
        elif state == _FLAGW:
            self.memory.issue_xgmi_out(len(self.flag_order), bytes_each=8)
        elif state == _LOCAL:
            self.memory.bulk_reads(
                p.local_sector_reads, bytes_each=cfg.sector_bytes
            )
            self.memory.bulk_local_writes(
                p.local_partial_writes, bytes_each=cfg.elem_bytes * cfg.N
            )
        elif state == _REDUCE:
            self.memory.bulk_reads(p.reduce_reads, bytes_each=cfg.elem_bytes)
        elif state == _BCAST:
            self.memory.issue_xgmi_out(
                p.broadcast_xgmi_writes, bytes_each=cfg.elem_bytes * cfg.N
            )
            self.memory.bulk_local_writes(
                p.broadcast_local_writes, bytes_each=cfg.elem_bytes * cfg.N
            )

    # ------------------------------------------------------------------
    # the state machine
    # ------------------------------------------------------------------

    def _advance(self, wg: _WG, now: int) -> None:
        if wg.state == _DONE:
            return
        if wg.state == _WAIT:
            self._run_wait(wg, now)
            return
        # completing a timed phase
        if wg.state != _PENDING:
            self._complete_phase(wg, wg.state, wg.phase_start, now)
        nxt = _PHASE_AFTER[wg.state]
        wg.state = nxt
        wg.phase_start = now
        if nxt == _WAIT:
            wg.flag_idx = 0
            wg.t_cursor = now
            wg.wait_start = now
            self._run_wait(wg, now)
        elif nxt == _DONE:
            self._finish(wg, now)
        else:
            self._push(now + self._dur(wg, nxt), wg.plan.wg)

    def _finish(self, wg: _WG, now: int) -> None:
        self.done_count += 1
        self.kernel_end_cycle = max(self.kernel_end_cycle, now)

    # ------------------------------------------------------------------
    # WAIT phase: spin / syncmon
    # ------------------------------------------------------------------

    def _run_wait(self, wg: _WG, now: int) -> None:
        cfg = self.cfg
        wg.blocked_on = None
        while wg.flag_idx < len(self.flag_order):
            g = self.flag_order[wg.flag_idx]
            set_c = self.flag_set_cycle.get(g)
            if set_c is not None and set_c <= wg.t_cursor:
                # observe-and-advance: a single read sees the flag set
                self.memory.bulk_reads(1, bytes_each=8, flag=True)
                wg.t_cursor += cfg.flag_check_cycles
                wg.flag_idx += 1
                continue
            if cfg.sync == SyncPolicy.SPIN:
                if set_c is not None:
                    # flag will be visible at set_c > t_cursor: poll until then
                    nticks = math.ceil(
                        (set_c - wg.t_cursor) / cfg.poll_interval_cycles
                    )
                    self.memory.bulk_reads(nticks + 1, bytes_each=8, flag=True)
                    wg.t_cursor += (
                        nticks * cfg.poll_interval_cycles + cfg.flag_check_cycles
                    )
                    wg.flag_idx += 1
                    continue
                # unset with unknown set time: block until notify
                wg.blocked_on = g
                self._spin_waiters.setdefault(g, set()).add(wg.plan.wg)
                return
            else:  # SYNCMON
                # one check read (sees unset or not-yet-visible)
                self.memory.bulk_reads(1, bytes_each=8, flag=True)
                t_arm = wg.t_cursor + cfg.monitor_arm_cycles
                if set_c is not None and set_c <= t_arm:
                    # race window: write landed between check and mwait; the
                    # mwait returns immediately after its own validation read
                    self.memory.bulk_reads(1, bytes_each=8, flag=True)
                    if self.monitor_log is not None:
                        self.monitor_log.stats["immediate_mwait_returns"] += 1
                    wg.t_cursor = t_arm + cfg.flag_check_cycles
                    wg.flag_idx += 1
                    continue
                # arm + deschedule
                entry = self.monitor_log.monitor(
                    self.amap.flag_addr(g), 8, 1
                )
                entry.waiting_wfs.add(wg.plan.wg)
                self._armed[wg.plan.wg] = entry
                wg.blocked_on = g
                wg.in_mwait = True
                wg.t_arm = t_arm
                wg.desched_segments.append((t_arm, -1))  # end filled on wake
                return
        # all flags observed — wait phase completes at the poll cursor
        end = wg.t_cursor
        self._complete_phase(wg, _WAIT, wg.wait_start, end)
        wg.state = _REDUCE
        wg.phase_start = end
        self._push(end + self._dur(wg, _REDUCE), wg.plan.wg)

    # ------------------------------------------------------------------
    # peer-write enactment hooks (called by the engines)
    # ------------------------------------------------------------------

    def on_writes_enacted(self, writes: List[RegisteredWrite], cycle: int) -> None:
        """Process a batch of WTT writes that were enacted at ``cycle``.

        The DirectoryMemory has already applied them (and fired Monitor Log
        observers).  Here we resolve flag visibility for blocked workgroups.
        """
        cfg = self.cfg
        woken: List[int] = []
        for w in writes:
            peer = self._addr_to_peer.get(w.addr)
            if peer is None:
                continue
            if peer not in self.flag_set_cycle:
                self.flag_set_cycle[peer] = cycle
            if cfg.sync == SyncPolicy.SPIN:
                waiters = self._spin_waiters.pop(peer, set())
                for wg_id in sorted(waiters):
                    wg = self.wgs[wg_id]
                    # account the polls from t_cursor up to the observation tick
                    nticks = math.ceil(
                        max(0, cycle - wg.t_cursor) / cfg.poll_interval_cycles
                    )
                    self.memory.bulk_reads(nticks + 1, bytes_each=8, flag=True)
                    wg.t_cursor += (
                        nticks * cfg.poll_interval_cycles + cfg.flag_check_cycles
                    )
                    wg.flag_idx += 1
                    wg.blocked_on = None
                    self._push(wg.t_cursor, wg_id)
        if cfg.sync == SyncPolicy.SYNCMON and self.monitor_log is not None:
            pending = self.monitor_log.pop_wakes_until(
                cycle + cfg.wake_latency_cycles
            )
            # group simultaneous wakes by (wake_cycle, cu) for the coalesced
            # validation read accounting
            groups: Dict[Tuple[int, int], List[int]] = {}
            for wg_id, wake_c in pending:
                wg = self.wgs[wg_id]
                if not wg.in_mwait:
                    continue
                if cycle <= wg.t_arm:
                    # race window: the write landed between the check read and
                    # the monitor arming; the mwait returns immediately after
                    # its own (uncoalesced) validation read at arm time
                    self.memory.bulk_reads(1, bytes_each=8, flag=True)
                    wg.in_mwait = False
                    self._armed.pop(wg_id, None)
                    if wg.desched_segments and wg.desched_segments[-1][1] == -1:
                        wg.desched_segments.pop()  # never actually descheduled
                    if self.monitor_log is not None:
                        self.monitor_log.stats["immediate_mwait_returns"] += 1
                    wg.blocked_on = None
                    wg.flag_idx += 1
                    wg.t_cursor = wg.t_arm + cfg.flag_check_cycles
                    self._push(wg.t_cursor, wg_id)
                    continue
                groups.setdefault((wake_c, wg.plan.cu), []).append(wg_id)
            for (wake_c, _cu), members in sorted(groups.items()):
                n_reads = math.ceil(len(members) / max(1, cfg.wake_coalesce_width))
                self.memory.bulk_reads(n_reads, bytes_each=8, flag=True)
                for wg_id in members:
                    wg = self.wgs[wg_id]
                    wg.in_mwait = False
                    self._armed.pop(wg_id, None)
                    # close the descheduled segment
                    if wg.desched_segments and wg.desched_segments[-1][1] == -1:
                        st = wg.desched_segments[-1][0]
                        wg.desched_segments[-1] = (st, wake_c)
                    jitter = wg.plan.wg % max(1, cfg.requeue_jitter_mod)
                    resume = wake_c + jitter
                    # the coalesced validation read observed the blocking flag;
                    # if it is (now) set, advance past it without another read
                    g = wg.blocked_on
                    set_c = self.flag_set_cycle.get(g)
                    if set_c is not None and set_c <= resume:
                        wg.flag_idx += 1
                    wg.blocked_on = None
                    wg.t_cursor = resume + cfg.flag_check_cycles
                    self._push(wg.t_cursor, wg.plan.wg)

    # ------------------------------------------------------------------
    # results
    # ------------------------------------------------------------------

    def collect_segments(self) -> List[Segment]:
        segs: List[Segment] = []
        ns = self.cfg.cycles_to_ns
        for wg in self.wgs:
            segs.extend(wg.segments)
            for st, en in wg.desched_segments:
                if en >= st >= 0:
                    segs.append(
                        Segment(
                            wg=wg.plan.wg,
                            phase="descheduled",
                            start_ns=ns(st),
                            end_ns=ns(en),
                        )
                    )
        return sorted(segs, key=lambda s: (s.wg, s.start_ns))
