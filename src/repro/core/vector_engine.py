"""Vectorized batch-replay engine.

The TPU-idiomatic rethink of the WTT poll loop (DESIGN.md §2): because
eidolons are *replay-only*, their write times are independent of target-device
state, so every workgroup's wait behaviour is a pure function of (its phase
schedule, the flag visibility times).  That turns the simulator's inner loop —
a pointer-chasing priority queue polled per cycle in gem5 — into a handful of
dense array passes over all workgroups at once.  Results are bit-identical to
the cycle/event engines (asserted in tests); wall time is near-constant in
simulated cycles and sub-linear in everything else.

A jax.lax.scan variant of the spin-read closed form is provided for the
pod-scale replay path (``repro.core.predictor``), demonstrating the engine
itself can run on the accelerator.

This engine is replay-only and gemv-specific; the same closed forms applied
to the N-device closed loop live in ``repro.core.cohort_timeline`` (lanes)
and ``repro.core.lockstep`` (all ranks × all loop steps of a symbolic
program, advanced in bulk without unrolling).
"""

from __future__ import annotations

import math
import time
from typing import Dict, List, Tuple

import numpy as np

from .config import SimConfig, SyncPolicy
from .events import RegisteredWrite, Segment, effective_writes

__all__ = ["run_vectorized"]


def _effective_writes(sim) -> List[RegisteredWrite]:
    return effective_writes(
        sim.traces,
        latency_ns=sim.cfg.xgmi_enact_latency_ns,
        perturb=sim.perturb,
    )


def run_vectorized(sim) -> "Report":  # noqa: F821 - avoids circular import
    from .simulator import Report
    from .workload import GemvAllReduceWorkload

    t0 = time.perf_counter()
    cfg: SimConfig = sim.cfg
    workload = GemvAllReduceWorkload(cfg, sim.amap)
    plans = workload.plans
    nwg = len(plans)
    order = workload.flag_order()

    writes = _effective_writes(sim)

    # Flag visibility cycles: first write to each (src_device, slot) wins.
    # Resolution uses amap.decode_flag — O(1) per write and covering EVERY
    # flag slot — rather than comparing against the slot-0 addresses only:
    # a multi-slot trace bundle (ring steps, pipeline microbatches) would be
    # invisible to a slot-0 linear scan and the run misreported as a
    # "no flag writes" deadlock even though the bundle is full of flags.
    flag_T: Dict[tuple, int] = {}
    for w in sorted(writes, key=lambda w: (w.wakeup_ns, w.seq)):
        decoded = sim.amap.decode_flag(w.addr)
        if decoded is not None and decoded not in flag_T:
            flag_T[decoded] = cfg.ns_to_cycles(w.wakeup_ns)
    # the gemv workload polls each peer's slot-0 flag, in flag_order()
    missing = [g for g in order if (g, 0) not in flag_T]
    if missing:
        from .target import EidolaDeadlock

        have = sorted(flag_T)
        raise EidolaDeadlock(
            f"no slot-0 flag writes for peers {missing} in trace"
            + (
                f" (bundle carries flags for (src, slot) {have})"
                if have
                else ""
            )
        )

    # --- per-WG static schedule (perturbable) -------------------------------
    def dur(wg_i: int, state: str, base: int) -> int:
        if sim.perturb is not None and base > 0:
            return sim.perturb.scale_phase(wg_i, state, base)
        return base

    dispatch = np.array([p.dispatch_cycle for p in plans], dtype=np.int64)
    remote = np.array(
        [dur(p.wg, "remote_tiles", p.remote_cycles) for p in plans], dtype=np.int64
    )
    flagw = np.array(
        [dur(p.wg, "flag_write", p.flag_write_cycles) for p in plans], dtype=np.int64
    )
    local = np.array(
        [dur(p.wg, "local_tiles", p.local_cycles) for p in plans], dtype=np.int64
    )
    reduce_d = np.array(
        [dur(p.wg, "reduce", p.reduce_cycles) for p in plans], dtype=np.int64
    )
    bcast_d = np.array(
        [dur(p.wg, "broadcast", p.broadcast_cycles) for p in plans], dtype=np.int64
    )
    cu = np.array([p.cu for p in plans], dtype=np.int64)
    wg_idx = np.arange(nwg, dtype=np.int64)

    wait_start = dispatch + remote + flagw + local
    c = wait_start.copy()
    flag_reads = np.zeros(nwg, dtype=np.int64)
    poll = cfg.poll_interval_cycles
    check = cfg.flag_check_cycles
    arm = cfg.monitor_arm_cycles
    wl = cfg.wake_latency_cycles
    jit = wg_idx % max(1, cfg.requeue_jitter_mod)

    coalesce_groups: Dict[Tuple[int, int], int] = {}
    monitor_stats = {
        "monitors_armed": 0,
        "mwaits": 0,
        "wakes": 0,
        "immediate_mwait_returns": 0,
        "writes_checked": 0,
    }
    desched: List[Tuple[int, int, int]] = []  # (wg, t_arm, wake_c)

    for g in order:
        T = flag_T[(g, 0)]
        already = T <= c
        if cfg.sync == SyncPolicy.SPIN:
            nticks = np.where(
                already, 0, np.ceil(np.maximum(T - c, 0) / poll).astype(np.int64)
            )
            flag_reads += np.where(already, 1, nticks + 1)
            c = np.where(already, c + check, c + nticks * poll + check)
        else:
            flag_reads += 1  # check/observe read
            t_arm = c + arm
            race = (~already) & (T <= t_arm)
            blocked = (~already) & (T > t_arm)
            flag_reads += race.astype(np.int64)
            # coalesced wake-validation accounting
            wake_c = T + wl
            for cu_id in range(cfg.n_cus):
                n = int(np.sum(blocked & (cu == cu_id)))
                if n:
                    coalesce_groups[(wake_c, cu_id)] = (
                        coalesce_groups.get((wake_c, cu_id), 0) + n
                    )
            nblocked = int(blocked.sum())
            nrace = int(race.sum())
            monitor_stats["monitors_armed"] += nblocked + nrace
            monitor_stats["mwaits"] += nblocked + nrace
            monitor_stats["wakes"] += nblocked + nrace
            monitor_stats["immediate_mwait_returns"] += nrace
            if nblocked:
                monitor_stats["writes_checked"] += 1
            for i in np.nonzero(blocked)[0]:
                desched.append((int(i), int(t_arm[i]), wake_c))
            resume = wake_c + jit
            c = np.where(
                already,
                c + check,
                np.where(race, t_arm + check, resume + check),
            )

    coalesced_reads = sum(
        math.ceil(n / max(1, cfg.wake_coalesce_width))
        for n in coalesce_groups.values()
    )
    total_flag_reads = int(flag_reads.sum()) + coalesced_reads

    wait_end = c
    reduce_end = wait_end + reduce_d
    bcast_end = reduce_end + bcast_d
    kernel_end = int(bcast_end.max()) if nwg else 0
    # writes beyond kernel end still enact (drained), matching event engine
    last_write_cycle = max(
        (cfg.ns_to_cycles(w.wakeup_ns) for w in writes), default=0
    )
    sim_cycles = max(kernel_end, last_write_cycle)

    # --- closed-form non-flag traffic ---------------------------------------
    nonflag = sum(
        p.remote_sector_reads + p.local_sector_reads + p.reduce_reads for p in plans
    )
    sector_reads = sum(p.remote_sector_reads + p.local_sector_reads for p in plans)
    reduce_reads = sum(p.reduce_reads for p in plans)
    local_writes = sum(
        p.local_partial_writes + p.broadcast_local_writes for p in plans
    )
    xgmi_out = sum(
        p.remote_xgmi_writes + p.broadcast_xgmi_writes for p in plans
    ) + nwg * len(order)
    xgmi_out_bytes = (
        sum(p.remote_xgmi_writes + p.broadcast_xgmi_writes for p in plans)
        * cfg.elem_bytes
        * cfg.N
        + nwg * len(order) * 8
    )
    traffic = {
        "flag_reads": total_flag_reads,
        "nonflag_reads": nonflag,
        "total_reads": total_flag_reads + nonflag,
        "local_writes": local_writes,
        "xgmi_writes_in": len(writes),
        "xgmi_writes_out": xgmi_out,
        "xgmi_bytes_in": sum(w.size for w in writes),
        "xgmi_bytes_out": xgmi_out_bytes,
        "read_bytes": sector_reads * cfg.sector_bytes
        + reduce_reads * cfg.elem_bytes
        + total_flag_reads * 8,
        "write_bytes": local_writes * cfg.elem_bytes * cfg.N,
    }

    segments: List[Segment] = []
    if sim.collect_segments:
        ns = cfg.cycles_to_ns
        for i, p in enumerate(plans):
            t = int(dispatch[i])
            bounds = [
                ("remote_tiles", t, t + int(remote[i])),
                ("flag_write", t + int(remote[i]), t + int(remote[i]) + int(flagw[i])),
                (
                    "local_tiles",
                    t + int(remote[i]) + int(flagw[i]),
                    int(wait_start[i]),
                ),
                ("wait_flags", int(wait_start[i]), int(wait_end[i])),
                ("reduce", int(wait_end[i]), int(reduce_end[i])),
                ("broadcast", int(reduce_end[i]), int(bcast_end[i])),
            ]
            for name, s, e in bounds:
                segments.append(
                    Segment(wg=p.wg, phase=name, start_ns=ns(s), end_ns=ns(e))
                )
        for wg_i, t_arm_i, wake_c in desched:
            segments.append(
                Segment(
                    wg=plans[wg_i].wg,
                    phase="descheduled",
                    start_ns=ns(t_arm_i),
                    end_ns=ns(wake_c),
                )
            )
        segments.sort(key=lambda s: (s.wg, s.start_ns))

    return Report(
        engine="vector",
        sync=cfg.sync.value,
        traffic=traffic,
        flag_reads=total_flag_reads,
        nonflag_reads=nonflag,
        kernel_span_ns=cfg.cycles_to_ns(kernel_end),
        sim_cycles=sim_cycles,
        wall_time_s=time.perf_counter() - t0,
        wtt_registered=len(writes),
        wtt_enacted=len(writes),
        wtt_head_polls=0,
        monitor_stats=monitor_stats if cfg.sync == SyncPolicy.SYNCMON else {},
        segments=segments,
        meta=dict(sim.traces.meta),
        n_devices=1,
        per_device={0: dict(traffic)},
        closed_loop=False,
    )


# ---------------------------------------------------------------------------
# jax.lax.scan variant of the spin-wait closed form (accelerator-residency
# demonstration; used by the pod-scale predictor)
# ---------------------------------------------------------------------------


def spin_reads_jax(wait_start, flag_T, poll: int, check: int):
    """flag reads + wait-end cursor for SPIN mode, as a jax scan over flags.

    wait_start: f32[nwg] wait-phase entry cycles
    flag_T:     f32[npeers] flag visibility cycles (polling order)
    returns (reads_per_wg, cursor_after) — matches the numpy closed form.
    """
    import jax
    import jax.numpy as jnp

    def step(c, T):
        already = T <= c
        nticks = jnp.where(
            already, 0, jnp.ceil(jnp.maximum(T - c, 0) / poll)
        ).astype(jnp.int32)
        reads = jnp.where(already, 1, nticks + 1)
        c2 = jnp.where(already, c + check, c + nticks * poll + check)
        return c2, reads

    cursor, reads = jax.lax.scan(step, wait_start.astype(jnp.float32), flag_T)
    return reads.sum(axis=0), cursor
