"""Pod-scale closed-loop engine: vectorized cohort timelines.

The cohort interpreter (``target.py``) already advances counted cohorts, but
the event engine still walks *every phase of every cohort* through a Python
heap: at 256 devices the 305k ``_advance`` calls dominate the wall clock, and
pod-scale sweeps (1024-4096 devices) are out of reach.  This module is the
``vector_engine.py`` spin-read treatment generalized to the N-device closed
loop.  (Not to be confused with :mod:`repro.core.trace_render` — formerly
``repro.core.timeline`` — which only draws/exports finished segment lists.)

The key invariant — **lockstep lanes** — makes it possible.  Under SPIN with
no perturbation, whether a wait blocks is decided by whether the flag's set
cycle is *known at processing time*, which is uniform across all cohorts of a
device that share one phase program (their programs only differ in dispatch
cycle).  So those cohorts stay at the same ``phase_idx`` forever; the only
per-cohort divergent state is the poll-cursor vector.  A device whose
workgroups all share one phases tuple (every built-in closed-loop scenario)
is then a single **lane**: one ``(phase_idx, flag_idx)`` scalar plus a dense
``int64`` cursor vector, advanced closed-form between synchronization events:

* a timed phase is one vector add (+ six integer traffic adds x total
  members, the same arithmetic as ``_complete_phase``);
* a wait address with known visibility cycle ``V`` is the unified spin
  closed form ``nticks = max(ceil((V - t) / poll), 0)`` per cohort —
  identical to both interpreter paths (observed-at-entry and
  blocked-then-resumed), so counters stay bit-exact;
* an unknown flag blocks the whole lane until the write enacts.

Lanes run *ahead* of global time safely: resume cursors after an enactment at
cycle ``T`` are strictly greater than ``T`` (``flag_check_cycles`` > 0) and
routed arrivals are clamped to cycle ``T + 1`` (``Cluster._emit_writes``), so
emissions computed during a run-ahead are simply collected into a heap keyed
``(cycle, device, first_member, phase_idx)`` and routed when global time
reaches them — reproducing the event engine's exact completion order, which
is what keeps the stateful fabric's port-FIFO arithmetic (and therefore every
counter) bit-identical.

The engine reports as ``engine="event"`` (same semantics, same counters —
bench row keys stay comparable) and marks ``meta["engine_impl"] =
"timeline"``.  Ineligible configurations (SyncMon, perturbations, multi-lane
devices, ``cohorts=False``, or a scenario's declared ``timeline_opt_out``)
fall back to the ordinary engines; ``Cluster(timeline=True)`` turns the
fallback into a hard error.

``replay_lane_numpy``/``replay_lane_jax`` expose the same closed form as a
standalone whole-lane replay over dense step arrays (``lane_step_arrays``) —
the numpy reference and the ``jax.lax.scan`` variant for accelerator-resident
fabric sweeps — validated against each other and against real cluster runs in
``tests/test_timeline.py``.
"""

from __future__ import annotations

import heapq
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .config import SyncPolicy
from .engine import EngineResult, _deadlock_error
from .scenario import EmitOp, PhaseSpec

__all__ = [
    "TimelineEngine",
    "timeline_support",
    "lane_step_arrays",
    "replay_lane_numpy",
    "replay_lane_jax",
]


def timeline_support(cluster) -> Optional[str]:
    """Why this cluster cannot use the timeline engine, or None if it can.

    The engine's eligibility is exactly the lockstep-lane invariant: SPIN
    sync, no perturbation on any device, cohort batching enabled, and every
    device's cohorts sharing one phase program.  A scenario may also opt out
    explicitly by declaring a ``timeline_opt_out`` reason string —
    ``python -m repro.analysis`` fails loudly on undeclared opt-outs.
    """
    opt_out = getattr(cluster.scenario, "timeline_opt_out", None)
    if opt_out:
        return f"scenario {cluster.scenario.name!r} opts out: {opt_out}"
    cfg = cluster.cfg
    if cfg.sync != SyncPolicy.SPIN:
        return (
            "SyncMon wake coalescing is member-granular; lanes require SPIN"
        )
    for d in range(cfg.n_devices):
        if cluster._perturb_for(d) is not None:
            return "perturbations force per-workgroup interpretation"
    for node in cluster.nodes:
        cohorts = node.target.cohorts
        if not cohorts:
            continue
        ph0 = cohorts[0].phases
        for c in cohorts[1:]:
            if c.phases is not ph0 and c.phases != ph0:
                return (
                    f"device {node.device_id} workgroups run distinct phase "
                    "programs (multi-lane devices not supported)"
                )
    return None


class _ProgramTable:
    """Dense-array form of one shared phase program.

    One table per distinct phases tuple, shared by every lane running it:
    phase kinds, timed durations, wait flag keys, per-phase traffic deltas
    (reusing the cohort interpreter's precomputed unit deltas), and emit
    schedules.
    """

    __slots__ = ("specs", "n", "is_wait", "dur", "wait_addrs", "tdelta",
                 "names", "emits", "all_last")

    def __init__(self, phases, tdelta_for=None):
        # ``phases`` may be a flat tuple or a SymbolicProgram — iterating the
        # latter materializes (memoized) PhaseSpecs, which is fine here: the
        # generic lane path is per-step anyway, and the bulk lockstep
        # solvers (``core.lockstep`` flat, ``core.lockstep_tiered``
        # group-uniform over multi-tier presets) take over before this
        # table is ever built for the pod-scale collectives; only shapes
        # they decline — cross-group pipelined chains, recorded in
        # ``meta["lockstep_reason"]`` — reach this walk at pod scale.
        specs = tuple(phases)
        self.specs = specs
        self.n = len(specs)
        self.is_wait = [sp.wait_addrs is not None for sp in specs]
        self.dur = [
            0 if sp.wait_addrs is not None else sp.duration_cycles
            for sp in specs
        ]
        self.wait_addrs = [sp.wait_addrs for sp in specs]
        self.tdelta = [
            tdelta_for(sp) if tdelta_for is not None else None for sp in specs
        ]
        self.names = [sp.name for sp in specs]
        self.emits = [sp.emits for sp in specs]
        self.all_last = [
            bool(sp.emits) and all(op.coalesce == "last" for op in sp.emits)
            for sp in specs
        ]


class _Lane:
    """All cohorts of one device, advancing in lockstep.

    Wraps the device's :class:`~repro.core.target.TargetDevice` for traffic
    counters, flag bookkeeping, and result/diagnostic write-back (cohort
    segments, ``kernel_end_cycle``, blocked-wait state) — so collection and
    deadlock reporting reuse the interpreter's own machinery unchanged.
    """

    __slots__ = ("dev_id", "target", "table", "nc", "counts", "counts_list",
                 "total", "fm", "t", "phase_idx", "flag_idx", "in_wait",
                 "wait_start", "blocked", "done", "seg_mode")

    def __init__(self, dev_id: int, target, table: _ProgramTable,
                 seg_mode: bool):
        cohorts = target.cohorts
        self.dev_id = dev_id
        self.target = target
        self.table = table
        self.nc = len(cohorts)
        self.counts = np.array([c.count for c in cohorts], np.int64)
        self.counts_list = [c.count for c in cohorts]
        self.total = int(self.counts.sum()) if cohorts else 0
        self.fm = [c.members[0] for c in cohorts]
        self.t = np.array(
            [c.program.dispatch_cycle for c in cohorts], np.int64
        )
        self.phase_idx = 0
        self.flag_idx = 0
        self.in_wait = False
        self.wait_start: Optional[np.ndarray] = None
        self.blocked: Optional[int] = None
        self.done = False
        self.seg_mode = seg_mode

    def advance(self, eng: "TimelineEngine") -> None:
        """Run the lane closed-form until it blocks or finishes."""
        if self.done:
            return
        tab = self.table
        tgt = self.target
        P = tab.n
        is_wait = tab.is_wait
        durs = tab.dur
        traffic = tgt.memory.traffic
        flag_set = tgt.flag_set_cycle
        poll = eng.poll
        check = eng.check
        counts = self.counts
        total = self.total
        t = self.t
        p = self.phase_idx
        while p < P:
            if is_wait[p]:
                if not self.in_wait:
                    self.in_wait = True
                    self.flag_idx = 0
                    if self.seg_mode:
                        self.wait_start = t.copy()
                addrs = tab.wait_addrs[p]
                fi = self.flag_idx
                na = len(addrs)
                while fi < na:
                    V = flag_set.get(addrs[fi])
                    if V is None:
                        # unknown visibility: the whole lane blocks (the
                        # interpreter would block every cohort here too —
                        # blocking is processing-time-uniform across the lane)
                        self.flag_idx = fi
                        self.blocked = addrs[fi]
                        self.t = t
                        self.phase_idx = p
                        return
                    # unified spin closed form, vectorized over cohorts:
                    # identical to both interpreter paths (_run_wait's
                    # set_c<=cursor / set_c>cursor and on_writes_enacted's
                    # blocked-resume arithmetic); in-place ops — t is never
                    # aliased here (wait_start is a copy, prior phases'
                    # start/end arrays are fully consumed by _complete)
                    nticks = V - t
                    nticks += poll - 1
                    nticks //= poll
                    np.maximum(nticks, 0, out=nticks)
                    m = int(counts @ nticks) + total
                    traffic.flag_reads += m
                    traffic.read_bytes += 8 * m
                    nticks *= poll
                    nticks += check
                    t += nticks
                    fi += 1
                self.blocked = None
                self.in_wait = False
                self._complete(p, self.wait_start, t, eng, traffic)
                p += 1
            else:
                dur = durs[p]
                start = t
                if dur:
                    t = t + dur
                self._complete(p, start, t, eng, traffic)
                p += 1
        self.t = t
        self.phase_idx = p
        self._finish()

    def _complete(
        self,
        p: int,
        start: Optional[np.ndarray],
        end: np.ndarray,
        eng: "TimelineEngine",
        traffic,
    ) -> None:
        tab = self.table
        d = tab.tdelta[p]
        if d is not None:
            n = self.total
            traffic.nonflag_reads += d[0] * n
            traffic.read_bytes += d[1] * n
            traffic.local_writes += d[2] * n
            traffic.write_bytes += d[3] * n
            traffic.xgmi_writes_out += d[4] * n
            traffic.xgmi_bytes_out += d[5] * n
        if self.seg_mode:
            # write into the cohorts' own segment lists so
            # TargetDevice.collect_segments serves the timeline run unchanged
            name = tab.names[p]
            wait = tab.is_wait[p]
            cohorts = self.target.cohorts
            for i in range(self.nc):
                st = int(start[i])
                en = int(end[i])
                if en > st or not wait:
                    cohorts[i].segments.append((name, st, en))
        emits = tab.emits[p]
        if emits:
            self._fire(p, emits, end, eng)

    def _fire(
        self,
        p: int,
        emits: Tuple[EmitOp, ...],
        end: np.ndarray,
        eng: "TimelineEngine",
    ) -> None:
        # The trigger completion — where the interpreter's "last" counter
        # crosses n_wgs — is the lexicographic max of (cycle, first_member)
        # over cohorts; first_members ascend with cohort index, so it is the
        # highest index among the max-cycle cohorts.
        nc = self.nc
        if nc == 1:
            trig = 0
            cyc = int(end[0])
        else:
            cm = end.max()
            trig = int(np.flatnonzero(end == cm)[-1])
            cyc = int(cm)
        if self.table.all_last[p]:
            # a single firing carrying all ops (the interpreter's _on_emit
            # fires them together at the trigger, batched when > 1)
            eng.push_emission(cyc, self.dev_id, self.fm[trig], p, list(emits))
            return
        # mixed / "each" coalescing: one firing per cohort, ops in emit
        # order, "each" ops repeated per represented member — exactly the
        # per-completion fire list _on_emit builds
        for i in range(nc):
            fire: List[EmitOp] = []
            ci = self.counts_list[i]
            for op in emits:
                if op.coalesce == "last":
                    if i == trig:
                        fire.append(op)
                else:
                    fire.extend([op] * ci)
            if fire:
                eng.push_emission(int(end[i]), self.dev_id, self.fm[i], p, fire)

    def _finish(self) -> None:
        self.done = True
        tgt = self.target
        tgt.done_count = tgt.n_wgs
        if self.nc:
            tgt.kernel_end_cycle = int(self.t.max())

    def sync_diagnostics(self) -> None:
        """Write blocked-wait state back onto the cohorts so the standard
        deadlock reporting (blocked_count/blocked_waits) works unchanged."""
        if self.done or self.blocked is None:
            return
        for c in self.target.cohorts:
            c.in_wait = True
            c.blocked_on = self.blocked
            c.phase_idx = self.phase_idx
            c.flag_idx = self.flag_idx


class TimelineEngine:
    """Drives a :class:`~repro.core.cluster.Cluster` of lockstep lanes.

    Global loop over two heaps: a WTT calendar (``on_register`` hooks, as in
    the event engine) and the emission heap filled by run-ahead lanes.  At
    each event cycle ``T``: deliveries first (devices in id order — enact,
    flag bookkeeping, resume blocked lanes), then emissions at ``T`` routed
    in ``(cycle, device, first_member, phase_idx)`` order through the
    cluster's ordinary ``_route``/``_route_batch`` — the event engine's exact
    intra-cycle order, hence bit-identical fabric and counter arithmetic.
    """

    def __init__(self, cluster):
        self.cluster = cluster
        cfg = cluster.cfg
        self.poll = cfg.poll_interval_cycles
        self.check = cfg.flag_check_cycles
        tables: Dict[int, _ProgramTable] = {}
        self.lanes: List[_Lane] = []
        seg_mode = cluster.collect_segments
        for node in cluster.nodes:
            tgt = node.target
            if tgt.cohorts:
                phases = tgt.cohorts[0].phases
                tab = tables.get(id(phases))
                if tab is None:
                    tab = _ProgramTable(phases, tgt._tdelta_for)
                    tables[id(phases)] = tab
            else:
                tab = _ProgramTable(())
            self.lanes.append(_Lane(node.device_id, tgt, tab, seg_mode))
        # (cycle, device, first_member, phase_idx, tie, ops)
        self._emissions: List[tuple] = []
        self._ectr = 0
        self.breakdown: Dict[str, float] = {}

    def push_emission(
        self, cycle: int, dev: int, fm: int, phase_idx: int, ops: List[EmitOp]
    ) -> None:
        self._ectr += 1
        heapq.heappush(
            self._emissions, (cycle, dev, fm, phase_idx, self._ectr, ops)
        )

    def run(self) -> EngineResult:
        t0 = time.perf_counter()
        pc = time.perf_counter
        cluster = self.cluster
        nodes = cluster.nodes
        lanes = self.lanes
        emis = self._emissions
        route = cluster._route
        route_batch = cluster._route_batch
        cal: List[Tuple[int, int]] = []
        push = heapq.heappush
        pop = heapq.heappop
        t_interp = t_fabric = t_wtt = 0.0
        last_cycle = 0
        saved_hooks = [n.wtt.on_register for n in nodes]
        try:
            for i, n in enumerate(nodes):
                n.wtt.on_register = lambda cyc, i=i: push(cal, (cyc, i))
                c = n.wtt.peek_wakeup_cycle()
                if c is not None:
                    push(cal, (c, i))
            ts = pc()
            for lane in lanes:
                lane.advance(self)
            t_interp += pc() - ts
            while True:
                # earliest still-valid WTT head (lazy invalidation)
                wtt_next = None
                while cal:
                    c, i = cal[0]
                    cur = nodes[i].wtt.peek_wakeup_cycle()
                    if cur != c:
                        pop(cal)
                        if cur is not None:
                            push(cal, (cur, i))
                        continue
                    wtt_next = c
                    break
                em_next = emis[0][0] if emis else None
                if wtt_next is None and em_next is None:
                    if all(lane.done for lane in lanes):
                        break
                    for lane in lanes:
                        lane.sync_diagnostics()
                    raise _deadlock_error(
                        [(n.target, n.wtt) for n in nodes], last_cycle
                    )
                if em_next is None or (
                    wtt_next is not None and wtt_next <= em_next
                ):
                    T = wtt_next
                else:
                    T = em_next

                # (1) deliveries at T, devices in id order (writes enact
                # before anything else at equal cycles)
                if wtt_next == T:
                    ts = pc()
                    ia0 = t_interp
                    due = {pop(cal)[1]}
                    while cal and cal[0][0] == T:
                        due.add(pop(cal)[1])
                    order = sorted(due) if len(due) > 1 else tuple(due)
                    # pass A: enact the cycle-T group of every due device
                    # (id order, resumes included) — the event engine's
                    # intra-cycle order exactly
                    hit: List[int] = []
                    for i in order:
                        node = nodes[i]
                        wtt = node.wtt
                        if wtt.peek_wakeup_cycle() != T:
                            continue  # stale duplicate
                        cycle, group = wtt.pop_next_group()
                        node.memory.enact_xgmi_group(group, cycle)
                        tgt = node.target
                        fs = tgt.flag_set_cycle
                        watched = tgt._watched
                        lane = lanes[i]
                        blocked = lane.blocked
                        resume = False
                        for w in group:
                            a = w.addr
                            if a in watched and a not in fs:
                                fs[a] = cycle
                                if a == blocked:
                                    resume = True
                        if resume:
                            ti = pc()
                            lane.advance(self)
                            t_interp += pc() - ti
                        hit.append(i)
                    # pass B: drain each due device's subsequent groups
                    # while no other event can precede them.  All cycle-T
                    # work (including resumes) is done, cal entries are
                    # strictly > T and static during deliveries (resumes
                    # never register writes — only emission *routing* does),
                    # and the emission heap is re-read live each step, so a
                    # group at cycle c <= min(emission head, cal head) can
                    # be enacted now: any future registration arrives
                    # strictly after the emission that causes it.  Equal-
                    # cycle ties are safe — deliveries precede emissions at
                    # one cycle, and same-cycle deliveries on different
                    # devices touch disjoint state (the emission heap key
                    # orders cross-device firings by (cycle, device), never
                    # by push order).
                    for i in hit:
                        node = nodes[i]
                        wtt = node.wtt
                        c = wtt.peek_wakeup_cycle()
                        if c is None:
                            continue
                        mem = node.memory
                        tgt = node.target
                        fs = tgt.flag_set_cycle
                        watched = tgt._watched
                        lane = lanes[i]
                        while True:
                            stop = emis[0][0] if emis else None
                            if cal:
                                c0 = cal[0][0]
                                if stop is None or c0 < stop:
                                    stop = c0
                            if stop is not None and c > stop:
                                break
                            # bulk-pop a head marker run in one call (no
                            # per-member heap round trip), bounded by the
                            # same horizon
                            run = wtt.pop_due_run(stop)
                            if run is not None:
                                cycles2, addrs, rdata, rsize = run
                                mem.enact_xgmi_run(
                                    addrs, cycles2, rdata, rsize
                                )
                                cycle = cycles2[-1]
                                blocked = lane.blocked
                                resume = False
                                for a, cy in zip(addrs, cycles2):
                                    if a in watched and a not in fs:
                                        fs[a] = cy
                                        if a == blocked:
                                            resume = True
                            else:
                                cycle, group = wtt.pop_next_group()
                                mem.enact_xgmi_group(group, cycle)
                                blocked = lane.blocked
                                resume = False
                                for w in group:
                                    a = w.addr
                                    if a in watched and a not in fs:
                                        fs[a] = cycle
                                        if a == blocked:
                                            resume = True
                            if resume:
                                ti = pc()
                                lane.advance(self)
                                t_interp += pc() - ti
                            if cycle > last_cycle:
                                last_cycle = cycle
                            c = wtt.peek_wakeup_cycle()
                            if c is None:
                                break
                        if c is not None:
                            push(cal, (c, i))
                    t_wtt += (pc() - ts) - (t_interp - ia0)

                # (2) route emissions at T, in completion order
                if emis and emis[0][0] == T:
                    ts = pc()
                    while emis and emis[0][0] == T:
                        cyc, dev, _fm, _p, _k, ops = pop(emis)
                        if len(ops) > 1:
                            route_batch(dev, ops, cyc)
                        else:
                            route(dev, ops[0], cyc)
                    t_fabric += pc() - ts
                if T > last_cycle:
                    last_cycle = T
        finally:
            for n, hook in zip(nodes, saved_hooks):
                n.wtt.on_register = hook
        # device transitions are events too: the last one is each lane's
        # kernel end (the event engine counts it via its calendar)
        for lane in lanes:
            if lane.target.kernel_end_cycle > last_cycle:
                last_cycle = lane.target.kernel_end_cycle
        wall = time.perf_counter() - t0
        self.breakdown = {
            "interpreter_s": t_interp,
            "fabric_s": t_fabric,
            "wtt_s": t_wtt,
            "other_s": max(0.0, wall - t_interp - t_fabric - t_wtt),
        }
        return EngineResult(
            sim_cycles=last_cycle,
            wall_time_s=wall,
            head_polls=sum(n.wtt.stats.head_polls for n in nodes),
            breakdown=self.breakdown,
        )


# ---------------------------------------------------------------------------
# Standalone whole-lane closed form (numpy reference + jax.lax variant)
# ---------------------------------------------------------------------------


def lane_step_arrays(
    phases: Tuple[PhaseSpec, ...], flag_set_cycle: Dict[int, int]
) -> Tuple[np.ndarray, np.ndarray]:
    """Flatten a phase program into dense per-step arrays.

    Each timed phase becomes one step ``(is_wait=False, value=duration)``;
    each wait *address* becomes one step ``(is_wait=True, value=visibility
    cycle)`` looked up in ``flag_set_cycle`` (e.g. a completed run's
    ``TargetDevice.flag_set_cycle``).  Feeding the result to
    :func:`replay_lane_numpy` / :func:`replay_lane_jax` replays the whole
    lane closed-form.
    """
    is_wait: List[bool] = []
    val: List[int] = []
    for sp in phases:
        if sp.wait_addrs is not None:
            for a in sp.wait_addrs:
                is_wait.append(True)
                val.append(int(flag_set_cycle[a]))
        else:
            is_wait.append(False)
            val.append(int(sp.duration_cycles))
    return np.asarray(is_wait, bool), np.asarray(val, np.int64)


def replay_lane_numpy(
    dispatch, is_wait, val, *, poll: int, check: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Closed-form lane replay (numpy reference).

    ``dispatch`` is the per-cohort dispatch-cycle vector; returns
    ``(flag_reads_per_cohort_member, end_cycle_per_cohort)`` after running
    every step of the program — the exact per-member arithmetic of
    ``TargetDevice._run_wait`` with no interpreter in the loop.
    """
    t = np.array(dispatch, np.int64, copy=True)
    reads = np.zeros_like(t)
    for w, v in zip(is_wait, val):
        if w:
            nticks = np.maximum((v - t + poll - 1) // poll, 0)
            reads += nticks + 1
            t += nticks * poll + check
        else:
            t += v
    return reads, t


def replay_lane_jax(dispatch, is_wait, val, *, poll: int, check: int):
    """The same closed form as a branchless ``jax.lax.scan`` over steps.

    Integer arithmetic throughout (int32 under jax's default x64-disabled
    config — fine for the cycle ranges of a lane replay; the numpy reference
    is the int64 ground truth).  Returns
    ``(flag_reads_per_cohort_member, end_cycle_per_cohort)`` as jax arrays.
    """
    import jax
    import jax.numpy as jnp

    xs = (
        jnp.asarray(np.asarray(is_wait, bool)),
        jnp.asarray(np.asarray(val, np.int32)),
    )

    def step(t, x):
        w, v = x
        nticks = jnp.maximum((v - t + poll - 1) // poll, 0)
        t_wait = t + nticks * poll + check
        t_timed = t + v
        return jnp.where(w, t_wait, t_timed), jnp.where(w, nticks + 1, 0)

    t, per_step_reads = jax.lax.scan(
        step, jnp.asarray(np.asarray(dispatch, np.int32)), xs
    )
    return per_step_reads.sum(axis=0), t
