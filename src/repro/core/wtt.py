"""Write Tracking Table (WTT).

The WTT is the paper's core simulator-side data structure (§3.1): a priority
queue of registered writes sorted by ``wakeupTime``.  The detailed engine polls
the head every simulated cycle; when current time reaches the head's wakeup
time, *all* entries sharing that timestamp are popped and enacted as xGMI
writes.  Registration order is arbitrary; pops are strictly chronological with
this table's own registration counter as a deterministic tie-break (write
``seq`` numbers are producer-local and may collide across producers).

Timestamps are registered in nanoseconds (as in the pseudo-op) and converted to
cycles with the device clock, exactly as the paper describes ("these timestamps
are converted into cycles based on the device clock frequency defined in the
gem5 configuration").
"""

from __future__ import annotations

import heapq
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple, Union

from .events import RegisteredWrite, TraceBundle

__all__ = ["WriteTrackingTable", "WTTStats", "LazyWriteRun"]


@dataclass
class WTTStats:
    registered: int = 0
    enacted: int = 0
    max_pending: int = 0
    head_polls: int = 0  # number of O(1) head comparisons performed


@dataclass(frozen=True)
class LazyWriteRun:
    """A compact descriptor for an arithmetic run of registered writes.

    The closed-loop incast registers O(devices^2) *marker* writes per run —
    every one of them on the same arithmetic grid: member ``k`` wakes at
    ``base_ns + span_ns * (k + 1) / (count + 1)`` (clamped to ``min_ns``,
    the emission-causality floor) and lands at ``addr_base + k *
    addr_stride`` with identical data/size/src and consecutive ``seq``
    numbers.  Registering one descriptor instead of ``count`` dataclasses
    keeps registration O(1) in the run length; the table synthesizes each
    :class:`RegisteredWrite` only when simulated time actually reaches it.

    Synthesis is bit-identical to materialized registration: the wakeup
    expression is evaluated with exactly the float arithmetic the eager
    builder used (same rounding into cycles), member cycles are
    non-decreasing in ``k`` (the clamp preserves monotonicity), and the
    run's members occupy a *contiguous* block of the owning table's
    registration counter — so ``(cycle, reg_no)`` pop order, the heap
    tie-break, and mid-run interleaving with ordinary writes are all exactly
    what ``count`` sequential registrations would have produced (property-
    tested in ``tests/test_timeline.py``).
    """

    count: int
    base_ns: float
    span_ns: float
    addr_base: int
    addr_stride: int
    data: int
    size: int = 8
    src: int = -1
    seq0: int = 0
    min_ns: float = 0.0

    def __post_init__(self) -> None:
        if self.count < 1:
            raise ValueError("LazyWriteRun.count must be >= 1")
        if self.span_ns < 0:
            raise ValueError("LazyWriteRun.span_ns must be >= 0")

    def wakeup_ns(self, k: int) -> float:
        # the exact expression (and float evaluation order) of the eager
        # marker builder in Cluster._emit_writes — cycle rounding must agree
        t = self.base_ns + self.span_ns * (k + 1) / (self.count + 1)
        return t if t >= self.min_ns else self.min_ns

    def materialize(self, k: int) -> RegisteredWrite:
        if not (0 <= k < self.count):
            raise IndexError(f"run member {k} out of range [0, {self.count})")
        # hot path: member fields are valid by construction (the descriptor
        # is built from an already-validated eager write recipe), so skip the
        # frozen-dataclass __init__/__post_init__ re-validation
        t = self.base_ns + self.span_ns * (k + 1) / (self.count + 1)
        if t < self.min_ns:
            t = self.min_ns
        w = RegisteredWrite.__new__(RegisteredWrite)
        w.__dict__.update(
            wakeup_ns=t,
            addr=self.addr_base + k * self.addr_stride,
            data=self.data,
            size=self.size,
            src=self.src,
            seq=self.seq0 + k,
        )
        return w


class _RunCursor:
    """Mutable heap payload: ``run`` with members ``k..count-1`` pending."""

    __slots__ = ("run", "k")

    def __init__(self, run: LazyWriteRun, k: int = 0):
        self.run = run
        self.k = k


RegistrationLike = Union[RegisteredWrite, LazyWriteRun]


class WriteTrackingTable:
    """Priority queue of pending emulated writes, keyed by wakeup cycle."""

    def __init__(self, clock_ghz: float = 1.5):
        if clock_ghz <= 0:
            raise ValueError("clock_ghz must be positive")
        self.clock_ghz = float(clock_ghz)
        # Heap entries: (wakeup_cycle, registration_no, RegisteredWrite).
        # The tie-break is this table's OWN monotonic registration counter,
        # not the write's ``seq``: seqs are only unique within one producer
        # (trace bundles and a Cluster's emission counter both start at 0),
        # so a warm-started closed loop can hold two same-cycle writes with
        # equal seqs — and RegisteredWrite is unorderable, which would make
        # heapq fall through to comparing the writes and raise TypeError.
        # For every single-producer table (all pre-cohort callers) writes are
        # registered in seq order, so pop order is unchanged.
        # Payloads are RegisteredWrite or _RunCursor (a LazyWriteRun with a
        # next-member index); a cursor stands for its remaining members, each
        # synthesized on pop at its own (cycle, reg_no) key.
        self._heap: List[Tuple[int, int, object]] = []
        self._next_reg = 0
        # logical pending count minus heap entries: a cursor covering m
        # remaining members contributes m - 1 here
        self._extra = 0
        self.stats = WTTStats()
        # Optional engine hook: called with the wakeup cycle of every newly
        # registered write, so a global event calendar can track cross-device
        # registrations without rescanning each table per event.
        self.on_register: Optional[Callable[[int], None]] = None

    # -- time conversion -----------------------------------------------------

    def ns_to_cycles(self, ns: float) -> int:
        return int(round(ns * self.clock_ghz))

    def cycles_to_ns(self, cycles: int) -> float:
        return cycles / self.clock_ghz

    # -- registration --------------------------------------------------------

    def register(self, write: RegisteredWrite) -> None:
        cyc = self.ns_to_cycles(write.wakeup_ns)
        heapq.heappush(self._heap, (cyc, self._next_reg, write))
        self._next_reg += 1
        self.stats.registered += 1
        self.stats.max_pending = max(self.stats.max_pending, len(self))
        if self.on_register is not None:
            self.on_register(cyc)

    def register_run(self, run: LazyWriteRun) -> None:
        """Register a :class:`LazyWriteRun` descriptor — O(log n), not O(count).

        Reserves a contiguous ``count``-wide block of the registration
        counter so the synthesized members pop exactly where ``count``
        sequential :meth:`register` calls would have placed them.
        """
        reg0 = self._next_reg
        self._next_reg = reg0 + run.count
        cyc = self.ns_to_cycles(run.wakeup_ns(0))
        heapq.heappush(self._heap, (cyc, reg0, _RunCursor(run, 0)))
        self._extra += run.count - 1
        self.stats.registered += run.count
        self.stats.max_pending = max(self.stats.max_pending, len(self))
        if self.on_register is not None:
            self.on_register(cyc)

    def register_many(self, writes: Sequence[RegistrationLike]) -> None:
        """Register a batch of writes with one heap restructure.

        Bit-identical to calling :meth:`register` once per write in order —
        heap pops are fully determined by the sorted ``(cycle, reg_no)`` keys,
        and batch reg_nos are assigned in the same order the sequential calls
        would have used — but the heap invariant is restored once per batch
        (``heapify``, O(n)) instead of once per write (``heappush``,
        O(log n) each), and the engine's ``on_register`` calendar hook fires
        once with the batch's earliest wakeup cycle instead of per write
        (sufficient: after every calendar pop the engine re-reads the table's
        actual head).  This is the closed-loop incast lever: an ``all_to_all``
        dispatch completion lands O(devices) marker+flag bursts per peer —
        O(devices^2) registrations per run — previously each paying its own
        push and hook call.

        Items may be plain :class:`RegisteredWrite`\\ s or
        :class:`LazyWriteRun` descriptors, freely mixed; a descriptor costs
        one heap entry regardless of its ``count`` (see :meth:`register_run`).
        """
        heap = self._heap
        n2c = self.ns_to_cycles
        reg = self._next_reg
        entries: List[Tuple[int, int, object]] = []
        logical = 0
        mn = None
        for item in writes:
            if type(item) is LazyWriteRun:
                c = n2c(item.wakeup_ns(0))
                entries.append((c, reg, _RunCursor(item, 0)))
                reg += item.count
                logical += item.count
            else:
                c = n2c(item.wakeup_ns)
                entries.append((c, reg, item))
                reg += 1
                logical += 1
            if mn is None or c < mn:
                mn = c
        if not entries:
            return
        self._next_reg = reg
        self._extra += logical - len(entries)
        # a few pushes into a big heap beat re-heapifying the whole heap
        if len(entries) * 8 < len(heap):
            for e in entries:
                heapq.heappush(heap, e)
        else:
            heap.extend(entries)
            heapq.heapify(heap)
        self.stats.registered += logical
        self.stats.max_pending = max(self.stats.max_pending, len(self))
        if self.on_register is not None:
            self.on_register(mn)

    def register_bundle(self, bundle: TraceBundle) -> None:
        for w in bundle:
            self.register(w)

    # -- queries -------------------------------------------------------------

    def __len__(self) -> int:
        # logical pending count: run cursors count their remaining members
        return len(self._heap) + self._extra

    @property
    def empty(self) -> bool:
        return not self._heap

    def _pop_head(self) -> RegisteredWrite:
        """Pop one logical write, synthesizing run members on demand.

        When the head is a run cursor, member ``k`` is materialized and the
        cursor is re-pushed at member ``k + 1``'s (cycle, reg_no) key — so
        ordinary writes and other runs landing between two members interleave
        exactly as they would against materialized registrations.
        """
        heap = self._heap
        cyc, reg, payload = heapq.heappop(heap)
        if type(payload) is not _RunCursor:
            return payload  # type: ignore[return-value]
        run = payload.run
        k = payload.k
        nk = k + 1
        if nk < run.count:
            payload.k = nk
            heapq.heappush(
                heap, (self.ns_to_cycles(run.wakeup_ns(nk)), reg + 1, payload)
            )
            self._extra -= 1
        return run.materialize(k)

    def pop_due_run(
        self, stop_cycle: Optional[int] = None
    ) -> Optional[Tuple[List[int], List[int], int, int]]:
        """Bulk-pop the maximal due prefix of a head run cursor.

        Returns ``(cycles, addrs, data, size)`` — parallel cycle/address
        lists plus the run's shared payload word — or ``None`` when the
        table is empty or the head is a plain write.  Members are synthesized
        while their ``(cycle, reg_no)`` key stays strictly ahead of every
        other heap entry and their cycle does not exceed ``stop_cycle``
        (``None`` = unbounded) — i.e. exactly the writes that consecutive
        :meth:`pop_next_group` calls would have yielded next, without the
        per-member heap pop/push round trip or per-member RegisteredWrite
        construction (every member of a run carries the same data/size, so
        the enactor splits the payload into bytes once per batch).  The
        timeline engine uses this to drain marker runs in one call; pop
        order (and therefore enactment order) is unchanged.
        """
        heap = self._heap
        if not heap:
            return None
        cyc, reg, payload = heap[0]
        if type(payload) is not _RunCursor:
            return None
        heapq.heappop(heap)
        nxt = heap[0] if heap else None
        run = payload.run
        k = payload.k
        count = run.count
        n2c = self.ns_to_cycles
        # member wakeup math inlined from LazyWriteRun.wakeup_ns (hot loop)
        base = run.base_ns
        span = run.span_ns
        mn = run.min_ns
        cnt1 = count + 1
        addr = run.addr_base
        stride = run.addr_stride
        cycles = [cyc]
        addrs = [addr + k * stride]
        k += 1
        while k < count:
            t = base + span * (k + 1) / cnt1
            if t < mn:
                t = mn
            cyc = n2c(t)
            reg += 1
            if stop_cycle is not None and cyc > stop_cycle:
                break
            if nxt is not None and (
                nxt[0] < cyc or (nxt[0] == cyc and nxt[1] < reg)
            ):
                break
            cycles.append(cyc)
            addrs.append(addr + k * stride)
            k += 1
        j = len(addrs)
        if k < count:
            payload.k = k
            heapq.heappush(heap, (cyc, reg, payload))
            self._extra -= j
        else:
            self._extra -= j - 1
        self.stats.enacted += j
        return cycles, addrs, run.data, run.size

    def peek_wakeup_cycle(self) -> Optional[int]:
        """Wakeup cycle of the head entry, or None if empty.  O(1)."""
        if not self._heap:
            return None
        return self._heap[0][0]

    # -- the per-cycle poll ---------------------------------------------------

    def poll(self, now_cycle: int) -> List[RegisteredWrite]:
        """The paper's per-cycle head check.

        Returns the (possibly empty) list of writes due at ``now_cycle``.
        In the common case the head lies in the future and this is a single
        comparison.  When due, all head entries with wakeup <= now are popped
        in (wakeup, seq) order.  Popping *everything* <= now (rather than == now
        only) makes the engine robust to coarse stepping, while remaining
        identical to the paper's behaviour under per-cycle stepping.
        """
        self.stats.head_polls += 1
        if not self._heap or self._heap[0][0] > now_cycle:
            return []
        due: List[RegisteredWrite] = []
        while self._heap and self._heap[0][0] <= now_cycle:
            due.append(self._pop_head())
        self.stats.enacted += len(due)
        return due

    def pop_next_group(self) -> Tuple[Optional[int], List[RegisteredWrite]]:
        """Event-queue mode: pop the next timestamp group without polling.

        Returns ``(wakeup_cycle, writes)`` for the earliest pending timestamp,
        or ``(None, [])`` if empty.  Used by the event-driven engine (the
        paper's §3.2.2 proposed design) and by the vectorized engine.
        """
        if not self._heap:
            return None, []
        cyc = self._heap[0][0]
        group: List[RegisteredWrite] = []
        while self._heap and self._heap[0][0] == cyc:
            group.append(self._pop_head())
        self.stats.enacted += len(group)
        return cyc, group

    # -- inspection (the paper highlights WTT debuggability) ------------------

    def pending(self) -> List[RegisteredWrite]:
        """All pending writes in chronological order (non-destructive).

        Run cursors are expanded to their remaining members at each member's
        own (cycle, reg_no) key before sorting, so the listing matches the
        exact pop order.
        """
        items: List[Tuple[int, int, RegisteredWrite]] = []
        for cyc, reg, payload in self._heap:
            if type(payload) is _RunCursor:
                run, k = payload.run, payload.k
                for j in range(k, run.count):
                    items.append(
                        (
                            self.ns_to_cycles(run.wakeup_ns(j)),
                            reg + (j - k),
                            run.materialize(j),
                        )
                    )
            else:
                items.append((cyc, reg, payload))
        items.sort(key=lambda e: (e[0], e[1]))
        return [w for _, _, w in items]
