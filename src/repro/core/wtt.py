"""Write Tracking Table (WTT).

The WTT is the paper's core simulator-side data structure (§3.1): a priority
queue of registered writes sorted by ``wakeupTime``.  The detailed engine polls
the head every simulated cycle; when current time reaches the head's wakeup
time, *all* entries sharing that timestamp are popped and enacted as xGMI
writes.  Registration order is arbitrary; pops are strictly chronological with
this table's own registration counter as a deterministic tie-break (write
``seq`` numbers are producer-local and may collide across producers).

Timestamps are registered in nanoseconds (as in the pseudo-op) and converted to
cycles with the device clock, exactly as the paper describes ("these timestamps
are converted into cycles based on the device clock frequency defined in the
gem5 configuration").
"""

from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

from .events import RegisteredWrite, TraceBundle

__all__ = ["WriteTrackingTable", "WTTStats"]


@dataclass
class WTTStats:
    registered: int = 0
    enacted: int = 0
    max_pending: int = 0
    head_polls: int = 0  # number of O(1) head comparisons performed


class WriteTrackingTable:
    """Priority queue of pending emulated writes, keyed by wakeup cycle."""

    def __init__(self, clock_ghz: float = 1.5):
        if clock_ghz <= 0:
            raise ValueError("clock_ghz must be positive")
        self.clock_ghz = float(clock_ghz)
        # Heap entries: (wakeup_cycle, registration_no, RegisteredWrite).
        # The tie-break is this table's OWN monotonic registration counter,
        # not the write's ``seq``: seqs are only unique within one producer
        # (trace bundles and a Cluster's emission counter both start at 0),
        # so a warm-started closed loop can hold two same-cycle writes with
        # equal seqs — and RegisteredWrite is unorderable, which would make
        # heapq fall through to comparing the writes and raise TypeError.
        # For every single-producer table (all pre-cohort callers) writes are
        # registered in seq order, so pop order is unchanged.
        self._heap: List[Tuple[int, int, RegisteredWrite]] = []
        self._reg_no = itertools.count()
        self.stats = WTTStats()
        # Optional engine hook: called with the wakeup cycle of every newly
        # registered write, so a global event calendar can track cross-device
        # registrations without rescanning each table per event.
        self.on_register: Optional[Callable[[int], None]] = None

    # -- time conversion -----------------------------------------------------

    def ns_to_cycles(self, ns: float) -> int:
        return int(round(ns * self.clock_ghz))

    def cycles_to_ns(self, cycles: int) -> float:
        return cycles / self.clock_ghz

    # -- registration --------------------------------------------------------

    def register(self, write: RegisteredWrite) -> None:
        cyc = self.ns_to_cycles(write.wakeup_ns)
        heapq.heappush(self._heap, (cyc, next(self._reg_no), write))
        self.stats.registered += 1
        self.stats.max_pending = max(self.stats.max_pending, len(self._heap))
        if self.on_register is not None:
            self.on_register(cyc)

    def register_many(self, writes: Sequence[RegisteredWrite]) -> None:
        """Register a batch of writes with one heap restructure.

        Bit-identical to calling :meth:`register` once per write in order —
        heap pops are fully determined by the sorted ``(cycle, reg_no)`` keys,
        and batch reg_nos are assigned in the same order the sequential calls
        would have used — but the heap invariant is restored once per batch
        (``heapify``, O(n)) instead of once per write (``heappush``,
        O(log n) each), and the engine's ``on_register`` calendar hook fires
        once with the batch's earliest wakeup cycle instead of per write
        (sufficient: after every calendar pop the engine re-reads the table's
        actual head).  This is the closed-loop incast lever: an ``all_to_all``
        dispatch completion lands O(devices) marker+flag bursts per peer —
        O(devices^2) registrations per run — previously each paying its own
        push and hook call.
        """
        heap = self._heap
        n2c = self.ns_to_cycles
        nxt = self._reg_no
        entries = [(n2c(w.wakeup_ns), next(nxt), w) for w in writes]
        if not entries:
            return
        # a few pushes into a big heap beat re-heapifying the whole heap
        if len(entries) * 8 < len(heap):
            for e in entries:
                heapq.heappush(heap, e)
        else:
            heap.extend(entries)
            heapq.heapify(heap)
        self.stats.registered += len(entries)
        self.stats.max_pending = max(self.stats.max_pending, len(heap))
        if self.on_register is not None:
            self.on_register(min(c for c, _, _ in entries))

    def register_bundle(self, bundle: TraceBundle) -> None:
        for w in bundle:
            self.register(w)

    # -- queries -------------------------------------------------------------

    def __len__(self) -> int:
        return len(self._heap)

    @property
    def empty(self) -> bool:
        return not self._heap

    def peek_wakeup_cycle(self) -> Optional[int]:
        """Wakeup cycle of the head entry, or None if empty.  O(1)."""
        if not self._heap:
            return None
        return self._heap[0][0]

    # -- the per-cycle poll ---------------------------------------------------

    def poll(self, now_cycle: int) -> List[RegisteredWrite]:
        """The paper's per-cycle head check.

        Returns the (possibly empty) list of writes due at ``now_cycle``.
        In the common case the head lies in the future and this is a single
        comparison.  When due, all head entries with wakeup <= now are popped
        in (wakeup, seq) order.  Popping *everything* <= now (rather than == now
        only) makes the engine robust to coarse stepping, while remaining
        identical to the paper's behaviour under per-cycle stepping.
        """
        self.stats.head_polls += 1
        if not self._heap or self._heap[0][0] > now_cycle:
            return []
        due: List[RegisteredWrite] = []
        while self._heap and self._heap[0][0] <= now_cycle:
            due.append(heapq.heappop(self._heap)[2])
        self.stats.enacted += len(due)
        return due

    def pop_next_group(self) -> Tuple[Optional[int], List[RegisteredWrite]]:
        """Event-queue mode: pop the next timestamp group without polling.

        Returns ``(wakeup_cycle, writes)`` for the earliest pending timestamp,
        or ``(None, [])`` if empty.  Used by the event-driven engine (the
        paper's §3.2.2 proposed design) and by the vectorized engine.
        """
        if not self._heap:
            return None, []
        cyc = self._heap[0][0]
        group: List[RegisteredWrite] = []
        while self._heap and self._heap[0][0] == cyc:
            group.append(heapq.heappop(self._heap)[2])
        self.stats.enacted += len(group)
        return cyc, group

    # -- inspection (the paper highlights WTT debuggability) ------------------

    def pending(self) -> List[RegisteredWrite]:
        """All pending writes in chronological order (non-destructive)."""
        return [w for _, _, w in sorted(self._heap)]
