"""Bulk lockstep solver: whole-program closed forms over symbolic programs.

The timeline engine (:mod:`repro.core.cohort_timeline`) already collapses each
device's cohorts into one lane, but it still walks *every phase of every lane*
through Python — at 1024 devices a flat ``ring_allreduce`` is ~8M lane-phase
advances plus ~2M heap-ordered emissions, and 4096 devices is 16x that.  This
module removes the last per-step Python loop for the **rank-uniform lockstep**
case: when every rank runs the *same* :class:`~repro.core.scenario.LoopSpec`
structure (only the affine bases — peer ids, flag addresses — differ per
rank), the whole pod advances stage by stage with one numpy expression per
phase over a ``[n_ranks, n_cohorts]`` cursor matrix:

* a timed phase is one matrix add (traffic deltas are rank-uniform scalars);
* an emission stage prices every rank's message in one vectorized pass that
  replicates :class:`~repro.core.topology.FabricModel`'s float arithmetic
  exactly (same IEEE-754 op order per egress port, including
  ``transfer_batch``'s per-port ``cumsum`` chains), then converts
  arrival + enactment latency to flag-set cycles with the WTT's own rounding;
* a wait phase applies the interpreter's unified spin closed form
  (``nticks = max(ceil((V - t)/poll), 0)``) against set cycles gathered from
  the matching earlier emission stage.

Stage-ordered processing is dependency-correct by construction: compilation
symbolically matches every wait to the emission that writes it (affine flag
addresses, permutation or all-peers fan-in), and rejects programs where a wait
precedes its writer.  Per-port FIFO order equals per-rank program order on the
flat ring (ports are ``(src, dir)``-owned), and issue cycles are monotone per
rank, so the sequential per-port pricing the event engine performs in global
heap order factors exactly into independent per-rank chains.

The solver substitutes for the timeline engine *inside* the same
``EngineKind.EVENT`` path (``meta["engine_impl"]`` stays ``"timeline"``;
``meta["program_stats"]["lockstep"]`` records that the bulk solver ran) and is
bit-identical to it — and therefore to the event and cycle engines — on every
counter the repo checks: per-device traffic, ``sim_cycles``,
``kernel_end_cycle``, WTT registered/enacted, fabric message/byte counters,
per-port busy chains and integer port stats.  Documented divergences, all
invisible to ``multi_device_bench --check`` and ``repro.analysis``:

* ``DirectoryMemory._mem`` contents and ``TargetDevice.flag_set_cycle`` are
  not populated (O(devices^2) state that no counter reads);
* the float ``queued_ns`` *aggregates* are summed per stage rather than in
  global heap order, so they can differ from the event engine's accumulation
  in the last ulps (per-port queued stats use the same add order as the
  engine and stay bit-exact);
* ``wtt_head_polls`` is 0 (the solver never polls a table head).

Eligibility (:func:`lockstep_support` + a successful compile) requires the
timeline invariant plus: flat single-tier ring fabric, no segment collection,
no sanitizer, no seed writes, and rank-uniform symbolic programs whose waits/
emits fit the affine single-peer or all-peers patterns.  Anything else falls
back to the generic timeline engine; ``Cluster(lockstep=True)`` turns the
fallback into a hard error naming the reason.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import EngineResult
from .scenario import (
    Affine,
    AffineRun,
    EmitOp,
    EmitRun,
    LoopEmit,
    LoopPhase,
    LoopSpec,
    PhaseSpec,
    as_symbolic,
)

__all__ = [
    "LockstepEngine",
    "UnsupportedProgram",
    "lockstep_support",
    "plan_stages",
]


class UnsupportedProgram(Exception):
    """Raised during compilation when the program shape doesn't fit."""


def lockstep_support(cluster) -> Optional[str]:
    """Why this cluster cannot use the bulk lockstep solver, or None.

    Callers check :func:`~repro.core.cohort_timeline.timeline_support` first
    (SPIN, no perturbation, one shared program per device); this adds the
    solver's own structural requirements.  A ``None`` here still requires a
    successful :meth:`LockstepEngine.compile` — the compile step verifies the
    affine wait/emit patterns rank by rank and returns its own reason when
    they don't fit.
    """
    cfg = cluster.cfg
    n = cfg.n_devices
    if n < 2:
        return "bulk solver needs at least 2 devices"
    if cluster.collect_segments:
        return (
            "segment collection needs per-phase spans "
            "(handled by the generic timeline engine)"
        )
    if cluster._san is not None:
        return "traffic sanitization observes individual write enactments"
    fab = cluster.fabric
    rcls = type(fab.spec.routing).__name__
    supported = {
        "ring": "_RingRouting",
        "two_tier": "_TwoTierRouting",
        "fat_tree": "_FatTreeRouting",
        "rail_optimized": "_RailRouting",
    }
    if supported.get(fab.spec.name) != rcls:
        return (
            f"fabric {fab.spec.name!r} (routing {rcls}) is outside the "
            "lockstep presets (ring, two_tier, fat_tree, rail_optimized)"
        )
    if "ici" not in fab._cls:
        return f"fabric {fab.spec.name!r} lacks an 'ici' link class"
    for node in cluster.nodes:
        if node.monitor is not None:
            return "monitor-based sync is per-write; lockstep needs SPIN"
        if len(node.wtt):
            return (
                "seed writes pre-registered in a WTT (warm start) need the "
                "event calendar"
            )
        cohorts = node.target.cohorts
        if not cohorts:
            return f"device {node.device_id} has no workgroup cohorts"
        if as_symbolic(cohorts[0].phases) is None:
            return (
                f"device {node.device_id} runs a flat (non-symbolic) phase "
                "program; only SymbolicPrograms compile to loop stages"
            )
    return None


# ---------------------------------------------------------------------------
# compiled plan
# ---------------------------------------------------------------------------


class _SingleEmit:
    """One message per rank per iteration: rank r -> dst(r, k), flag address
    addr(r, k), both affine in the loop index ``k``."""

    __slots__ = (
        "dst_base", "dst_step", "addr_base", "addr_step",
        "payload", "size", "dw",
    )

    def __init__(self, dst_base, dst_step, addr_base, addr_step,
                 payload, size, dw):
        self.dst_base = dst_base      # int64[n]
        self.dst_step = dst_step      # int
        self.addr_base = addr_base    # int64[n]
        self.addr_step = addr_step    # int
        self.payload = payload
        self.size = size
        self.dw = dw


class _FanoutEmit:
    """All-peers fan-out: rank r sends one message to every other rank in
    ascending order, all carrying rank r's flag address ``addr_vec[r]``."""

    __slots__ = ("addr_vec", "payload", "size", "dw")

    def __init__(self, addr_vec, payload, size, dw):
        self.addr_vec = addr_vec      # int64[n]
        self.payload = payload
        self.size = size
        self.dw = dw


class _PhasePlan:
    __slots__ = ("name", "is_wait", "dur", "tdelta", "wait", "emit")

    def __init__(self, name, is_wait, dur, tdelta, wait, emit):
        self.name = name
        self.is_wait = is_wait
        self.dur = dur
        self.tdelta = tdelta
        # wait: None | ("single", base_vec, step) | ("allpeers", alpha, beta)
        self.wait = wait
        self.emit = emit


class _Seg:
    __slots__ = ("count", "k0", "body")

    def __init__(self, count, k0, body):
        self.count = count
        self.k0 = k0
        self.body = body


class _Plan:
    __slots__ = ("segs", "wait_src", "counts", "dispatch", "total", "n_stages")

    def __init__(self, segs, wait_src, counts, dispatch, total, n_stages):
        self.segs = segs
        self.wait_src = wait_src  # stage_id -> ("single", src, perm)|("allpeers", src)
        self.counts = counts      # int64[nc], rank-uniform cohort sizes
        self.dispatch = dispatch  # int64[nc], rank-uniform dispatch cycles
        self.total = total        # workgroups per rank
        self.n_stages = n_stages


def _uniform(values, what):
    it = iter(values)
    first = next(it)
    for v in it:
        if v != first:
            raise UnsupportedProgram(f"{what} varies across ranks")
    return first


def _wait_runs_of(entries, k0, count, n):
    """Normalize one rank's wait entries to ``(start, stride, count)`` runs.

    Entries must be k-invariant (ints or :class:`AffineRun`); an ``Affine``
    with step 0 degenerates to an int.  Used only for the all-peers pattern —
    the single-address pattern handles k-varying ``Affine`` entries directly.
    """
    runs = []
    for e in entries:
        if isinstance(e, AffineRun):
            runs.append((e.start, e.stride, e.count))
        elif isinstance(e, Affine):
            if e.step != 0 and count > 1:
                raise UnsupportedProgram(
                    "k-varying wait address inside an all-peers barrier"
                )
            runs.append((e.at(k0), 0, 1))
        elif isinstance(e, int):
            runs.append((e, 0, 1))
        else:
            raise UnsupportedProgram(f"unsupported wait entry {type(e).__name__}")
    return runs


def _classify_wait(specs, k0, count, n):
    """("single", base_vec, step) or ("allpeers", alpha, beta)."""
    # -- one address per rank per iteration ------------------------------
    single = True
    for sp in specs:
        entries = sp.wait_addrs
        if len(entries) != 1 or isinstance(entries[0], AffineRun) and \
                entries[0].count != 1:
            single = False
            break
    if single:
        base = np.empty(len(specs), np.int64)
        steps = set()
        for r, sp in enumerate(specs):
            e = sp.wait_addrs[0]
            if isinstance(e, Affine):
                base[r] = e.base
                steps.add(e.step if count > 1 else 0)
                if count <= 1:
                    base[r] = e.at(k0)
            elif isinstance(e, AffineRun):
                base[r] = e.start
                steps.add(0)
            else:
                base[r] = int(e)
                steps.add(0)
        if len(steps) != 1:
            raise UnsupportedProgram("wait address step varies across ranks")
        return ("single", base, steps.pop())
    # -- all-peers barrier: writers 0..n-1 minus self, ascending ---------
    # derive the writer-affine (alpha, beta) from rank n-1, whose single
    # run covers writers 0..n-2
    last = specs[n - 1].wait_addrs
    runs_last = _wait_runs_of(last, k0, count, n)
    if len(runs_last) != 1 or runs_last[0][2] != n - 1:
        raise UnsupportedProgram("wait entries do not form an all-peers barrier")
    alpha = runs_last[0][0]
    beta = runs_last[0][1] if n - 1 >= 2 else 0
    for r, sp in enumerate(specs):
        runs = _wait_runs_of(sp.wait_addrs, k0, count, n)
        below = (alpha, beta, r)
        above = (alpha + beta * (r + 1), beta, n - 1 - r)
        want = [x for x in (below, above) if x[2] > 0]
        if len(runs) != len(want):
            raise UnsupportedProgram("wait entries do not form an all-peers barrier")
        for got, exp in zip(runs, want):
            ok = got[0] == exp[0] and got[2] == exp[2] and (
                got[2] == 1 or got[1] == exp[1]
            )
            if not ok:
                raise UnsupportedProgram(
                    "wait entries do not form an all-peers barrier"
                )
    return ("allpeers", alpha, beta)


def _classify_emit(amap, specs, k0, count, n):
    """None, :class:`_SingleEmit`, or :class:`_FanoutEmit`."""
    if not specs[0].emits:
        for sp in specs:
            if sp.emits:
                raise UnsupportedProgram("emit presence varies across ranks")
        return None
    nranks = len(specs)
    first = specs[0].emits
    if len(first) == 1 and isinstance(first[0], (LoopEmit, EmitOp)):
        dst_base = np.empty(nranks, np.int64)
        dst_steps, payloads, sizes, dws = set(), set(), set(), set()
        slots = []  # per-rank (slot_base, slot_step)
        for r, sp in enumerate(specs):
            if len(sp.emits) != 1:
                raise UnsupportedProgram("emit count varies across ranks")
            e = sp.emits[0]
            if isinstance(e, LoopEmit):
                if e.coalesce != "last":
                    raise UnsupportedProgram("per-workgroup ('each') emission")
                dst_base[r] = e.dst.base
                dst_steps.add(e.dst.step if count > 1 else 0)
                if count <= 1:
                    dst_base[r] = e.dst.at(k0)
                slots.append((e.slot.base, e.slot.step if count > 1 else 0)
                             if count > 1 else (e.slot.at(k0), 0))
            elif isinstance(e, EmitOp):
                if e.coalesce != "last":
                    raise UnsupportedProgram("per-workgroup ('each') emission")
                if e.addr is not None:
                    raise UnsupportedProgram("explicit EmitOp.addr override")
                dst_base[r] = e.dst
                dst_steps.add(0)
                slots.append((e.slot, 0))
            else:
                raise UnsupportedProgram(
                    f"unsupported emit entry {type(e).__name__}"
                )
            payloads.add(e.payload_bytes)
            sizes.add(e.size)
            dws.add(e.data_writes)
        if len(dst_steps) != 1 or len(payloads) != 1 or len(sizes) != 1 \
                or len(dws) != 1:
            raise UnsupportedProgram("emit parameters vary across ranks")
        dst_step = dst_steps.pop()
        # flag addresses: addr(r, k) = flag_addr(r, slot_r(k)), verified
        # affine in k over the full loop range (never assumed from layout)
        addr_base = np.empty(nranks, np.int64)
        addr_steps = set()
        for r, (sb, ss) in enumerate(slots):
            a0 = amap.flag_addr(r, sb + ss * k0)
            if count > 1:
                a1 = amap.flag_addr(r, sb + ss * (k0 + 1))
                step = a1 - a0
                klast = k0 + count - 1
                if amap.flag_addr(r, sb + ss * klast) != a0 + step * (
                    count - 1
                ):
                    raise UnsupportedProgram(
                        "flag address is not affine over the loop range"
                    )
            else:
                step = 0
            addr_steps.add(step)
            addr_base[r] = a0 - step * k0
        if len(addr_steps) != 1:
            raise UnsupportedProgram("flag address step varies across ranks")
        # destination sanity over the whole k range (affine in k, so the
        # endpoints bound the range; self-sends can only occur at one k)
        ranks = np.arange(nranks, dtype=np.int64)
        for kk in (k0, k0 + max(count - 1, 0)):
            d = dst_base + dst_step * kk
            if d.min() < 0 or d.max() >= n:
                raise UnsupportedProgram("emit destination out of range")
        if dst_step == 0:
            if np.any(dst_base == ranks):
                raise UnsupportedProgram("self-directed emission")
        else:
            for r in range(nranks):
                num = r - int(dst_base[r])
                if num % dst_step == 0 and \
                        k0 <= num // dst_step < k0 + count:
                    raise UnsupportedProgram("self-directed emission")
        return _SingleEmit(
            dst_base, dst_step, addr_base, addr_steps.pop(),
            payloads.pop(), sizes.pop(), dws.pop(),
        )
    # -- all-peers fan-out: EmitRuns below/above self, ascending ----------
    payloads, sizes, dws, slot0s = set(), set(), set(), set()
    for r, sp in enumerate(specs):
        want = [(0, r), (r + 1, n - 1 - r)]
        want = [w for w in want if w[1] > 0]
        if len(sp.emits) != len(want):
            raise UnsupportedProgram("emits do not form an all-peers fan-out")
        for e, (d0, cnt) in zip(sp.emits, want):
            if not isinstance(e, EmitRun):
                raise UnsupportedProgram("emits do not form an all-peers fan-out")
            if e.coalesce != "last":
                raise UnsupportedProgram("per-workgroup ('each') emission")
            ok = e.dst0 == d0 and e.count == cnt and e.slot_stride == 0 and (
                e.count == 1 or e.dst_stride == 1
            )
            if not ok:
                raise UnsupportedProgram("emits do not form an all-peers fan-out")
            payloads.add(e.payload_bytes)
            sizes.add(e.size)
            dws.add(e.data_writes)
            slot0s.add(e.slot0)
    if len(payloads) != 1 or len(sizes) != 1 or len(dws) != 1 \
            or len(slot0s) != 1:
        raise UnsupportedProgram("fan-out parameters vary across ranks")
    slot0 = slot0s.pop()
    addr_vec = np.array(
        [amap.flag_addr(r, slot0) for r in range(len(specs))], np.int64
    )
    return _FanoutEmit(addr_vec, payloads.pop(), sizes.pop(), dws.pop())


def _phase_plan(amap, n, tdelta_for, specs, k0, count):
    """Compile one aligned body-phase position across all ranks."""
    s0 = specs[0]
    name = s0.name
    is_wait = s0.wait_addrs is not None
    for sp in specs:
        if sp.name != name or (sp.wait_addrs is not None) != is_wait:
            raise UnsupportedProgram("phase structure varies across ranks")
    dur = 0 if is_wait else _uniform(
        (sp.duration_cycles for sp in specs), "phase duration"
    )
    _uniform((sp.traffic for sp in specs), "phase traffic")
    tdelta = tdelta_for(s0) if tdelta_for is not None else None
    wait = emit = None
    if is_wait:
        wait = _classify_wait(specs, k0, count, n)
        for sp in specs:
            if sp.emits:
                raise UnsupportedProgram("wait phase with emissions")
    else:
        emit = _classify_emit(amap, specs, k0, count, n)
    return _PhasePlan(name, is_wait, dur, tdelta, wait, emit)


def _verify_ring_routes(fab, n) -> None:
    """Spot-check the fabric against the solver's replicated ring router."""
    srcs = sorted({0, 1, n // 2, n - 1})
    for src in srcs:
        for dst in sorted({(src + 1) % n, (src - 1) % n, (src + n // 2) % n}):
            if dst == src:
                continue
            fwd = (dst - src) % n
            bwd = (src - dst) % n
            hops, d = (fwd, 1) if fwd <= bwd else (bwd, -1)
            legs = fab.legs(src, dst)
            if len(legs) != 1:
                raise UnsupportedProgram("multi-leg route on the flat ring")
            leg = legs[0]
            if leg.cls != "ici" or leg.port != (src, d) or leg.hops != hops:
                raise UnsupportedProgram(
                    "fabric routes diverge from the flat ring router"
                )


def plan_stages(amap, n, progs, tdelta_for=None) -> _Plan:
    """Compile rank-aligned symbolic programs into the stage plan.

    This is the engine-independent half of lockstep compilation: segment
    alignment, affine wait/emit classification, and the symbolic wait<->
    emission matching that proves every wait is satisfied by a strictly
    earlier emission (lex order over (segment, k, body position)) — one
    node per (lane, affine pattern), never one per step.  The static
    verifier (:mod:`repro.analysis.verify`) reuses it with
    ``tdelta_for=None`` to check loop-space dependency graphs at pod scale
    without materializing O(devices x steps) sites.

    Raises :class:`UnsupportedProgram` when the programs are not rank-uniform or
    a pattern falls outside the affine single-peer / all-peers families.
    The returned plan's cohort fields (``counts``/``dispatch``/``total``)
    are unset; :func:`_compile` fills them for the runtime solver.
    """
    nsegs = _uniform((len(p.segments) for p in progs), "segment count")
    segs: List[_Seg] = []
    for j in range(nsegs):
        col = [p.segments[j] for p in progs]
        s0 = col[0]
        if isinstance(s0, LoopSpec):
            for s in col:
                if not isinstance(s, LoopSpec) or s.count != s0.count \
                        or s.k0 != s0.k0 or len(s.body) != len(s0.body):
                    raise UnsupportedProgram("loop structure varies across ranks")
            body = [
                _phase_plan(
                    amap, n, tdelta_for, [s.body[b] for s in col],
                    s0.k0, s0.count,
                )
                for b in range(len(s0.body))
            ]
            segs.append(_Seg(s0.count, s0.k0, body))
        else:
            # literal segments (PhaseSpec or LoopPhase at k=0) are compiled
            # symbolically — materializing LoopPhase.at(0) would expand
            # EmitRuns into O(n) EmitOps per rank, O(n^2) for the pod
            for s in col:
                if isinstance(s, LoopSpec):
                    raise UnsupportedProgram("segment kinds vary across ranks")
            segs.append(
                _Seg(1, 0, [_phase_plan(amap, n, tdelta_for, col, 0, 1)])
            )

    # ---- symbolic wait<->emission matching over the full stage sequence
    wait_src: Dict[int, tuple] = {}
    open_recs: List[list] = []  # [stage_id, kind, dst_vec, addr_vec]
    perm_cache: Dict[bytes, np.ndarray] = {}
    ar = np.arange(n, dtype=np.int64)
    stage_id = 0
    for seg in segs:
        for k in range(seg.k0, seg.k0 + seg.count):
            for pp in seg.body:
                if pp.is_wait:
                    kind = pp.wait[0]
                    hit = None
                    if kind == "single":
                        want = pp.wait[1] + pp.wait[2] * k
                        for idx in range(len(open_recs) - 1, -1, -1):
                            sid, rkind, dstv, addrv = open_recs[idx]
                            if rkind != "single":
                                # at n == 2 the all-peers fan-out is a
                                # single exchange; a one-address wait can
                                # consume it as an all-peers barrier
                                if n == 2 and np.array_equal(
                                    addrv[::-1], want
                                ):
                                    del open_recs[idx]
                                    hit = ("allpeers", sid)
                                    break
                                continue
                            inv = np.empty(n, np.int64)
                            inv[dstv] = ar
                            if np.array_equal(addrv[inv], want):
                                del open_recs[idx]
                                key = inv.tobytes()
                                perm = perm_cache.get(key)
                                if perm is None:
                                    perm = perm_cache[key] = inv
                                hit = ("single", sid, perm)
                                break
                    else:
                        want = pp.wait[1] + pp.wait[2] * ar
                        for idx in range(len(open_recs) - 1, -1, -1):
                            sid, rkind, _dstv, addrv = open_recs[idx]
                            if rkind != "fanout":
                                continue
                            if np.array_equal(addrv, want):
                                del open_recs[idx]
                                hit = ("allpeers", sid)
                                break
                    if hit is None:
                        raise UnsupportedProgram(
                            f"wait phase {pp.name!r} (k={k}) has no matching "
                            "earlier emission"
                        )
                    wait_src[stage_id] = hit
                elif isinstance(pp.emit, _SingleEmit):
                    e = pp.emit
                    dstv = e.dst_base + e.dst_step * k
                    if not np.array_equal(np.bincount(dstv, minlength=n),
                                          np.ones(n, dtype=np.int64)):
                        raise UnsupportedProgram(
                            "emission destinations are not a permutation"
                        )
                    addrv = e.addr_base + e.addr_step * k
                    open_recs.append([stage_id, "single", dstv, addrv])
                elif isinstance(pp.emit, _FanoutEmit):
                    open_recs.append(
                        [stage_id, "fanout", None, pp.emit.addr_vec]
                    )
                stage_id += 1
    return _Plan(segs, wait_src, None, None, 0, stage_id)


def _compile(cluster) -> _Plan:
    """Full runtime compile: fabric spot-check, cohort uniformity, and the
    engine-independent stage plan (:func:`plan_stages`)."""
    cfg = cluster.cfg
    n = cfg.n_devices
    _verify_ring_routes(cluster.fabric, n)
    progs = [
        as_symbolic(node.target.cohorts[0].phases) for node in cluster.nodes
    ]
    # rank-uniform cohort shape: same sizes and dispatch cycles everywhere
    c0 = cluster.nodes[0].target.cohorts
    counts = np.array([c.count for c in c0], np.int64)
    dispatch = np.array([c.program.dispatch_cycle for c in c0], np.int64)
    for node in cluster.nodes[1:]:
        cs = node.target.cohorts
        if len(cs) != len(c0) or any(
            a.count != b.count
            or a.program.dispatch_cycle != b.program.dispatch_cycle
            for a, b in zip(cs, c0)
        ):
            raise UnsupportedProgram("cohort shapes vary across ranks")
    plan = plan_stages(
        cluster.amap, n, progs,
        tdelta_for=cluster.nodes[0].target._tdelta_for,
    )
    plan.counts = counts
    plan.dispatch = dispatch
    plan.total = int(counts.sum())
    return plan


# ---------------------------------------------------------------------------
# the solver
# ---------------------------------------------------------------------------


class LockstepEngine:
    """Vectorized pod-scale solve of a compiled rank-uniform program."""

    def __init__(self, cluster):
        self.cluster = cluster
        self._plan: Optional[_Plan] = None
        self._tiered = None
        self.breakdown: Dict[str, float] = {}

    def compile(self, reuse=None) -> Optional[str]:
        """Build the stage plan; returns a fallback reason or None.

        The flat single-tier ring keeps the original rank-uniform stage
        plan; every other supported preset compiles through the tiered
        group-uniform solver (:mod:`repro.core.lockstep_tiered`).
        Compilation mutates nothing, so a failure here falls back to the
        generic timeline engine cleanly.

        ``reuse`` accepts a :meth:`plan_handle` compiled for an identical
        (scenario, config, fabric) point — plans are read-only at run time,
        so a sweep revisiting the same shape skips recompilation.
        """
        t0 = time.perf_counter()
        if reuse is not None:
            kind, plan = reuse
            if kind == "tiered":
                self._tiered = plan
            else:
                self._plan = plan
            self.breakdown["compile_s"] = time.perf_counter() - t0
            self.breakdown["compile_cached"] = 1.0
            return None
        fab = self.cluster.fabric
        try:
            if fab.spec.name == "ring" and fab.n_nodes == 1:
                self._plan = _compile(self.cluster)
            else:
                from .lockstep_tiered import compile_tiered

                self._tiered = compile_tiered(self.cluster)
        except UnsupportedProgram as e:
            return str(e)
        except ValueError as e:  # e.g. address-map probing out of range
            return f"symbolic program probing failed: {e}"
        self.breakdown["compile_s"] = time.perf_counter() - t0
        return None

    def plan_handle(self):
        """The compiled plan as an opaque (kind, plan) pair for reuse via
        ``compile(reuse=...)``; None before a successful compile."""
        if self._tiered is not None:
            return ("tiered", self._tiered)
        if self._plan is not None:
            return ("flat", self._plan)
        return None

    def run(self) -> EngineResult:
        if self._tiered is not None:
            from .lockstep_tiered import run_tiered

            return run_tiered(self.cluster, self._tiered, self.breakdown)
        t0 = time.perf_counter()
        plan = self._plan
        assert plan is not None, "compile() must succeed before run()"
        cluster = self.cluster
        cfg = cluster.cfg
        n = cfg.n_devices
        clock = cfg.clock_ghz
        poll = cfg.poll_interval_cycles
        check = cfg.flag_check_cycles
        xgmi_lat = cfg.xgmi_enact_latency_ns
        include_dw = cfg.include_data_writes
        fab = cluster.fabric
        bw, lat = fab._cls["ici"]
        counts = plan.counts
        total = plan.total
        ar = np.arange(n, dtype=np.int64)

        # cursor matrix: every rank starts its cohorts at the dispatch cycles
        T = np.tile(plan.dispatch, (n, 1))
        # per-rank traffic that varies by rank (spin reads); rank-uniform
        # categories accumulate as plain ints
        fr = np.zeros(n, np.int64)
        rb = np.zeros(n, np.int64)
        u_nfr = u_rb = u_lw = u_wb = u_xo = u_xob = 0
        u_xi = u_xib = u_reg = u_marks = 0
        # fabric state: the flat ring's ports are (rank, +-1); busy chains,
        # port stats, and the used-port masks (only touched ports get busy
        # entries written back, matching the engine's lazy dict)
        busy = {
            1: np.array(
                [fab._busy_until_ns.get((r, 1), 0.0) for r in range(n)]
            ),
            -1: np.array(
                [fab._busy_until_ns.get((r, -1), 0.0) for r in range(n)]
            ),
        }
        used = {1: np.zeros(n, bool), -1: np.zeros(n, bool)}
        pcnt = {1: np.zeros(n, np.int64), -1: np.zeros(n, np.int64)}
        pbyt = {1: np.zeros(n, np.int64), -1: np.zeros(n, np.int64)}
        pqd = {1: np.zeros(n), -1: np.zeros(n)}
        g_msgs = 0
        g_bytes = 0
        g_q = 0.0
        setcycs: Dict[int, np.ndarray] = {}
        max_set = 0
        seq_add = 0

        def spin(V):
            """One wait address against the cursor matrix: the interpreter's
            unified closed form, vectorized over ranks x cohorts."""
            nonlocal fr, rb, T
            nt = V[:, None] - T
            nt += poll - 1
            nt //= poll
            np.maximum(nt, 0, out=nt)
            m = nt @ counts
            m += total
            fr += m
            rb += 8 * m
            nt *= poll
            nt += check
            T += nt

        stage_id = 0
        for seg in plan.segs:
            for k in range(seg.k0, seg.k0 + seg.count):
                for pp in seg.body:
                    if pp.is_wait:
                        src = plan.wait_src[stage_id]
                        if src[0] == "single":
                            sc = setcycs.pop(src[1])
                            spin(sc[src[2]])
                        else:
                            M = setcycs.pop(src[1])
                            for j in range(n - 1):
                                g = np.where(ar > j, j, j + 1)
                                spin(M[g, ar])
                    else:
                        if pp.dur:
                            T += pp.dur
                        e = pp.emit
                        if e is not None:
                            E = T.max(axis=1)
                            issue = E / clock
                            nb = e.payload + e.size
                            dw = e.dw if include_dw and e.dw > 0 else 0
                            regs = 1 + dw
                            if isinstance(e, _SingleEmit):
                                ser = nb / bw
                                dstv = e.dst_base + e.dst_step * k
                                off = (dstv - ar) % n
                                hops = np.minimum(off, n - off)
                                dirs = np.where(2 * off <= n, 1, -1)
                                arrns = np.empty(n)
                                for dval in (1, -1):
                                    msk = dirs == dval
                                    if not msk.any():
                                        continue
                                    b = busy[dval]
                                    st = np.maximum(issue[msk], b[msk])
                                    nbsy = st + ser
                                    b[msk] = nbsy
                                    used[dval][msk] = True
                                    q = st - issue[msk]
                                    arrns[msk] = nbsy + hops[msk] * lat
                                    pcnt[dval][msk] += 1
                                    pbyt[dval][msk] += nb
                                    pqd[dval][msk] += q
                                    g_q += float(np.cumsum(q)[-1])
                                g_msgs += n
                                g_bytes += n * nb
                                wake = arrns + xgmi_lat
                                minns = (E + 1) / clock
                                np.maximum(wake, minns, out=wake)
                                sc = np.rint(wake * clock).astype(np.int64)
                                setcycs[stage_id] = sc
                                ms = int(sc.max())
                                if ms > max_set:
                                    max_set = ms
                                u_xo += 1
                                u_xob += e.size
                                u_xi += regs
                                u_xib += e.size + 8 * dw
                                u_reg += regs
                                u_marks += dw
                                seq_add += n * regs
                            else:  # _FanoutEmit
                                M = np.zeros((n, n), np.int64)
                                for r in range(n):
                                    iss = float(E[r]) / clock
                                    ds = np.concatenate(
                                        (ar[:r], ar[r + 1:])
                                    )
                                    off = (ds - r) % n
                                    hops = np.minimum(off, n - off)
                                    pos = 2 * off <= n
                                    minns = (float(E[r]) + 1.0) / clock
                                    for dval, msk in ((1, pos), (-1, ~pos)):
                                        cnt = int(msk.sum())
                                        if not cnt:
                                            continue
                                        b0 = float(busy[dval][r])
                                        start0 = max(iss, b0)
                                        # the exact per-port cumsum chain of
                                        # FabricModel.transfer_batch
                                        chain = np.empty(cnt + 1)
                                        chain[0] = start0
                                        chain[1:] = nb / bw
                                        bs = np.cumsum(chain)
                                        busy[dval][r] = float(bs[-1])
                                        used[dval][r] = True
                                        arrm = bs[1:] + hops[msk] * lat
                                        q = bs[:-1] - iss
                                        pcnt[dval][r] += cnt
                                        pbyt[dval][r] += cnt * nb
                                        pqd[dval][r] += float(
                                            np.cumsum(q)[-1]
                                        )
                                        g_q += float(np.cumsum(q)[-1])
                                        wake = arrm + xgmi_lat
                                        np.maximum(wake, minns, out=wake)
                                        M[r, ds[msk]] = np.rint(
                                            wake * clock
                                        ).astype(np.int64)
                                setcycs[stage_id] = M
                                ms = int(M.max())
                                if ms > max_set:
                                    max_set = ms
                                g_msgs += n * (n - 1)
                                g_bytes += n * (n - 1) * nb
                                u_xo += n - 1
                                u_xob += (n - 1) * e.size
                                u_xi += (n - 1) * regs
                                u_xib += (n - 1) * (e.size + 8 * dw)
                                u_reg += (n - 1) * regs
                                u_marks += (n - 1) * dw
                                seq_add += n * (n - 1) * regs
                    d = pp.tdelta
                    if d is not None:
                        u_nfr += d[0] * total
                        u_rb += d[1] * total
                        u_lw += d[2] * total
                        u_wb += d[3] * total
                        u_xo += d[4] * total
                        u_xob += d[5] * total
                    stage_id += 1

        solve_done = time.perf_counter()

        # ---- write-back -------------------------------------------------
        kend = T.max(axis=1)
        sim_cycles = max(int(kend.max()), max_set)
        for r, node in enumerate(self.cluster.nodes):
            t = node.memory.traffic
            t.flag_reads += int(fr[r])
            t.nonflag_reads += u_nfr
            t.read_bytes += int(rb[r]) + u_rb
            t.local_writes += u_lw
            t.write_bytes += u_wb
            t.xgmi_writes_out += u_xo
            t.xgmi_bytes_out += u_xob
            t.xgmi_writes_in += u_xi
            t.xgmi_bytes_in += u_xib
            tgt = node.target
            tgt.done_count = tgt.n_wgs
            tgt.kernel_end_cycle = int(kend[r])
            ws = node.wtt.stats
            ws.registered += u_reg
            ws.enacted += u_reg
            if u_marks:
                cluster._data_marks[r] = (
                    cluster._data_marks.get(r, 0) + u_marks
                )
        cluster._seq += seq_add
        st = fab.stats
        st["messages"] += g_msgs
        st["bytes"] += g_bytes
        st["queued_ns"] += g_q
        st["ici_messages"] += g_msgs
        st["ici_bytes"] += g_bytes
        st["ici_queued_ns"] += g_q
        for dval in (1, -1):
            um = used[dval]
            for r in np.flatnonzero(um):
                r = int(r)
                port = (r, dval)
                fab._busy_until_ns[port] = float(busy[dval][r])
                ps = fab.port_stats.get(port)
                if ps is None:
                    ps = fab.port_stats[port] = [0, 0, 0.0]
                ps[0] += int(pcnt[dval][r])
                ps[1] += int(pbyt[dval][r])
                ps[2] += float(pqd[dval][r])
        run_wall = time.perf_counter() - t0
        self.breakdown.update(
            solve_s=solve_done - t0,
            writeback_s=run_wall - (solve_done - t0),
        )
        return EngineResult(
            sim_cycles=sim_cycles,
            # the compile pass is part of this engine's cost; include it so
            # wall_time_s >= sum(breakdown.values())
            wall_time_s=run_wall + self.breakdown.get("compile_s", 0.0),
            head_polls=0,
            breakdown=self.breakdown,
        )
