"""Roofline terms and step-time prediction from dry-run artifacts.

Implements the assignment's three-term roofline over the per-device SPMD
module (``cost_analysis()`` and the parsed collective schedule are both
per-device, so the spec's ``X_global / (chips * rate)`` equals our
``X_per_device / rate``):

  compute_s    = HLO_FLOPs   / peak_FLOP/s
  memory_s     = HLO_bytes   / HBM_bw
  collective_s = coll_bytes  / link_bw

plus Eidola-refined collective time (topology-aware ring algebra instead of
the flat link-bandwidth division) and a step-time envelope.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence

from .hlo_capture import CollectiveOp, collective_bytes
from .topology import Topology

__all__ = ["RooflineTerms", "roofline", "StepPrediction", "predict_step"]


@dataclass(frozen=True)
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_device: float
    hlo_bytes_per_device: float
    collective_bytes_per_device: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_flops_ratio: float     # MODEL_FLOPS / (HLO_FLOPs * chips)
    bytes_per_device_hbm: int     # from memory_analysis (args+temps+outs)
    fits_hbm: bool
    note: str = ""

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent on useful model FLOPs.

        = (MODEL_FLOPS/chips/peak) / max(terms): 1.0 means the step is
        entirely useful compute at peak; lower means waste (redundant FLOPs,
        memory- or collective-bound execution).
        """
        if self.bound_s <= 0:
            return 0.0
        useful_s = self.compute_s * self.useful_flops_ratio
        return useful_s / self.bound_s

    def as_dict(self) -> Dict:
        d = asdict(self)
        d["bound_s"] = self.bound_s
        d["roofline_fraction"] = self.roofline_fraction()
        return d


def roofline(
    *,
    arch: str,
    shape: str,
    mesh: str,
    topo: Topology,
    hlo_flops_per_device: float,
    hlo_bytes_per_device: float,
    collective_ops: Sequence[CollectiveOp] = (),
    collective_bytes_per_device: Optional[int] = None,
    model_flops_total: float = 0.0,
    bytes_per_device_hbm: int = 0,
    collective_axis: Optional[str] = None,
    note: str = "",
) -> RooflineTerms:
    hw = topo.hw
    coll_bytes = (
        collective_bytes_per_device
        if collective_bytes_per_device is not None
        else collective_bytes(collective_ops)
    )
    compute_s = hlo_flops_per_device / hw.peak_flops_bf16
    memory_s = hlo_bytes_per_device / hw.hbm_bw
    collective_s = topo.flat_collective_seconds(coll_bytes, collective_axis)
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)  # type: ignore[arg-type]
    chips = topo.n_chips
    hlo_total = hlo_flops_per_device * chips
    useful = model_flops_total / hlo_total if hlo_total > 0 else 0.0
    return RooflineTerms(
        arch=arch,
        shape=shape,
        mesh=mesh,
        chips=chips,
        hlo_flops_per_device=hlo_flops_per_device,
        hlo_bytes_per_device=hlo_bytes_per_device,
        collective_bytes_per_device=coll_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=model_flops_total,
        useful_flops_ratio=useful,
        bytes_per_device_hbm=bytes_per_device_hbm,
        fits_hbm=bytes_per_device_hbm <= hw.hbm_bytes,
        note=note,
    )


@dataclass(frozen=True)
class StepPrediction:
    """Step-time envelope with and without compute/comm overlap."""

    no_overlap_s: float        # compute-or-memory bound + all collectives
    full_overlap_s: float      # max(compute, memory, collective)
    eidola_collective_s: float # topology-aware (ring algebra) collective time
    exposed_comm_s: float      # collective time not hideable under compute

    def as_dict(self) -> Dict:
        return asdict(self)


def predict_step(
    terms: RooflineTerms,
    topo: Topology,
    collective_ops: Sequence[CollectiveOp] = (),
    *,
    overlap_fraction: float = 0.0,
) -> StepPrediction:
    """Refine the flat collective term with ring algebra + overlap model.

    ``overlap_fraction`` is how much of collective time the schedule hides
    under compute (0 = paper-faithful sequential baseline; the framework's
    overlapped schedules raise it).
    """
    eidola_coll = 0.0
    default_axis = topo.axis_names[-1]
    for op in collective_ops:
        if op.group_size == 1:
            continue
        axis = default_axis
        for name, size in zip(topo.axis_names, topo.axis_sizes):
            if size == op.group_size:
                axis = name
                break
        eidola_coll += topo.collective(op.kind, op.operand_bytes, axis).time_s
    base = max(terms.compute_s, terms.memory_s)
    exposed = max(0.0, eidola_coll * (1.0 - overlap_fraction))
    return StepPrediction(
        no_overlap_s=base + eidola_coll,
        full_overlap_s=max(base, eidola_coll),
        eidola_collective_s=eidola_coll,
        exposed_comm_s=exposed,
    )
