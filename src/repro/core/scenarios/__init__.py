"""Built-in Eidola traffic scenarios.

Importing this package registers every built-in with the scenario registry
(:mod:`repro.core.scenario`):

* ``gemv_allreduce`` — the paper's fused GEMV+AllReduce kernel (Table 1),
  ported from the seed's hardwired workload model.
* ``ring_allreduce`` — chunked ring all-reduce; one wait/flag per ring step,
  arrival schedule synthesized from the collective cost model in
  :mod:`repro.core.topology`.
* ``all_to_all``     — MoE-dispatch-shaped incast: every peer pushes a token
  shard and a completion flag; the target barriers on all of them.
* ``pipeline_p2p``   — pipeline-parallel stage: per-microbatch activation
  wait -> forward compute -> p2p send to the next stage.
* ``hierarchical_allreduce`` — closed-loop cross-tier collective: intra-node
  ring reduce-scatter (ICI), leader ring all-reduce over the DCI uplinks,
  intra-node broadcast.
"""

from .all_to_all import AllToAllScenario
from .gemv_allreduce import GemvAllReduceScenario
from .hierarchical_allreduce import HierarchicalAllReduceScenario
from .pipeline_p2p import PipelineP2PScenario
from .ring_allreduce import RingAllReduceScenario

__all__ = [
    "AllToAllScenario",
    "GemvAllReduceScenario",
    "HierarchicalAllReduceScenario",
    "PipelineP2PScenario",
    "RingAllReduceScenario",
]
