"""All-to-all (MoE-dispatch-shaped) incast as an Eidola scenario.

Expert-parallel MoE dispatch is the canonical irregular pattern the paper
motivates: every device simultaneously pushes a token shard to every other
device, then barriers before the expert computation.  From the detailed
device's perspective this is an *incast*: n-1 peers each land a burst of data
writes followed by a completion flag, and every workgroup waits on all n-1
flags (exactly the fused kernel's wait structure, but with the compute phases
on the other side of the barrier).

Peer arrival times are the all-to-all cost from :mod:`repro.core.topology`
plus a configurable per-peer skew — sweeping ``skew_ns`` reproduces the
incast-straggler effect (flag traffic grows linearly in the last arrival under
SPIN, stays flat under SyncMon).
"""

from __future__ import annotations

import math
from typing import List, Optional

from ..config import SimConfig
from ..events import TraceBundle, register_phase
from ..memory import AddressMap
from ..scenario import (
    AffineRun,
    EmitOp,
    EmitRun,
    LoopPhase,
    PhaseSpec,
    Scenario,
    SymbolicProgram,
    WGProgram,
    affine_of,
    local_writes,
    reads,
    register_scenario,
    xgmi_out,
)
from ..topology import HardwareSpec, Topology, V5E

__all__ = ["AllToAllScenario"]

register_phase("a2a_dispatch", color="green", glyph="d")
register_phase("a2a_combine", color="brown", glyph="c")


@register_scenario
class AllToAllScenario(Scenario):
    """MoE-dispatch-shaped all-to-all incast with per-peer arrival skew."""

    name = "all_to_all"
    closed_loop_capable = True

    def __init__(
        self,
        cfg: SimConfig,
        amap: Optional[AddressMap] = None,
        *,
        tokens_per_device: int = 4096,
        token_bytes: int = 512,
        skew_ns: float = 2_000.0,
        writes_per_peer: int = 8,
        closed_loop: bool = False,
        devices_per_node: Optional[int] = None,
        fabric=None,
        link_bw=None,
        hw: HardwareSpec = V5E,
    ):
        super().__init__(cfg, amap)
        if tokens_per_device <= 0 or token_bytes <= 0:
            raise ValueError("tokens_per_device and token_bytes must be positive")
        self.tokens_per_device = int(tokens_per_device)
        self.token_bytes = int(token_bytes)
        self.skew_ns = float(skew_ns)
        self.writes_per_peer = int(writes_per_peer)
        self.closed_loop = bool(closed_loop)
        self.devices_per_node = devices_per_node
        self.hw = hw
        k = cfg.n_devices
        self.payload_bytes = self.tokens_per_device * self.token_bytes
        # Closed-loop fabric shape (flat when devices_per_node is unset,
        # fabric= selects any registered preset); the open-loop arrival
        # schedule keeps the flat single-tier algebra.
        self._setup_fabric(
            devices_per_node=devices_per_node, hw=hw, fabric=fabric,
            link_bw=link_bw,
        )
        # every rank announces dispatch completion in its slot-0 column
        self.amap.claim_flag_block("a2a_dispatch_barrier", 0, 1)
        self.cost = Topology.flat_ring(k, axis="ep", hw=hw).collective(
            "all-to-all", self.payload_bytes, "ep"
        )
        self.base_arrival_ns = self.cost.time_s * 1e9
        self.params = {
            "tokens_per_device": self.tokens_per_device,
            "token_bytes": self.token_bytes,
            "skew_ns": self.skew_ns,
            "closed_loop": self.closed_loop,
            "devices_per_node": self.devices_per_node,
            "fabric": self.fabric_name,
        }

    # ------------------------------------------------------------------

    def _shares(self) -> tuple:
        """Per-WG (bytes, sectors, cycles) of the local token shard."""
        cfg = self.cfg
        share = max(1, self.payload_bytes // cfg.workgroups)
        sectors = math.ceil(share / cfg.sector_bytes)
        cycles = max(1, math.ceil(sectors / cfg.wg_sector_throughput))
        return share, sectors, cycles

    def _flat_phases(self, rank: int, *, emit: bool):
        """Pre-refactor flat phase construction — O(devices) wait addresses
        and EmitOps per rank.  Kept as the reference oracle for
        ``SymbolicProgram.expand()`` equivalence (property-tested); runtime
        paths use :meth:`_symbolic_phases`."""
        cfg = self.cfg
        n_peers = cfg.n_egpus
        share, sectors, cycles = self._shares()
        peer_share = max(1, share // cfg.n_devices)
        peer_chunk = max(1, self.payload_bytes // cfg.n_devices)
        wait_addrs = tuple(
            self.amap.flag_addr(g) for g in range(cfg.n_devices) if g != rank
        )
        emits = (
            tuple(
                EmitOp(
                    g,
                    slot=0,
                    payload_bytes=peer_chunk,
                    data_writes=self.writes_per_peer,
                )
                for g in range(cfg.n_devices)
                if g != rank
            )
            if emit
            else ()
        )
        # open loop: each WG's flag pushes are closed-form traffic; closed
        # loop: the coalesced EmitOps account the (one-per-peer) flag writes
        dispatch_traffic = [
            reads(sectors, cfg.sector_bytes),
            xgmi_out(n_peers, peer_share),
        ]
        if not emit:
            dispatch_traffic.append(xgmi_out(n_peers, 8))
        return (
            # route + push our token shard to every peer, then the
            # completion flag write to each of them
            PhaseSpec(
                "a2a_dispatch",
                cycles,
                traffic=tuple(dispatch_traffic),
                emits=emits,
            ),
            # incast barrier on every peer's completion flag
            PhaseSpec("wait_flags", wait_addrs=wait_addrs),
            # combine: read the n-1 received shards + our own
            PhaseSpec(
                "a2a_combine",
                cycles * cfg.n_devices,
                traffic=(
                    reads(sectors * cfg.n_devices, cfg.sector_bytes),
                    local_writes(1, share),
                ),
            ),
        )

    def _symbolic_phases(self, rank: int, *, emit: bool) -> SymbolicProgram:
        """The same program as :meth:`_flat_phases`, compressed: the per-peer
        fan-out and the incast barrier's wait list become *within-phase* runs
        (:class:`EmitRun` / :class:`AffineRun`), split around our own rank —
        O(1) objects per rank in device count."""
        cfg = self.cfg
        n = cfg.n_devices
        n_peers = cfg.n_egpus
        share, sectors, cycles = self._shares()
        peer_share = max(1, share // n)
        peer_chunk = max(1, self.payload_bytes // n)
        # barrier flag addresses are affine in the writer id (verified over
        # the full device range, not assumed from the AddressMap layout)
        flag_aff = affine_of(lambda g: self.amap.flag_addr(g), 0, n)
        below, above = rank, n - 1 - rank
        wait_entries = tuple(
            AffineRun(flag_aff.at(g0), flag_aff.step, cnt)
            for g0, cnt in ((0, below), (rank + 1, above))
            if cnt
        )
        emit_entries = (
            tuple(
                EmitRun(
                    cnt,
                    dst0=g0,
                    payload_bytes=peer_chunk,
                    data_writes=self.writes_per_peer,
                )
                for g0, cnt in ((0, below), (rank + 1, above))
                if cnt
            )
            if emit
            else ()
        )
        dispatch_traffic = [
            reads(sectors, cfg.sector_bytes),
            xgmi_out(n_peers, peer_share),
        ]
        if not emit:
            dispatch_traffic.append(xgmi_out(n_peers, 8))
        return SymbolicProgram(
            (
                LoopPhase(
                    "a2a_dispatch",
                    cycles,
                    traffic=tuple(dispatch_traffic),
                    emits=emit_entries,
                ),
                LoopPhase("wait_flags", wait_addrs=wait_entries),
                PhaseSpec(
                    "a2a_combine",
                    cycles * n,
                    traffic=(
                        reads(sectors * n, cfg.sector_bytes),
                        local_writes(1, share),
                    ),
                ),
            ),
            group="all",
        )

    def _rank_programs(self, rank: int, *, emit: bool) -> List[WGProgram]:
        """Dispatch -> incast barrier -> combine, for one rank.

        ``rank`` waits on every peer's completion flag; with ``emit`` its own
        dispatch phase pushes a completion flag to each peer over the fabric
        (per-rank dispatch skew then *emerges* from dispatch compute + link
        serialization instead of the open-loop ``skew_ns`` constant).

        Phases are workgroup-invariant, so per-WG records are stamped against
        one shared :class:`SymbolicProgram` — O(1) construction per rank in
        device count, and the shared identity feeds the cohort interpreter's
        grouping.
        """
        cfg = self.cfg
        shared = self._symbolic_phases(rank, emit=emit)
        return [
            WGProgram(
                wg=wg,
                cu=wg % cfg.n_cus,
                dispatch_cycle=(wg // cfg.n_cus) * cfg.dispatch_stagger_cycles,
                phases=shared,
            )
            for wg in range(cfg.workgroups)
        ]

    def programs(self) -> List[WGProgram]:
        return self._rank_programs(0, emit=False)

    def programs_for(self, device: int) -> List[WGProgram]:
        if not self.closed_loop:
            return super().programs_for(device)
        return self._rank_programs(device, emit=True)

    def traces(self) -> TraceBundle:
        cfg = self.cfg
        bundle = TraceBundle(
            meta={
                "scenario": self.name,
                "n_devices": cfg.n_devices,
                "payload_bytes": self.payload_bytes,
                "base_arrival_ns": self.base_arrival_ns,
                "skew_ns": self.skew_ns,
            }
        )
        lead = cfg.data_write_lead_ns
        for g in range(1, cfg.n_devices):
            flag_t = self.base_arrival_ns + (g - 1) * self.skew_ns
            if cfg.include_data_writes and self.writes_per_peer > 0:
                t0 = max(0.0, flag_t - lead)
                for i in range(self.writes_per_peer):
                    t = t0 + (flag_t - t0) * (i + 1) / (self.writes_per_peer + 1)
                    bundle.add(
                        wakeup_ns=t,
                        addr=self.amap.partial_base
                        + (g * self.writes_per_peer + i) * 64,
                        data=0xE0 + g,
                        size=8,
                        src=g,
                    )
            bundle.add(
                wakeup_ns=flag_t,
                addr=self.amap.flag_addr(g),
                data=1,
                size=8,
                src=g,
            )
        return bundle
