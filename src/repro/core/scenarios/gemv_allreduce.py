"""The fused GEMV+AllReduce kernel (paper Fig. 3) as a registered scenario.

This is the seed's hardwired workload, re-expressed as a phase program:

  remote_tiles : partials for rows owned by peers  -> xGMI-written to owners
  flag_write   : flags[my_gpu] <- 1 on every peer
  local_tiles  : partials for rows owned locally   -> local writes
  wait_flags   : spin/monitor until every peer's flag is set locally
  reduce       : sum the n partials for each owned row
  broadcast    : push final rows to all peers

Durations, traffic attribution, and trace generation all come from the
existing :class:`repro.core.workload.GemvAllReduceWorkload` model, so the
scenario reproduces the seed's Table-1 numbers bit-for-bit (asserted in
tests/test_scenarios.py).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Union

from ..config import SimConfig
from ..events import TraceBundle
from ..memory import AddressMap
from ..scenario import (
    PhaseSpec,
    Scenario,
    WGProgram,
    local_writes,
    reads,
    register_scenario,
    xgmi_out,
)
from ..workload import GemvAllReduceWorkload, WGPlan, make_gemv_allreduce_traces

__all__ = ["GemvAllReduceScenario"]


@register_scenario
class GemvAllReduceScenario(Scenario):
    """Fused GEMV+AllReduce kernel (paper Table 1 / Fig. 3)."""

    name = "gemv_allreduce"

    def __init__(
        self,
        cfg: SimConfig,
        amap: Optional[AddressMap] = None,
        *,
        flag_delays_ns: Union[Sequence[float], float] = 10_000.0,
        workload: Optional[GemvAllReduceWorkload] = None,
    ):
        super().__init__(cfg, amap)
        self.workload = workload or GemvAllReduceWorkload(cfg, self.amap)
        self.flag_delays_ns = flag_delays_ns
        self.params = {"flag_delays_ns": flag_delays_ns}

    @classmethod
    def from_workload(
        cls, cfg: SimConfig, workload: GemvAllReduceWorkload
    ) -> "GemvAllReduceScenario":
        """Wrap an already-built workload model (back-compat path)."""
        return cls(cfg, workload.amap, workload=workload)

    # ------------------------------------------------------------------

    def _program(self, p: WGPlan) -> WGProgram:
        cfg = self.cfg
        n_peers = cfg.n_egpus
        data_bytes = cfg.elem_bytes * cfg.N
        wait_addrs = tuple(self.amap.flag_addr(g) for g in self.workload.flag_order())
        return WGProgram(
            wg=p.wg,
            cu=p.cu,
            dispatch_cycle=p.dispatch_cycle,
            phases=(
                PhaseSpec(
                    "remote_tiles",
                    p.remote_cycles,
                    traffic=(
                        reads(p.remote_sector_reads, cfg.sector_bytes),
                        xgmi_out(p.remote_xgmi_writes, data_bytes),
                    ),
                ),
                PhaseSpec(
                    "flag_write",
                    p.flag_write_cycles,
                    traffic=(xgmi_out(n_peers, 8),),
                ),
                PhaseSpec(
                    "local_tiles",
                    p.local_cycles,
                    traffic=(
                        reads(p.local_sector_reads, cfg.sector_bytes),
                        local_writes(p.local_partial_writes, data_bytes),
                    ),
                ),
                PhaseSpec("wait_flags", wait_addrs=wait_addrs),
                PhaseSpec(
                    "reduce",
                    p.reduce_cycles,
                    traffic=(reads(p.reduce_reads, cfg.elem_bytes),),
                ),
                PhaseSpec(
                    "broadcast",
                    p.broadcast_cycles,
                    traffic=(
                        xgmi_out(p.broadcast_xgmi_writes, data_bytes),
                        local_writes(p.broadcast_local_writes, data_bytes),
                    ),
                ),
            ),
        )

    def programs(self) -> List[WGProgram]:
        return [self._program(p) for p in self.workload.plans]

    def traces(self) -> TraceBundle:
        bundle = make_gemv_allreduce_traces(self.cfg, self.flag_delays_ns, self.amap)
        bundle.meta["scenario"] = self.name
        return bundle

    def expected_nonflag_reads(self) -> int:
        return self.workload.expected_nonflag_reads()

    # the closed-form vectorized engine understands exactly this scenario
    def run_vectorized(self, sim):
        from ..vector_engine import run_vectorized

        return run_vectorized(sim)
