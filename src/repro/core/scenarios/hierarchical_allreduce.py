"""Hierarchical (intra-node / inter-node) all-reduce as a closed-loop scenario.

The canonical cross-tier collective on a pod of nodes: every rank first
participates in an **intra-node ring reduce-scatter** over the ICI tier, the
non-leader ranks hand their reduced shards to the node leader, the **node
leaders ring-all-reduce over the DCI tier** while everyone else sits in the
broadcast wait, and finally each leader **broadcasts** the result back to its
node.  Every stage hand-off is flag-synchronized through
:class:`repro.core.scenario.EmitOp` slots, so nothing is pre-scheduled — the
stage cadence emerges from compute + tiered fabric routing, and slowing the
DCI tier lengthens exactly the leader-stage waits (``hir_wait`` on leaders,
``hbc_wait`` on everyone else) while the intra-node reduce-scatter stage is
untouched (asserted in ``tests/test_hierarchy.py``).

Wait phases carry stage-specific names (``hrs_wait`` / ``hir_wait`` /
``hbc_wait``) precisely so per-stage timelines can be told apart; the
interpreter treats any registered name with ``wait_addrs`` as a wait phase.

Closed-loop only: with one detailed device there is no tier to cross.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

from ..config import SimConfig
from ..events import TraceBundle, register_phase
from ..memory import AddressMap
from ..scenario import (
    Affine,
    AffineRun,
    EmitOp,
    EmitRun,
    LoopEmit,
    LoopPhase,
    LoopSpec,
    PhaseSpec,
    Scenario,
    SymbolicProgram,
    WGProgram,
    affine_of,
    local_writes,
    reads,
    register_scenario,
    xgmi_out,
)
from ..topology import HardwareSpec, V5E

__all__ = ["HierarchicalAllReduceScenario"]

register_phase("hrs_send", color="green", glyph="s")
register_phase("hrs_reduce", color="brown", glyph="+")
register_phase("hrs_handoff", color="blue", glyph="^")
register_phase("hrs_wait", color="red", glyph="r")
register_phase("hir_send", color="green", glyph="S")
register_phase("hir_reduce", color="brown", glyph="*")
register_phase("hir_gather", color="blue", glyph="a")
register_phase("hir_wait", color="red", glyph="R")
register_phase("hbc_push", color="blue", glyph="v")
register_phase("hbc_read", color="green", glyph="b")
register_phase("hbc_wait", color="red", glyph="w")


@register_scenario
class HierarchicalAllReduceScenario(Scenario):
    """Intra-node reduce-scatter -> leader ring all-reduce -> broadcast."""

    name = "hierarchical_allreduce"
    closed_loop_capable = True

    def __init__(
        self,
        cfg: SimConfig,
        amap: Optional[AddressMap] = None,
        *,
        payload_bytes: int = 1 << 20,
        devices_per_node: Optional[int] = None,
        writes_per_step: int = 4,
        closed_loop: bool = True,
        fabric=None,
        link_bw=None,
        hw: HardwareSpec = V5E,
    ):
        if not closed_loop:
            raise ValueError(
                "hierarchical_allreduce is closed-loop only (the stages are "
                "emitted, never pre-scheduled)"
            )
        if payload_bytes <= 0:
            raise ValueError("payload_bytes must be positive")
        n = cfg.n_devices
        dpn = n if devices_per_node is None else int(devices_per_node)
        if dpn < 1 or n % dpn:
            raise ValueError(
                f"devices_per_node={dpn} must divide n_devices={n}"
            )
        self.dpn = dpn
        self.n_nodes = n // dpn
        # slots: [0, dpn-2] intra ring steps, dpn-1 shard handoff to the
        # leader, [dpn, dpn + 2(nodes-1)) leader ring steps, last = broadcast
        self.leader_slot_base = dpn
        self.bcast_slot = dpn + 2 * (self.n_nodes - 1)
        if amap is None:
            # bcast_slot grows with the node count; past ~720 devices the
            # pool would cross the default partial_base and data markers
            # would alias high flag slots (layout prover finding) — re-base
            # the partial region above the pool
            amap = AddressMap(
                n_devices=n, flag_slots=self.bcast_slot + 1
            ).with_partial_clearance()
        super().__init__(cfg, amap)
        self.payload_bytes = int(payload_bytes)
        self.devices_per_node = devices_per_node
        self.writes_per_step = int(writes_per_step)
        self.closed_loop = True
        self.hw = hw
        # The *program structure* (leaders, handoffs, stages) follows
        # devices_per_node; the *fabric* carrying it is independently
        # pluggable — the same hierarchical collective can run over two_tier
        # uplinks, a fat tree, or rails.
        self._setup_fabric(
            devices_per_node=devices_per_node, hw=hw, fabric=fabric,
            link_bw=link_bw,
        )
        # the four stages get disjoint slot ranges; a collision here means
        # the layout arithmetic above regressed
        if dpn > 1:
            self.amap.claim_flag_block("hier_intra_ring", 0, dpn - 1)
            self.amap.claim_flag_block("hier_shard_handoff", dpn - 1, dpn)
        if self.n_nodes > 1:
            self.amap.claim_flag_block(
                "hier_leader_ring", self.leader_slot_base, self.bcast_slot
            )
        self.amap.claim_flag_block(
            "hier_broadcast", self.bcast_slot, self.bcast_slot + 1
        )
        self.params = {
            "payload_bytes": self.payload_bytes,
            "devices_per_node": self.devices_per_node,
            "writes_per_step": self.writes_per_step,
            "closed_loop": True,
            "fabric": self.fabric_name,
        }

    # ------------------------------------------------------------------

    def _share(self, nbytes: int) -> Tuple[int, int, int]:
        """(bytes, sectors, cycles) of one WG's slice of an ``nbytes`` block."""
        cfg = self.cfg
        share = max(1, nbytes // cfg.workgroups)
        sectors = math.ceil(share / cfg.sector_bytes)
        cycles = max(1, math.ceil(sectors / cfg.wg_sector_throughput))
        return share, sectors, cycles

    def _emit(self, dst: int, slot: int, payload: int) -> Tuple[EmitOp, ...]:
        return (
            EmitOp(
                dst,
                slot=slot,
                payload_bytes=payload,
                data_writes=self.writes_per_step,
            ),
        )

    def programs_for(self, device: int) -> List[WGProgram]:
        cfg = self.cfg
        shared = self._symbolic_phases(device)
        return [
            WGProgram(
                wg=wg,
                cu=wg % cfg.n_cus,
                dispatch_cycle=(wg // cfg.n_cus) * cfg.dispatch_stagger_cycles,
                phases=shared,
            )
            for wg in range(cfg.workgroups)
        ]

    def _symbolic_phases(self, device: int) -> SymbolicProgram:
        """The per-rank stage program, compressed: both ring stages become
        :class:`LoopSpec`\\ s whose wait address / emit slot are affine in the
        step index, the leader's handoff barrier and broadcast fan-out become
        within-phase runs — O(1) objects per rank in devices and nodes.
        Bit-identity with the flat construction (:meth:`_flat_phases`) is
        property-tested."""
        cfg = self.cfg
        dpn, nodes = self.dpn, self.n_nodes
        node, local = divmod(device, dpn)
        leader = node * dpn
        is_leader = local == 0
        chunk1 = max(1, self.payload_bytes // dpn)
        share1, sectors1, cycles1 = self._share(chunk1)
        segs: List[object] = []

        def _loop_emit(dst: int, slot: Affine, payload: int):
            return (
                LoopEmit(
                    Affine(dst),
                    slot=slot,
                    payload_bytes=payload,
                    data_writes=self.writes_per_step,
                ),
            )

        # ---- stage 1: intra-node ring reduce-scatter (ICI tier) ----------
        if dpn > 1:
            local_up = node * dpn + (local - 1) % dpn
            local_down = node * dpn + (local + 1) % dpn
            segs.append(
                PhaseSpec(
                    "hrs_send",
                    cycles1,
                    traffic=(
                        reads(sectors1, cfg.sector_bytes),
                        xgmi_out(1, share1),
                    ),
                    emits=self._emit(local_down, 0, chunk1),
                )
            )
            t_reduce = (
                reads(2 * sectors1, cfg.sector_bytes),
                local_writes(1, share1),
                xgmi_out(1, share1),
            )
            t_reduce_last = t_reduce[:2]
            wait1 = affine_of(
                lambda k: self.amap.flag_addr(local_up, slot=k), 0, dpn - 1
            )
            # steps 0..dpn-3 are a loop (emit flag k+1 downstream); the last
            # reduce step dpn-2 keeps its shard and emits nothing
            segs.append(
                LoopSpec(
                    dpn - 2,
                    (
                        LoopPhase("hrs_wait", wait_addrs=(wait1,)),
                        LoopPhase(
                            "hrs_reduce",
                            cycles1,
                            traffic=t_reduce,
                            emits=_loop_emit(local_down, Affine(1, 1), chunk1),
                        ),
                    ),
                )
            )
            segs.append(
                PhaseSpec("hrs_wait", wait_addrs=(wait1.at(dpn - 2),))
            )
            segs.append(
                PhaseSpec("hrs_reduce", cycles1, traffic=t_reduce_last)
            )
            # shard handoff: non-leaders push their reduced shard to the
            # leader; the leader barriers on all dpn-1 handoff flags
            if is_leader:
                handoff = affine_of(
                    lambda l2: self.amap.flag_addr(node * dpn + l2, slot=dpn - 1),
                    1,
                    dpn - 1,
                )
                segs.append(
                    LoopPhase(
                        "hrs_wait",
                        wait_addrs=(
                            AffineRun(handoff.at(1), handoff.step, dpn - 1),
                        ),
                    )
                )
            else:
                segs.append(
                    PhaseSpec(
                        "hrs_handoff",
                        cycles1,
                        traffic=(xgmi_out(1, share1),),
                        emits=self._emit(leader, dpn - 1, chunk1),
                    )
                )

        # ---- stage 2: leader ring all-reduce (DCI tier) ------------------
        if nodes > 1 and is_leader:
            chunk2 = max(1, self.payload_bytes // nodes)
            share2, sectors2, cycles2 = self._share(chunk2)
            up_leader = ((node - 1) % nodes) * dpn
            down_leader = ((node + 1) % nodes) * dpn
            base = self.leader_slot_base
            steps2 = 2 * (nodes - 1)
            rs2 = nodes - 1
            segs.append(
                PhaseSpec(
                    "hir_send",
                    cycles2,
                    traffic=(
                        reads(sectors2, cfg.sector_bytes),
                        xgmi_out(1, share2),
                    ),
                    emits=self._emit(down_leader, base, chunk2),
                )
            )
            t_red = (
                reads(2 * sectors2, cfg.sector_bytes),
                local_writes(1, share2),
                xgmi_out(1, share2),
            )
            t_gat = (
                reads(sectors2, cfg.sector_bytes),
                local_writes(1, share2),
                xgmi_out(1, share2),
            )
            t_gat_last = t_gat[:2]
            wait2 = affine_of(
                lambda k: self.amap.flag_addr(up_leader, slot=base + k),
                0,
                steps2,
            )
            wait2_body = LoopPhase("hir_wait", wait_addrs=(wait2,))
            # emit slot is base + k + 1 for finishing step k
            slot_out = Affine(base + 1, 1)
            segs.append(
                LoopSpec(
                    rs2,
                    (
                        wait2_body,
                        LoopPhase(
                            "hir_reduce",
                            cycles2,
                            traffic=t_red,
                            emits=_loop_emit(down_leader, slot_out, chunk2),
                        ),
                    ),
                )
            )
            segs.append(
                LoopSpec(
                    steps2 - 1 - rs2,
                    (
                        wait2_body,
                        LoopPhase(
                            "hir_gather",
                            cycles2,
                            traffic=t_gat,
                            emits=_loop_emit(down_leader, slot_out, chunk2),
                        ),
                    ),
                    k0=rs2,
                )
            )
            segs.append(
                PhaseSpec("hir_wait", wait_addrs=(wait2.at(steps2 - 1),))
            )
            segs.append(PhaseSpec("hir_gather", cycles2, traffic=t_gat_last))

        # ---- stage 3: intra-node broadcast (ICI tier) --------------------
        shareF, sectorsF, cyclesF = self._share(self.payload_bytes)
        if dpn > 1:
            if is_leader:
                segs.append(
                    LoopPhase(
                        "hbc_push",
                        cyclesF,
                        traffic=(xgmi_out(dpn - 1, shareF),),
                        emits=(
                            EmitRun(
                                dpn - 1,
                                dst0=node * dpn + 1,
                                slot0=self.bcast_slot,
                                payload_bytes=self.payload_bytes,
                                data_writes=self.writes_per_step,
                            ),
                        ),
                    )
                )
            else:
                segs.append(
                    PhaseSpec(
                        "hbc_wait",
                        wait_addrs=(
                            self.amap.flag_addr(leader, slot=self.bcast_slot),
                        ),
                    )
                )
        segs.append(
            PhaseSpec(
                "hbc_read",
                cyclesF,
                traffic=(
                    reads(sectorsF, cfg.sector_bytes),
                    local_writes(1, shareF),
                ),
            )
        )
        return SymbolicProgram(segs, group="leader" if is_leader else "worker")

    def _flat_phases(self, device: int):
        """Pre-refactor flat phase construction — the reference oracle for
        :meth:`_symbolic_phases` (property-tested, never on runtime paths)."""
        cfg = self.cfg
        dpn, nodes = self.dpn, self.n_nodes
        node, local = divmod(device, dpn)
        leader = node * dpn
        is_leader = local == 0
        chunk1 = max(1, self.payload_bytes // dpn)
        share1, sectors1, cycles1 = self._share(chunk1)
        phases: List[PhaseSpec] = []

        # ---- stage 1: intra-node ring reduce-scatter (ICI tier) ----------
        if dpn > 1:
            local_up = node * dpn + (local - 1) % dpn
            local_down = node * dpn + (local + 1) % dpn
            phases.append(
                PhaseSpec(
                    "hrs_send",
                    cycles1,
                    traffic=(
                        reads(sectors1, cfg.sector_bytes),
                        xgmi_out(1, share1),
                    ),
                    emits=self._emit(local_down, 0, chunk1),
                )
            )
            # loop-invariant traffic tuples hoisted (built once per device,
            # not per ring step — pod-scale construction walks O(devices)
            # steps per leader)
            t_reduce = (
                reads(2 * sectors1, cfg.sector_bytes),
                local_writes(1, share1),
                xgmi_out(1, share1),
            )
            t_reduce_last = t_reduce[:2]
            for s in range(dpn - 1):
                phases.append(
                    PhaseSpec(
                        "hrs_wait",
                        wait_addrs=(self.amap.flag_addr(local_up, slot=s),),
                    )
                )
                last_rs = s == dpn - 2
                phases.append(
                    PhaseSpec(
                        "hrs_reduce",
                        cycles1,
                        traffic=t_reduce_last if last_rs else t_reduce,
                        emits=()
                        if last_rs
                        else self._emit(local_down, s + 1, chunk1),
                    )
                )
            # shard handoff: non-leaders push their reduced shard to the
            # leader; the leader barriers on all dpn-1 handoff flags
            if is_leader:
                phases.append(
                    PhaseSpec(
                        "hrs_wait",
                        wait_addrs=tuple(
                            self.amap.flag_addr(node * dpn + l2, slot=dpn - 1)
                            for l2 in range(1, dpn)
                        ),
                    )
                )
            else:
                phases.append(
                    PhaseSpec(
                        "hrs_handoff",
                        cycles1,
                        traffic=(xgmi_out(1, share1),),
                        emits=self._emit(leader, dpn - 1, chunk1),
                    )
                )

        # ---- stage 2: leader ring all-reduce (DCI tier) ------------------
        if nodes > 1 and is_leader:
            chunk2 = max(1, self.payload_bytes // nodes)
            share2, sectors2, cycles2 = self._share(chunk2)
            up_leader = ((node - 1) % nodes) * dpn
            down_leader = ((node + 1) % nodes) * dpn
            base = self.leader_slot_base
            steps2 = 2 * (nodes - 1)
            rs2 = nodes - 1
            phases.append(
                PhaseSpec(
                    "hir_send",
                    cycles2,
                    traffic=(
                        reads(sectors2, cfg.sector_bytes),
                        xgmi_out(1, share2),
                    ),
                    emits=self._emit(down_leader, base, chunk2),
                )
            )
            # per-step traffic is one of three loop-invariant tuples
            t_red = (
                reads(2 * sectors2, cfg.sector_bytes),
                local_writes(1, share2),
                xgmi_out(1, share2),
            )
            t_gat = (
                reads(sectors2, cfg.sector_bytes),
                local_writes(1, share2),
                xgmi_out(1, share2),
            )
            t_gat_last = t_gat[:2]
            for s in range(steps2):
                phases.append(
                    PhaseSpec(
                        "hir_wait",
                        wait_addrs=(
                            self.amap.flag_addr(up_leader, slot=base + s),
                        ),
                    )
                )
                reducing = s < rs2
                last = s == steps2 - 1
                phases.append(
                    PhaseSpec(
                        "hir_reduce" if reducing else "hir_gather",
                        cycles2,
                        traffic=t_red
                        if reducing
                        else (t_gat_last if last else t_gat),
                        emits=()
                        if last
                        else self._emit(down_leader, base + s + 1, chunk2),
                    )
                )

        # ---- stage 3: intra-node broadcast (ICI tier) --------------------
        shareF, sectorsF, cyclesF = self._share(self.payload_bytes)
        if dpn > 1:
            if is_leader:
                phases.append(
                    PhaseSpec(
                        "hbc_push",
                        cyclesF,
                        traffic=(xgmi_out(dpn - 1, shareF),),
                        emits=tuple(
                            EmitOp(
                                node * dpn + l2,
                                slot=self.bcast_slot,
                                payload_bytes=self.payload_bytes,
                                data_writes=self.writes_per_step,
                            )
                            for l2 in range(1, dpn)
                        ),
                    )
                )
            else:
                phases.append(
                    PhaseSpec(
                        "hbc_wait",
                        wait_addrs=(
                            self.amap.flag_addr(leader, slot=self.bcast_slot),
                        ),
                    )
                )
        phases.append(
            PhaseSpec(
                "hbc_read",
                cyclesF,
                traffic=(
                    reads(sectorsF, cfg.sector_bytes),
                    local_writes(1, shareF),
                ),
            )
        )
        return tuple(phases)

    # closed-loop only fallbacks -------------------------------------------

    def programs(self) -> List[WGProgram]:
        raise NotImplementedError("hierarchical_allreduce is closed-loop only")

    def traces(self) -> TraceBundle:
        return TraceBundle(meta={"scenario": self.name, "closed_loop": True})
