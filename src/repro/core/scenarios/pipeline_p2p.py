"""Pipeline-parallel p2p send/recv as an Eidola scenario.

The detailed device is one interior stage of a pipeline: for every microbatch
it (1) waits for the previous stage's activation hand-off — the upstream
eidolon pushes the activation tensor as data writes, then a per-microbatch
arrival flag, the TPU analogue being a DMA-completion semaphore — (2) runs the
stage's forward compute, and (3) pushes its own activations to the next stage
over the fabric.

One flag slot per microbatch keeps successive hand-offs independent (a flag is
write-once, so reusing one address would make every wait after the first free).
The upstream cadence is derived from the collective-permute cost of the
activation tensor in :mod:`repro.core.topology`, stretched by
``bubble_factor`` to model the upstream stage's own compute time.
"""

from __future__ import annotations

import math
from typing import List, Optional

from ..config import SimConfig
from ..events import TraceBundle, register_phase
from ..memory import AddressMap
from ..scenario import (
    Affine,
    EmitOp,
    LoopEmit,
    LoopPhase,
    LoopSpec,
    PhaseSpec,
    Scenario,
    SymbolicProgram,
    WGProgram,
    affine_of,
    local_writes,
    reads,
    register_scenario,
    xgmi_out,
)
from ..topology import HardwareSpec, Topology, V5E

__all__ = ["PipelineP2PScenario"]

register_phase("fwd_compute", color="green", glyph="f")
register_phase("p2p_send", color="blue", glyph=">")


@register_scenario
class PipelineP2PScenario(Scenario):
    """Pipeline stage: per-microbatch activation wait -> compute -> p2p send."""

    name = "pipeline_p2p"
    closed_loop_capable = True

    def __init__(
        self,
        cfg: SimConfig,
        amap: Optional[AddressMap] = None,
        *,
        n_microbatches: int = 8,
        activation_bytes: int = 1 << 19,
        compute_scale: float = 4.0,
        bubble_factor: float = 1.25,
        writes_per_microbatch: int = 4,
        interval_ns: Optional[float] = None,
        closed_loop: bool = False,
        devices_per_node: Optional[int] = None,
        fabric=None,
        link_bw=None,
        hw: HardwareSpec = V5E,
    ):
        super().__init__(cfg, amap)
        if n_microbatches <= 0 or activation_bytes <= 0:
            raise ValueError("n_microbatches and activation_bytes must be positive")
        self.n_microbatches = int(n_microbatches)
        self.activation_bytes = int(activation_bytes)
        self.compute_scale = float(compute_scale)
        self.writes_per_microbatch = int(writes_per_microbatch)
        self.closed_loop = bool(closed_loop)
        self.devices_per_node = devices_per_node
        self.hw = hw
        self.upstream = 1  # previous stage
        # next stage: where the p2p_send traffic is headed (trace metadata;
        # outgoing writes are aggregate counters, not per-address)
        self.downstream = 2 if cfg.n_devices > 2 else 1
        # Closed-loop fabric shape: consecutive pipeline stages share a node
        # until a stage boundary crosses a node boundary, where the hand-off
        # rides the DCI uplink (flat when devices_per_node is unset, fabric=
        # selects any registered preset).  The open-loop cadence keeps the
        # flat single-tier algebra.
        self._setup_fabric(
            devices_per_node=devices_per_node, hw=hw, fabric=fabric,
            link_bw=link_bw,
        )
        # one flag slot per microbatch, each stage writing its own column
        self.amap.claim_flag_block("pipe_microbatch", 0, self.n_microbatches)
        self.cost = Topology.flat_ring(
            cfg.n_devices, axis="pp", hw=hw
        ).collective("collective-permute", self.activation_bytes, "pp")
        if interval_ns is not None:
            self.interval_ns = float(interval_ns)
        else:
            self.interval_ns = self.cost.time_s * 1e9 * float(bubble_factor)
        self.params = {
            "n_microbatches": self.n_microbatches,
            "activation_bytes": self.activation_bytes,
            "interval_ns": self.interval_ns,
            "closed_loop": self.closed_loop,
            "devices_per_node": self.devices_per_node,
            "fabric": self.fabric_name,
        }

    @classmethod
    def default_amap(cls, cfg: SimConfig) -> AddressMap:
        # worst case a caller re-instantiates with more microbatches on the
        # same map; 64 slots cover the defaults with headroom.  At 4092+
        # devices 64 slots overrun the default flag/partial gap (layout
        # prover finding), so clear the partial region past the pool.
        return AddressMap(
            n_devices=cfg.n_devices, flag_slots=64
        ).with_partial_clearance()

    # ------------------------------------------------------------------

    def _shares(self) -> tuple:
        cfg = self.cfg
        share = max(1, self.activation_bytes // cfg.workgroups)
        sectors = math.ceil(share / cfg.sector_bytes)
        io_cycles = max(1, math.ceil(sectors / cfg.wg_sector_throughput))
        fwd_cycles = max(1, math.ceil(io_cycles * self.compute_scale))
        return share, sectors, io_cycles, fwd_cycles

    def _check_slots(self) -> None:
        if self.n_microbatches > self.amap.flag_slots:
            raise ValueError(
                f"{self.n_microbatches} microbatches need flag_slots >= "
                f"{self.n_microbatches} (amap has {self.amap.flag_slots})"
            )

    def _stamp(self, phases) -> List[WGProgram]:
        """Stamp per-WG program records against one shared phase program.

        Phases are workgroup-invariant — only (wg, cu, dispatch_cycle) vary —
        so sharing the program removes the O(workgroups) construction factor
        and feeds the cohort interpreter's identity-based grouping."""
        cfg = self.cfg
        shared = phases if isinstance(phases, SymbolicProgram) else tuple(phases)
        return [
            WGProgram(
                wg=wg,
                cu=wg % cfg.n_cus,
                dispatch_cycle=(wg // cfg.n_cus) * cfg.dispatch_stagger_cycles,
                phases=shared,
            )
            for wg in range(cfg.workgroups)
        ]

    def _microbatch_flag(self) -> Affine:
        """Per-microbatch wait address, affine in the microbatch index."""
        return affine_of(
            lambda m: self.amap.flag_addr(self.upstream, slot=m),
            0,
            self.n_microbatches,
        )

    def _flat_open_phases(self):
        """Pre-refactor flat open-loop construction — the reference oracle
        for :meth:`_symbolic_open_phases` (property-tested)."""
        cfg = self.cfg
        share, sectors, io_cycles, fwd_cycles = self._shares()
        phases: List[PhaseSpec] = []
        for m in range(self.n_microbatches):
            phases.append(
                PhaseSpec(
                    "wait_flags",
                    wait_addrs=(self.amap.flag_addr(self.upstream, slot=m),),
                )
            )
            phases.append(
                PhaseSpec(
                    "fwd_compute",
                    fwd_cycles,
                    traffic=(
                        reads(sectors, cfg.sector_bytes),
                        local_writes(1, share),
                    ),
                )
            )
            phases.append(
                PhaseSpec(
                    "p2p_send",
                    io_cycles,
                    traffic=(xgmi_out(1, share), xgmi_out(1, 8)),
                )
            )
        return tuple(phases)

    def _symbolic_open_phases(self) -> SymbolicProgram:
        """One :class:`LoopSpec` over microbatches — O(1) objects in
        ``n_microbatches``."""
        cfg = self.cfg
        share, sectors, io_cycles, fwd_cycles = self._shares()
        return SymbolicProgram(
            (
                LoopSpec(
                    self.n_microbatches,
                    (
                        LoopPhase(
                            "wait_flags", wait_addrs=(self._microbatch_flag(),)
                        ),
                        LoopPhase(
                            "fwd_compute",
                            fwd_cycles,
                            traffic=(
                                reads(sectors, cfg.sector_bytes),
                                local_writes(1, share),
                            ),
                        ),
                        LoopPhase(
                            "p2p_send",
                            io_cycles,
                            traffic=(xgmi_out(1, share), xgmi_out(1, 8)),
                        ),
                    ),
                ),
            )
        )

    def programs(self) -> List[WGProgram]:
        self._check_slots()
        return self._stamp(self._symbolic_open_phases())

    def programs_for(self, device: int) -> List[WGProgram]:
        """Closed loop: device ``r`` is pipeline stage ``r`` (0 = source).

        The source stage free-runs its microbatches; every other stage waits
        for the upstream stage's per-microbatch arrival flag, runs forward
        compute, and — except for the final stage — pushes activations plus
        the hand-off flag downstream.  The microbatch cadence of interior
        stages then *emerges* from stage-0 compute + link serialization
        instead of the open-loop ``interval_ns`` constant.
        """
        if not self.closed_loop:
            return super().programs_for(device)
        self._check_slots()
        return self._stamp(self._symbolic_closed_phases(device))

    def _flat_closed_phases(self, device: int):
        """Pre-refactor flat closed-loop construction — the reference oracle
        for :meth:`_symbolic_closed_phases` (property-tested)."""
        cfg = self.cfg
        share, sectors, io_cycles, fwd_cycles = self._shares()
        n = cfg.n_devices
        first = device == 0
        last = device == n - 1
        phases: List[PhaseSpec] = []
        for m in range(self.n_microbatches):
            if not first:
                phases.append(
                    PhaseSpec(
                        "wait_flags",
                        wait_addrs=(
                            self.amap.flag_addr(device - 1, slot=m),
                        ),
                    )
                )
            phases.append(
                PhaseSpec(
                    "fwd_compute",
                    fwd_cycles,
                    traffic=(
                        reads(sectors, cfg.sector_bytes),
                        local_writes(1, share),
                    ),
                )
            )
            if last:
                # final stage: write the microbatch result locally
                phases.append(
                    PhaseSpec(
                        "p2p_send",
                        io_cycles,
                        traffic=(local_writes(1, share),),
                    )
                )
            else:
                phases.append(
                    PhaseSpec(
                        "p2p_send",
                        io_cycles,
                        traffic=(xgmi_out(1, share),),
                        emits=(
                            EmitOp(
                                device + 1,
                                slot=m,
                                payload_bytes=self.activation_bytes,
                                data_writes=self.writes_per_microbatch,
                            ),
                        ),
                    )
                )
        return tuple(phases)

    def _symbolic_closed_phases(self, device: int) -> SymbolicProgram:
        """One :class:`LoopSpec` over microbatches, body shaped by the
        stage's position (source stages free-run, the final stage keeps its
        results local) — O(1) objects in ``n_microbatches``."""
        cfg = self.cfg
        share, sectors, io_cycles, fwd_cycles = self._shares()
        n = cfg.n_devices
        first = device == 0
        last = device == n - 1
        body: List[LoopPhase] = []
        if not first:
            wait_aff = affine_of(
                lambda m: self.amap.flag_addr(device - 1, slot=m),
                0,
                self.n_microbatches,
            )
            body.append(LoopPhase("wait_flags", wait_addrs=(wait_aff,)))
        body.append(
            LoopPhase(
                "fwd_compute",
                fwd_cycles,
                traffic=(
                    reads(sectors, cfg.sector_bytes),
                    local_writes(1, share),
                ),
            )
        )
        if last:
            body.append(
                LoopPhase("p2p_send", io_cycles, traffic=(local_writes(1, share),))
            )
        else:
            body.append(
                LoopPhase(
                    "p2p_send",
                    io_cycles,
                    traffic=(xgmi_out(1, share),),
                    emits=(
                        LoopEmit(
                            Affine(device + 1),
                            slot=Affine(0, 1),
                            payload_bytes=self.activation_bytes,
                            data_writes=self.writes_per_microbatch,
                        ),
                    ),
                )
            )
        return SymbolicProgram(
            (LoopSpec(self.n_microbatches, tuple(body)),),
            group="head" if first else ("tail" if last else "interior"),
        )

    def traces(self) -> TraceBundle:
        cfg = self.cfg
        bundle = TraceBundle(
            meta={
                "scenario": self.name,
                "n_devices": cfg.n_devices,
                "n_microbatches": self.n_microbatches,
                "activation_bytes": self.activation_bytes,
                "interval_ns": self.interval_ns,
                "upstream": self.upstream,
                "downstream": self.downstream,
            }
        )
        lead = cfg.data_write_lead_ns
        for m in range(self.n_microbatches):
            flag_t = self.interval_ns * (m + 1)
            if cfg.include_data_writes and self.writes_per_microbatch > 0:
                t0 = max(0.0, flag_t - lead)
                for i in range(self.writes_per_microbatch):
                    t = t0 + (flag_t - t0) * (i + 1) / (self.writes_per_microbatch + 1)
                    bundle.add(
                        wakeup_ns=t,
                        addr=self.amap.partial_base
                        + (m * self.writes_per_microbatch + i) * 64,
                        data=0xD0 + m % 16,
                        size=8,
                        src=self.upstream,
                    )
            bundle.add(
                wakeup_ns=flag_t,
                addr=self.amap.flag_addr(self.upstream, slot=m),
                data=1,
                size=8,
                src=self.upstream,
            )
        return bundle
