"""Chunked ring all-reduce as an Eidola scenario.

Devices 0..n-1 form a unidirectional ring (0 -> 1 -> ... -> n-1 -> 0).  A
payload of ``payload_bytes`` is split into n chunks and reduce-scattered then
all-gathered in the textbook 2(n-1) ring steps.  Each step is a
*synchronization event*: the upstream neighbour pushes its chunk (data writes
into the partial region) followed by a per-step flag — one flag slot per ring
step — and every workgroup waits on that flag before reducing/forwarding its
share of the chunk.

Two modes:

* **open loop** (default): only device 0 is detailed; the upstream eidolon's
  arrival schedule is synthesized from the collective cost model in
  :mod:`repro.core.topology` (ring algebra over the configured fabric), so the
  step cadence reflects link bandwidth and hop latency rather than an
  arbitrary constant; ``step_time_ns`` overrides it for controlled sweeps.
* **closed loop** (``closed_loop=True``): every rank runs the same per-step
  program in a :class:`repro.core.cluster.Cluster`; finishing step k *emits*
  the step-k flag to the downstream rank (:class:`repro.core.scenario.EmitOp`
  routed over the fabric model), so nothing is pre-scheduled and a
  perturbation on one rank propagates around the ring.
"""

from __future__ import annotations

import math
from typing import List, Optional

from ..config import SimConfig
from ..events import TraceBundle, register_phase
from ..memory import AddressMap
from ..scenario import (
    Affine,
    EmitOp,
    LoopEmit,
    LoopPhase,
    LoopSpec,
    PhaseSpec,
    Scenario,
    SymbolicProgram,
    WGProgram,
    affine_of,
    local_writes,
    reads,
    register_scenario,
    xgmi_out,
)
from ..topology import HardwareSpec, Topology, V5E

__all__ = ["RingAllReduceScenario"]

register_phase("ring_send", color="green", glyph="s")
register_phase("ring_reduce", color="brown", glyph="+")
register_phase("ring_gather", color="blue", glyph="a")


@register_scenario
class RingAllReduceScenario(Scenario):
    """Chunked ring all-reduce; one wait/flag per ring step."""

    name = "ring_allreduce"
    closed_loop_capable = True

    def __init__(
        self,
        cfg: SimConfig,
        amap: Optional[AddressMap] = None,
        *,
        payload_bytes: int = 1 << 20,
        step_time_ns: Optional[float] = None,
        writes_per_step: int = 4,
        closed_loop: bool = False,
        devices_per_node: Optional[int] = None,
        fabric=None,
        link_bw=None,
        hw: HardwareSpec = V5E,
    ):
        super().__init__(cfg, amap)
        if payload_bytes <= 0:
            raise ValueError("payload_bytes must be positive")
        self.payload_bytes = int(payload_bytes)
        self.writes_per_step = int(writes_per_step)
        self.closed_loop = bool(closed_loop)
        self.devices_per_node = devices_per_node
        self.hw = hw
        k = cfg.n_devices
        self.steps = 2 * (k - 1)
        self.upstream = k - 1
        # Closed-loop fabric shape: the global ring maps onto intra-node ICI
        # rings stitched by DCI uplinks (flat when devices_per_node is unset);
        # fabric= selects any registered interconnect preset instead.
        self._setup_fabric(
            devices_per_node=devices_per_node, hw=hw, fabric=fabric,
            link_bw=link_bw,
        )
        # one flag slot per ring step, every rank writing its own column
        self.amap.claim_flag_block("ring_step", 0, self.steps)
        # Open-loop cadence keeps the flat single-ring collective algebra the
        # trace schedule was always derived from.
        self.cost = Topology.flat_ring(k, axis="ring", hw=hw).collective(
            "all-reduce", self.payload_bytes, "ring"
        )
        if step_time_ns is not None:
            self.step_time_ns = float(step_time_ns)
        else:
            self.step_time_ns = self.cost.time_s * 1e9 / max(1, self.steps)
        self.params = {
            "payload_bytes": self.payload_bytes,
            "step_time_ns": self.step_time_ns,
            "writes_per_step": self.writes_per_step,
            "closed_loop": self.closed_loop,
            "devices_per_node": self.devices_per_node,
            "fabric": self.fabric_name,
        }

    @classmethod
    def default_amap(cls, cfg: SimConfig) -> AddressMap:
        # per-step flag slots overrun the default flag/partial gap beyond
        # ~256 devices; clear the partial region so ring-step waits can
        # never be satisfied by stale data-marker writes
        return AddressMap(
            n_devices=cfg.n_devices, flag_slots=max(1, 2 * (cfg.n_devices - 1))
        ).with_partial_clearance()

    # ------------------------------------------------------------------

    def _wg_share(self) -> tuple:
        """(bytes, sectors, cycles) of one WG's slice of one chunk."""
        cfg = self.cfg
        chunk = max(1, self.payload_bytes // cfg.n_devices)
        share = max(1, chunk // cfg.workgroups)
        sectors = math.ceil(share / cfg.sector_bytes)
        cycles = max(1, math.ceil(sectors / cfg.wg_sector_throughput))
        return share, sectors, cycles

    def _flat_phases(self, rank: int, *, emit: bool):
        """Pre-refactor flat phase construction — O(steps) PhaseSpecs.  Kept
        as the reference oracle for ``SymbolicProgram.expand()`` equivalence
        (property-tested); runtime paths use :meth:`_symbolic_phases`."""
        cfg = self.cfg
        n = cfg.n_devices
        share, sectors, cycles = self._wg_share()
        chunk = max(1, self.payload_bytes // n)
        rs_steps = n - 1
        upstream = (rank - 1) % n
        downstream = (rank + 1) % n

        def flag_out(slot: int):
            if not emit:
                return ()
            return (
                EmitOp(
                    downstream,
                    slot=slot,
                    payload_bytes=chunk,
                    data_writes=self.writes_per_step,
                ),
            )

        phases: List[PhaseSpec] = [
            # step 0: push our own chunk downstream before waiting
            PhaseSpec(
                "ring_send",
                cycles,
                traffic=(reads(sectors, cfg.sector_bytes), xgmi_out(1, share)),
                emits=flag_out(0),
            )
        ]
        for s in range(self.steps):
            phases.append(
                PhaseSpec(
                    "wait_flags",
                    wait_addrs=(self.amap.flag_addr(upstream, slot=s),),
                )
            )
            reducing = s < rs_steps
            last = s == self.steps - 1
            traffic = [
                # incoming chunk + (while reducing) the local accumulator
                reads(sectors * (2 if reducing else 1), cfg.sector_bytes),
                local_writes(1, share),
            ]
            if not last:
                traffic.append(xgmi_out(1, share))
            phases.append(
                PhaseSpec(
                    "ring_reduce" if reducing else "ring_gather",
                    cycles,
                    traffic=tuple(traffic),
                    emits=() if last else flag_out(s + 1),
                )
            )
        return tuple(phases)

    def _symbolic_phases(self, rank: int, *, emit: bool) -> SymbolicProgram:
        """The same program as :meth:`_flat_phases`, compressed: a literal
        send, one :class:`LoopSpec` per ring stage (reduce-scatter /
        all-gather) whose wait address and emit slot are affine in the step
        index k, and a literal tail — O(1) objects per rank in step count."""
        cfg = self.cfg
        n = cfg.n_devices
        share, sectors, cycles = self._wg_share()
        chunk = max(1, self.payload_bytes // n)
        rs_steps = n - 1
        upstream = (rank - 1) % n
        downstream = (rank + 1) % n

        def loop_out(slot: Affine):
            if not emit:
                return ()
            return (
                LoopEmit(
                    Affine(downstream),
                    slot=slot,
                    payload_bytes=chunk,
                    data_writes=self.writes_per_step,
                ),
            )

        # step-k wait address: one flag slot per ring step, the upstream
        # writer's column — derived from the AddressMap rather than assuming
        # its layout (affine_of verifies affinity over the full step range).
        wait_aff = affine_of(
            lambda k: self.amap.flag_addr(upstream, slot=k), 0, self.steps
        )
        wait_body = LoopPhase("wait_flags", wait_addrs=(wait_aff,))
        step_out = loop_out(Affine(1, 1))  # finishing step k emits flag k+1
        segments = [
            PhaseSpec(
                "ring_send",
                cycles,
                traffic=(reads(sectors, cfg.sector_bytes), xgmi_out(1, share)),
                emits=tuple(e.at(0) for e in loop_out(Affine(0))),
            ),
            LoopSpec(
                rs_steps,
                (
                    wait_body,
                    LoopPhase(
                        "ring_reduce",
                        cycles,
                        traffic=(
                            reads(sectors * 2, cfg.sector_bytes),
                            local_writes(1, share),
                            xgmi_out(1, share),
                        ),
                        emits=step_out,
                    ),
                ),
            ),
            LoopSpec(
                self.steps - 1 - rs_steps,
                (
                    wait_body,
                    LoopPhase(
                        "ring_gather",
                        cycles,
                        traffic=(
                            reads(sectors, cfg.sector_bytes),
                            local_writes(1, share),
                            xgmi_out(1, share),
                        ),
                        emits=step_out,
                    ),
                ),
                k0=rs_steps,
            ),
            PhaseSpec(
                "wait_flags", wait_addrs=(wait_aff.at(self.steps - 1),)
            ),
            PhaseSpec(
                "ring_gather",
                cycles,
                traffic=(reads(sectors, cfg.sector_bytes), local_writes(1, share)),
            ),
        ]
        return SymbolicProgram(segments, group="ring")

    def _rank_programs(self, rank: int, *, emit: bool) -> List[WGProgram]:
        """Per-step ring program of one rank; with ``emit`` the step-k flag is
        pushed downstream when (the last WG of) step k completes.

        The phase list is identical for every workgroup of the rank — only
        (wg, cu, dispatch_cycle) vary — so build ONE shared
        :class:`SymbolicProgram` and stamp per-WG program records against it.
        Construction is O(1) in step count; the shared identity lets the
        cohort interpreter group workgroups without comparing phase lists.
        """
        cfg = self.cfg
        shared = self._symbolic_phases(rank, emit=emit)
        return [
            WGProgram(
                wg=wg,
                cu=wg % cfg.n_cus,
                dispatch_cycle=(wg // cfg.n_cus) * cfg.dispatch_stagger_cycles,
                phases=shared,
            )
            for wg in range(cfg.workgroups)
        ]

    def programs(self) -> List[WGProgram]:
        return self._rank_programs(0, emit=False)

    def programs_for(self, device: int) -> List[WGProgram]:
        if not self.closed_loop:
            return super().programs_for(device)
        return self._rank_programs(device, emit=True)

    def traces(self) -> TraceBundle:
        cfg = self.cfg
        bundle = TraceBundle(
            meta={
                "scenario": self.name,
                "n_devices": cfg.n_devices,
                "payload_bytes": self.payload_bytes,
                "steps": self.steps,
                "step_time_ns": self.step_time_ns,
            }
        )
        chunk = max(1, self.payload_bytes // cfg.n_devices)
        lead = cfg.data_write_lead_ns
        for s in range(self.steps):
            flag_t = self.step_time_ns * (s + 1)
            if cfg.include_data_writes and self.writes_per_step > 0:
                t0 = max(0.0, flag_t - lead)
                for i in range(self.writes_per_step):
                    t = t0 + (flag_t - t0) * (i + 1) / (self.writes_per_step + 1)
                    bundle.add(
                        wakeup_ns=t,
                        addr=self.amap.partial_base
                        + (s * self.writes_per_step + i) * 64,
                        data=0xC0 + s,
                        size=min(8, max(1, chunk % 8 or 8)),
                        src=self.upstream,
                    )
            bundle.add(
                wakeup_ns=flag_t,
                addr=self.amap.flag_addr(self.upstream, slot=s),
                data=1,
                size=8,
                src=self.upstream,
            )
        return bundle
