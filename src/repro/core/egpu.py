"""Eidolon trace generators.

The paper feeds Eidola with (a) annotated timing profiles from real
applications and (b) "synthetically generated profiles from probabilistic
models" [8, 17, 27, 47].  This module provides the synthetic side: per-eGPU
stochastic write-stream generators, plus helpers to merge streams into a
:class:`TraceBundle`.  The GEMV+AllReduce application traces live in
``workload.make_gemv_allreduce_traces``; compiled-HLO capture in
``hlo_capture``.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .events import RegisteredWrite, TraceBundle
from .memory import AddressMap

__all__ = [
    "uniform_stream",
    "poisson_stream",
    "burst_stream",
    "periodic_stream",
    "merge_streams",
]


def _bundle_from(times_by_src: Dict[int, np.ndarray], amap: AddressMap,
                 meta: Optional[dict] = None) -> TraceBundle:
    bundle = TraceBundle(meta=meta or {})
    for src in sorted(times_by_src):
        for i, t in enumerate(np.sort(times_by_src[src])):
            addr = amap.partial_base + 64 * ((src * 65536 + i) % 4096)
            bundle.add(wakeup_ns=float(t), addr=addr, data=i, size=8, src=src)
        # every stream ends with the peer's flag write so waiting workloads
        # can terminate
        bundle.add(
            wakeup_ns=float(times_by_src[src].max(initial=0.0)),
            addr=amap.flag_addr(src),
            data=1,
            size=8,
            src=src,
        )
    return bundle


def uniform_stream(
    n_egpus: int,
    writes_per_egpu: int,
    span_ns: float,
    *,
    seed: int = 0,
    amap: Optional[AddressMap] = None,
) -> TraceBundle:
    """Writes uniformly distributed over [0, span_ns)."""
    amap = amap or AddressMap(n_devices=n_egpus + 1)
    rng = np.random.default_rng(seed)
    times = {
        g: rng.uniform(0.0, span_ns, size=writes_per_egpu)
        for g in range(1, n_egpus + 1)
    }
    return _bundle_from(times, amap, {"pattern": "uniform", "span_ns": span_ns})


def poisson_stream(
    n_egpus: int,
    rate_per_us: float,
    span_ns: float,
    *,
    seed: int = 0,
    amap: Optional[AddressMap] = None,
) -> TraceBundle:
    """Poisson arrivals with the given rate (writes per microsecond)."""
    amap = amap or AddressMap(n_devices=n_egpus + 1)
    rng = np.random.default_rng(seed)
    times: Dict[int, np.ndarray] = {}
    for g in range(1, n_egpus + 1):
        gaps = rng.exponential(1000.0 / rate_per_us, size=max(4, int(
            2 * rate_per_us * span_ns / 1000.0)))
        t = np.cumsum(gaps)
        times[g] = t[t < span_ns]
        if times[g].size == 0:
            times[g] = np.array([span_ns * 0.5])
    return _bundle_from(times, amap, {"pattern": "poisson", "rate_per_us": rate_per_us})


def burst_stream(
    n_egpus: int,
    bursts: int,
    writes_per_burst: int,
    span_ns: float,
    *,
    burst_width_ns: float = 200.0,
    seed: int = 0,
    amap: Optional[AddressMap] = None,
) -> TraceBundle:
    """Bursty producer-consumer traffic (the paper's asymmetric use case)."""
    amap = amap or AddressMap(n_devices=n_egpus + 1)
    rng = np.random.default_rng(seed)
    times: Dict[int, np.ndarray] = {}
    for g in range(1, n_egpus + 1):
        centers = rng.uniform(0.0, span_ns, size=bursts)
        t = (
            centers[:, None]
            + rng.normal(0.0, burst_width_ns, size=(bursts, writes_per_burst))
        ).ravel()
        times[g] = np.clip(t, 0.0, span_ns)
    return _bundle_from(times, amap, {"pattern": "burst"})


def periodic_stream(
    n_egpus: int,
    period_ns: float,
    span_ns: float,
    *,
    phase_ns: float = 0.0,
    amap: Optional[AddressMap] = None,
) -> TraceBundle:
    """Deterministic periodic writes (e.g. pipeline-parallel microbatches)."""
    amap = amap or AddressMap(n_devices=n_egpus + 1)
    times = {
        g: np.arange(phase_ns + (g - 1) * period_ns / n_egpus, span_ns, period_ns)
        for g in range(1, n_egpus + 1)
    }
    return _bundle_from(times, amap, {"pattern": "periodic", "period_ns": period_ns})


def merge_streams(*bundles: TraceBundle) -> TraceBundle:
    out = TraceBundle(meta={"pattern": "merged"})
    for b in bundles:
        out.extend(b)
    return out
