"""Interconnect topology and collective-cost model.

Maps the paper's xGMI fabric onto the TPU v5e target: a 2D ICI torus within a
pod (16x16 for the production mesh) and a lower-bandwidth inter-pod fabric for
the ``pod`` axis.  Collective costs use standard ring/bidirectional-ring
algebra; they feed the roofline's collective term cross-check and generate
arrival schedules for Eidola pod-scale replay (each ring step's completion is
one semaphore write — the TPU analogue of the paper's flag writes).

Hardware constants follow the assignment: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Sequence, Tuple

__all__ = ["HardwareSpec", "Topology", "CollectiveCost", "FabricModel", "V5E"]

CollectiveKind = Literal[
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"
]


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_link_bw: float = 50e9           # bytes/s per link per direction
    ici_links_per_axis: int = 1         # links a ring along one axis can use
    ici_hop_latency_s: float = 1e-6
    dci_link_bw: float = 12.5e9         # inter-pod (pod axis) bandwidth
    dci_hop_latency_s: float = 10e-6
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3


V5E = HardwareSpec()


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    bytes_in: int          # per-device operand bytes
    axis_size: int
    link_bytes: int        # bytes crossing the busiest link
    time_s: float
    steps: int             # ring steps (used for arrival schedules)

    def arrival_times_s(self, start_s: float = 0.0) -> List[float]:
        """Completion time of each ring step (semaphore-write schedule)."""
        if self.steps <= 0:
            return [start_s]
        dt = self.time_s / self.steps
        return [start_s + dt * (i + 1) for i in range(self.steps)]


@dataclass(frozen=True)
class Topology:
    """A mesh of chips with per-axis fabric characteristics."""

    axis_sizes: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    hw: HardwareSpec = V5E
    # axes routed over the inter-pod fabric rather than intra-pod ICI
    dci_axes: Tuple[str, ...] = ("pod",)

    def __post_init__(self):
        if len(self.axis_sizes) != len(self.axis_names):
            raise ValueError("axis_sizes and axis_names length mismatch")

    @property
    def n_chips(self) -> int:
        return math.prod(self.axis_sizes)

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    def _fabric(self, axis: str) -> Tuple[float, float]:
        if axis in self.dci_axes:
            return self.hw.dci_link_bw, self.hw.dci_hop_latency_s
        return (
            self.hw.ici_link_bw * self.hw.ici_links_per_axis,
            self.hw.ici_hop_latency_s,
        )

    # ------------------------------------------------------------------
    # collective cost algebra (bidirectional ring per mesh axis)
    # ------------------------------------------------------------------

    def collective(self, kind: str, bytes_in: int, axis: str) -> CollectiveCost:
        """Cost of one collective of per-device operand size ``bytes_in``.

        bytes_in semantics per kind (per device):
          all-reduce      : the full reduced tensor's shard held per device
          all-gather      : the local shard that gets gathered
          reduce-scatter  : the full input that gets reduce-scattered
          all-to-all      : the full local buffer exchanged
          collective-permute : the buffer shifted to the neighbour
        """
        k = self.axis_size(axis)
        bw, lat = self._fabric(axis)
        if k <= 1:
            return CollectiveCost(kind, bytes_in, k, 0, 0.0, 0)
        if kind == "all-reduce":
            # reduce-scatter + all-gather, 2(k-1) steps of bytes/k
            link = 2 * bytes_in * (k - 1) // k
            steps = 2 * (k - 1)
        elif kind == "all-gather":
            link = bytes_in * (k - 1)
            steps = k - 1
        elif kind == "reduce-scatter":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "all-to-all":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "collective-permute":
            link = bytes_in
            steps = 1
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        time = link / bw + steps * lat
        return CollectiveCost(kind, bytes_in, k, link, time, steps)

    def flat_collective_seconds(self, total_bytes: int, axis: Optional[str] = None) -> float:
        """The assignment's flat roofline collective term:
        collective_bytes / link_bw (per chip)."""
        bw, _ = self._fabric(axis or self.axis_names[-1])
        return total_bytes / bw

    # ------------------------------------------------------------------

    def describe(self) -> str:
        axes = ", ".join(
            f"{n}={s}{' (DCI)' if n in self.dci_axes else ''}"
            for n, s in zip(self.axis_names, self.axis_sizes)
        )
        return f"<Topology {self.n_chips} chips: {axes}; {self.hw.name}>"


class FabricModel:
    """Per-message routing over a bidirectional ring fabric, with contention.

    This is the closed-loop counterpart of :meth:`Topology.collective`: instead
    of pricing a whole collective in closed form, it prices *one xGMI write
    burst* from ``src`` to ``dst`` at a concrete issue time, so the
    :class:`repro.core.cluster.Cluster` can register the write into the
    destination device's WTT at a physically-derived arrival time.

    The model is deliberately simple (the paper models the fabric only through
    per-write wakeup times):

    * shortest-path hop count on the ring x ``hop_latency_ns`` of pure latency;
    * store-and-forward serialization of the burst on the *egress port*
      (``bytes / link_bw``), with one port per (device, ring direction);
    * contention: each egress port is busy until its previous burst finished
      serializing, so back-to-back emissions queue up (FIFO per port).

    All state updates are deterministic in emission order, which both engines
    reproduce identically (writes before transitions, devices in id order), so
    cycle/event runs stay bit-identical.
    """

    def __init__(
        self,
        n_devices: int,
        hw: HardwareSpec = V5E,
        *,
        hop_latency_ns: Optional[float] = None,
        link_bw_bytes_per_ns: Optional[float] = None,
    ):
        if n_devices < 2:
            raise ValueError("a fabric needs at least 2 devices")
        self.n_devices = int(n_devices)
        self.hw = hw
        self.hop_latency_ns = (
            float(hop_latency_ns)
            if hop_latency_ns is not None
            else hw.ici_hop_latency_s * 1e9
        )
        self.link_bw_bytes_per_ns = (
            float(link_bw_bytes_per_ns)
            if link_bw_bytes_per_ns is not None
            else hw.ici_link_bw * self.hw.ici_links_per_axis / 1e9
        )
        if self.hop_latency_ns < 0 or self.link_bw_bytes_per_ns <= 0:
            raise ValueError("hop latency must be >= 0 and link bandwidth > 0")
        # (device, direction) -> ns at which the egress port frees up
        self._busy_until_ns: Dict[Tuple[int, int], float] = {}
        self.stats = {"messages": 0, "bytes": 0, "queued_ns": 0.0}

    def reset(self) -> None:
        self._busy_until_ns.clear()
        self.stats = {"messages": 0, "bytes": 0, "queued_ns": 0.0}

    def route(self, src: int, dst: int) -> Tuple[int, int]:
        """(hops, direction) of the shortest ring path; +1 = ascending ids."""
        n = self.n_devices
        if src == dst or not (0 <= src < n and 0 <= dst < n):
            raise ValueError(f"bad route {src} -> {dst} on {n}-device ring")
        fwd = (dst - src) % n
        bwd = (src - dst) % n
        return (fwd, +1) if fwd <= bwd else (bwd, -1)

    def transfer(self, src: int, dst: int, nbytes: int, issue_ns: float) -> float:
        """Arrival time (ns) of an ``nbytes`` burst issued at ``issue_ns``.

        Mutates the egress-port busy state (contention) and returns when the
        burst becomes *deliverable* at the destination directory.
        """
        hops, direction = self.route(src, dst)
        port = (src, direction)
        start = max(issue_ns, self._busy_until_ns.get(port, 0.0))
        ser_ns = max(0, nbytes) / self.link_bw_bytes_per_ns
        self._busy_until_ns[port] = start + ser_ns
        self.stats["messages"] += 1
        self.stats["bytes"] += max(0, nbytes)
        self.stats["queued_ns"] += start - issue_ns
        return start + ser_ns + hops * self.hop_latency_ns
