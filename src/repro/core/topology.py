"""Interconnect topology, collective-cost model, and the tiered fabric.

Maps the paper's xGMI fabric onto the TPU v5e target: a 2D ICI torus within a
pod (16x16 for the production mesh) and a lower-bandwidth inter-pod fabric for
the ``pod`` axis.  Collective costs use standard ring/bidirectional-ring
algebra; they feed the roofline's collective term cross-check and generate
arrival schedules for Eidola pod-scale replay (each ring step's completion is
one semaphore write — the TPU analogue of the paper's flag writes).

:class:`FabricModel` is the closed-loop counterpart: per-message routing over
a *tiered* fabric (intra-node ICI rings stitched by per-node DCI uplinks,
each egress port with its own serialization/contention state), which the
:class:`repro.core.cluster.Cluster` uses to derive physical arrival times for
emitted flag writes.  ``Topology.flat_ring`` / ``two_tier`` /
``for_devices`` make tier participation explicit, and
``FabricModel.from_topology`` derives the closed-loop shape from them.

Hardware constants follow the assignment: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Sequence, Tuple

__all__ = ["HardwareSpec", "Topology", "CollectiveCost", "FabricModel", "V5E"]

CollectiveKind = Literal[
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"
]


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_link_bw: float = 50e9           # bytes/s per link per direction
    ici_links_per_axis: int = 1         # links a ring along one axis can use
    ici_hop_latency_s: float = 1e-6
    dci_link_bw: float = 12.5e9         # inter-pod (pod axis) bandwidth
    dci_hop_latency_s: float = 10e-6
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3


V5E = HardwareSpec()


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    bytes_in: int          # per-device operand bytes
    axis_size: int
    link_bytes: int        # bytes crossing the busiest link
    time_s: float
    steps: int             # ring steps (used for arrival schedules)

    def arrival_times_s(self, start_s: float = 0.0) -> List[float]:
        """Completion time of each ring step (semaphore-write schedule)."""
        if self.steps <= 0:
            return [start_s]
        dt = self.time_s / self.steps
        return [start_s + dt * (i + 1) for i in range(self.steps)]


@dataclass(frozen=True)
class Topology:
    """A mesh of chips with per-axis fabric characteristics."""

    axis_sizes: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    hw: HardwareSpec = V5E
    # axes routed over the inter-pod fabric rather than intra-pod ICI
    dci_axes: Tuple[str, ...] = ("pod",)

    def __post_init__(self):
        if len(self.axis_sizes) != len(self.axis_names):
            raise ValueError("axis_sizes and axis_names length mismatch")

    @property
    def n_chips(self) -> int:
        return math.prod(self.axis_sizes)

    @property
    def devices_per_node(self) -> int:
        """Chips reachable over the intra-node (ICI) tier: the product of
        every axis NOT routed over the DCI fabric."""
        out = 1
        for n, s in zip(self.axis_names, self.axis_sizes):
            if n not in self.dci_axes:
                out *= s
        return out

    @property
    def n_nodes(self) -> int:
        """Number of nodes (DCI endpoints): the product of the DCI axes."""
        out = 1
        for n, s in zip(self.axis_names, self.axis_sizes):
            if n in self.dci_axes:
                out *= s
        return out

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    # ------------------------------------------------------------------
    # tier-explicit constructors (scenarios use these instead of spelling
    # out dci_axes, so tier participation is always intentional)
    # ------------------------------------------------------------------

    @classmethod
    def flat_ring(cls, n: int, axis: str = "ring", hw: HardwareSpec = V5E) -> "Topology":
        """A single-tier ring of ``n`` chips: every hop is intra-node ICI."""
        if n < 1:
            raise ValueError("flat_ring needs at least 1 chip")
        return cls(axis_sizes=(n,), axis_names=(axis,), hw=hw, dci_axes=())

    @classmethod
    def two_tier(
        cls,
        n_nodes: int,
        devices_per_node: int,
        hw: HardwareSpec = V5E,
        *,
        intra_axis: str = "ici",
        inter_axis: str = "dcn",
    ) -> "Topology":
        """``n_nodes`` nodes of ``devices_per_node`` chips each: the intra
        axis rides ICI, the inter axis rides the DCI fabric."""
        if n_nodes < 1 or devices_per_node < 1:
            raise ValueError("n_nodes and devices_per_node must be >= 1")
        return cls(
            axis_sizes=(n_nodes, devices_per_node),
            axis_names=(inter_axis, intra_axis),
            hw=hw,
            dci_axes=(inter_axis,),
        )

    @classmethod
    def for_devices(
        cls,
        n_devices: int,
        devices_per_node: Optional[int] = None,
        hw: HardwareSpec = V5E,
        *,
        intra_axis: str = "ici",
        inter_axis: str = "dcn",
    ) -> "Topology":
        """The closed-loop shape knob: ``devices_per_node=None`` (or >= the
        device count) is the flat single-tier ring; anything smaller groups
        the devices into nodes with a DCI tier between them."""
        if devices_per_node is None or devices_per_node >= n_devices:
            return cls.flat_ring(n_devices, axis=intra_axis, hw=hw)
        if devices_per_node < 1 or n_devices % devices_per_node:
            raise ValueError(
                f"devices_per_node={devices_per_node} must divide "
                f"n_devices={n_devices}"
            )
        return cls.two_tier(
            n_devices // devices_per_node,
            devices_per_node,
            hw,
            intra_axis=intra_axis,
            inter_axis=inter_axis,
        )

    def _fabric(self, axis: str) -> Tuple[float, float]:
        if axis in self.dci_axes:
            return self.hw.dci_link_bw, self.hw.dci_hop_latency_s
        return (
            self.hw.ici_link_bw * self.hw.ici_links_per_axis,
            self.hw.ici_hop_latency_s,
        )

    # ------------------------------------------------------------------
    # collective cost algebra (bidirectional ring per mesh axis)
    # ------------------------------------------------------------------

    def collective(self, kind: str, bytes_in: int, axis: str) -> CollectiveCost:
        """Cost of one collective of per-device operand size ``bytes_in``.

        bytes_in semantics per kind (per device):
          all-reduce      : the full reduced tensor's shard held per device
          all-gather      : the local shard that gets gathered
          reduce-scatter  : the full input that gets reduce-scattered
          all-to-all      : the full local buffer exchanged
          collective-permute : the buffer shifted to the neighbour
        """
        k = self.axis_size(axis)
        bw, lat = self._fabric(axis)
        if k <= 1:
            return CollectiveCost(kind, bytes_in, k, 0, 0.0, 0)
        if kind == "all-reduce":
            # reduce-scatter + all-gather, 2(k-1) steps of bytes/k
            link = 2 * bytes_in * (k - 1) // k
            steps = 2 * (k - 1)
        elif kind == "all-gather":
            link = bytes_in * (k - 1)
            steps = k - 1
        elif kind == "reduce-scatter":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "all-to-all":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "collective-permute":
            link = bytes_in
            steps = 1
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        time = link / bw + steps * lat
        return CollectiveCost(kind, bytes_in, k, link, time, steps)

    def flat_collective_seconds(self, total_bytes: int, axis: Optional[str] = None) -> float:
        """The assignment's flat roofline collective term:
        collective_bytes / link_bw (per chip)."""
        bw, _ = self._fabric(axis or self.axis_names[-1])
        return total_bytes / bw

    # ------------------------------------------------------------------

    def describe(self) -> str:
        axes = ", ".join(
            f"{n}={s}{' (DCI)' if n in self.dci_axes else ''}"
            for n, s in zip(self.axis_names, self.axis_sizes)
        )
        return f"<Topology {self.n_chips} chips: {axes}; {self.hw.name}>"


class FabricModel:
    """Per-message routing over a *tiered* fabric, with per-port contention.

    This is the closed-loop counterpart of :meth:`Topology.collective`: instead
    of pricing a whole collective in closed form, it prices *one xGMI write
    burst* from ``src`` to ``dst`` at a concrete issue time, so the
    :class:`repro.core.cluster.Cluster` can register the write into the
    destination device's WTT at a physically-derived arrival time.

    Devices are grouped into nodes of ``devices_per_node`` consecutive ids
    (``rank -> (node, local) = divmod(rank, devices_per_node)``); two tiers
    carry traffic:

    * **ICI (intra-node)** — the local ranks of one node form a bidirectional
      ring; one egress port per ``(device, direction)``.
    * **DCI (inter-node)** — the nodes form a bidirectional ring of gateway
      devices (local rank 0); each node owns one DCI uplink port per
      direction, with its *own* serialization/contention state.

    A same-node message is exactly the classic flat-ring model on the local
    ring.  A cross-node message composes up to three store-and-forward legs —
    ``intra (src -> gateway) -> DCI (gateway -> gateway) -> intra (gateway ->
    dst)`` — re-serializing and FIFO-queueing at each leg's egress port.  Per
    leg the cost is the paper-simple recipe the flat model used:

    * shortest-path hop count on the leg's ring x the tier's hop latency;
    * store-and-forward serialization of the burst on the egress port
      (``bytes / tier_link_bw``);
    * contention: each egress port is busy until its previous burst finished
      serializing, so back-to-back emissions queue up (FIFO per port).

    With one node (``devices_per_node >= n_devices``, the default when built
    from a device count) every message takes the single same-node leg and the
    model is bit-for-bit the old flat ring.

    All state updates are deterministic in emission order, which both engines
    reproduce identically (writes before transitions, devices in id order), so
    cycle/event runs stay bit-identical.
    """

    def __init__(
        self,
        n_devices: int,
        hw: HardwareSpec = V5E,
        *,
        devices_per_node: Optional[int] = None,
        hop_latency_ns: Optional[float] = None,
        link_bw_bytes_per_ns: Optional[float] = None,
        dci_hop_latency_ns: Optional[float] = None,
        dci_link_bw_bytes_per_ns: Optional[float] = None,
    ):
        if n_devices < 2:
            raise ValueError("a fabric needs at least 2 devices")
        self.n_devices = int(n_devices)
        self.hw = hw
        if devices_per_node is None or devices_per_node >= self.n_devices:
            devices_per_node = self.n_devices
        if devices_per_node < 1 or self.n_devices % devices_per_node:
            raise ValueError(
                f"devices_per_node={devices_per_node} must divide "
                f"n_devices={n_devices}"
            )
        self.devices_per_node = int(devices_per_node)
        self.n_nodes = self.n_devices // self.devices_per_node
        self.hop_latency_ns = (
            float(hop_latency_ns)
            if hop_latency_ns is not None
            else hw.ici_hop_latency_s * 1e9
        )
        self.link_bw_bytes_per_ns = (
            float(link_bw_bytes_per_ns)
            if link_bw_bytes_per_ns is not None
            else hw.ici_link_bw * self.hw.ici_links_per_axis / 1e9
        )
        self.dci_hop_latency_ns = (
            float(dci_hop_latency_ns)
            if dci_hop_latency_ns is not None
            else hw.dci_hop_latency_s * 1e9
        )
        self.dci_link_bw_bytes_per_ns = (
            float(dci_link_bw_bytes_per_ns)
            if dci_link_bw_bytes_per_ns is not None
            else hw.dci_link_bw / 1e9
        )
        if self.hop_latency_ns < 0 or self.link_bw_bytes_per_ns <= 0:
            raise ValueError("hop latency must be >= 0 and link bandwidth > 0")
        if self.dci_hop_latency_ns < 0 or self.dci_link_bw_bytes_per_ns <= 0:
            raise ValueError(
                "DCI hop latency must be >= 0 and DCI bandwidth > 0"
            )
        # ICI ports are (device, direction); DCI uplinks are ("dci", node,
        # direction) -> ns at which the egress port frees up
        self._busy_until_ns: Dict[Tuple, float] = {}
        self.stats = self._fresh_stats()

    @classmethod
    def from_topology(cls, topo: Topology, **overrides) -> "FabricModel":
        """The closed-loop fabric a :class:`Topology` describes: its non-DCI
        axes collapse into the intra-node tier, its DCI axes into the
        inter-node tier, with bandwidths/latencies from ``topo.hw`` (keyword
        overrides win, as in ``__init__``)."""
        return cls(
            topo.n_chips,
            topo.hw,
            devices_per_node=topo.devices_per_node,
            **overrides,
        )

    @staticmethod
    def _fresh_stats() -> Dict[str, float]:
        return {
            "messages": 0,
            "bytes": 0,
            "queued_ns": 0.0,
            # per-tier leg counters (a cross-node message counts one leg per
            # tier it traverses; totals above count each message once)
            "ici_messages": 0,
            "ici_bytes": 0,
            "ici_queued_ns": 0.0,
            "dci_messages": 0,
            "dci_bytes": 0,
            "dci_queued_ns": 0.0,
        }

    def reset(self) -> None:
        self._busy_until_ns.clear()
        self.stats = self._fresh_stats()

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    @staticmethod
    def _ring_route(src: int, dst: int, n: int) -> Tuple[int, int]:
        """(hops, direction) of the shortest path on an ``n``-ring."""
        fwd = (dst - src) % n
        bwd = (src - dst) % n
        return (fwd, +1) if fwd <= bwd else (bwd, -1)

    def _check(self, src: int, dst: int) -> None:
        n = self.n_devices
        if src == dst or not (0 <= src < n and 0 <= dst < n):
            raise ValueError(f"bad route {src} -> {dst} on {n}-device fabric")

    def node_of(self, device: int) -> int:
        return device // self.devices_per_node

    def route(self, src: int, dst: int) -> Tuple[int, int]:
        """(hops, direction) of the shortest same-ring path; +1 = ascending.

        Valid for same-node pairs (the intra ring; with one node that is every
        pair, matching the old flat model).  Cross-node pairs take a composed
        tiered path — see :meth:`route_legs`.
        """
        self._check(src, dst)
        dpn = self.devices_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn != dn:
            raise ValueError(
                f"route {src} -> {dst} crosses nodes {sn} -> {dn}; tiered "
                "paths are described by route_legs()"
            )
        return self._ring_route(sl, dl, dpn)

    def route_legs(self, src: int, dst: int) -> List[Tuple[str, Tuple, int]]:
        """The composed path as ``(tier, egress_port, hops)`` legs.

        Same-node: one ``("ici", (src, dir), hops)`` leg.  Cross-node: an
        optional intra leg to the source gateway, a ``("dci", ("dci", node,
        dir), hops)`` uplink leg between gateways, and an optional intra leg
        from the destination gateway (zero-hop legs are omitted).
        """
        self._check(src, dst)
        dpn = self.devices_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn == dn:
            hops, d = self._ring_route(sl, dl, dpn)
            return [("ici", (src, d), hops)]
        legs: List[Tuple[str, Tuple, int]] = []
        if sl != 0:
            hops, d = self._ring_route(sl, 0, dpn)
            legs.append(("ici", (src, d), hops))
        nhops, nd = self._ring_route(sn, dn, self.n_nodes)
        legs.append(("dci", ("dci", sn, nd), nhops))
        if dl != 0:
            hops, d = self._ring_route(0, dl, dpn)
            legs.append(("ici", (dn * dpn, d), hops))
        return legs

    # ------------------------------------------------------------------
    # transfers
    # ------------------------------------------------------------------

    def _leg(
        self,
        tier: str,
        port: Tuple,
        nbytes: int,
        ready_ns: float,
        hops: int,
        bw: float,
        lat: float,
    ) -> float:
        """Serialize one burst on ``port`` (FIFO behind its previous burst)
        and propagate it ``hops`` hops; returns the leg's arrival time."""
        start = max(ready_ns, self._busy_until_ns.get(port, 0.0))
        ser_ns = nbytes / bw
        self._busy_until_ns[port] = start + ser_ns
        queued = start - ready_ns
        self.stats["queued_ns"] += queued
        self.stats[tier + "_messages"] += 1
        self.stats[tier + "_bytes"] += nbytes
        self.stats[tier + "_queued_ns"] += queued
        return start + ser_ns + hops * lat

    def transfer(self, src: int, dst: int, nbytes: int, issue_ns: float) -> float:
        """Arrival time (ns) of an ``nbytes`` burst issued at ``issue_ns``.

        Mutates the traversed egress ports' busy state (contention) and
        returns when the burst becomes *deliverable* at the destination
        directory.
        """
        self._check(src, dst)
        nb = max(0, nbytes)
        self.stats["messages"] += 1
        self.stats["bytes"] += nb
        dpn = self.devices_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        ici_bw = self.link_bw_bytes_per_ns
        ici_lat = self.hop_latency_ns
        if sn == dn:
            hops, d = self._ring_route(sl, dl, dpn)
            return self._leg("ici", (src, d), nb, issue_ns, hops, ici_bw, ici_lat)
        t = issue_ns
        if sl != 0:
            hops, d = self._ring_route(sl, 0, dpn)
            t = self._leg("ici", (src, d), nb, t, hops, ici_bw, ici_lat)
        nhops, nd = self._ring_route(sn, dn, self.n_nodes)
        t = self._leg(
            "dci",
            ("dci", sn, nd),
            nb,
            t,
            nhops,
            self.dci_link_bw_bytes_per_ns,
            self.dci_hop_latency_ns,
        )
        if dl != 0:
            hops, d = self._ring_route(0, dl, dpn)
            t = self._leg("ici", (dn * dpn, d), nb, t, hops, ici_bw, ici_lat)
        return t

    def transfer_batch(
        self,
        src: int,
        dsts: Sequence[int],
        nbytes: Sequence[int],
        issue_ns: float,
    ) -> List[float]:
        """Arrival times of ``len(dsts)`` bursts all issued by ``src`` at
        ``issue_ns`` — bit-identical to calling :meth:`transfer` once per
        destination in order, but priced per egress port in one vectorized
        pass.

        This is the ``all_to_all`` incast shape: a completing dispatch phase
        emits one burst to every peer at the same cycle, O(devices) messages
        per call and O(devices^2) per simulation, which per-message python
        routing made the closed-loop bottleneck.  Same-issue bursts on one
        egress port serialize back-to-back, so each port's queue is a prefix
        sum over its bursts' serialization times — computed here with one
        cumulative sum per port instead of a python transition per message.
        Cross-node batches fall back to the per-message path (their legs
        couple ports in issue order).
        """
        if len(dsts) != len(nbytes):
            raise ValueError("dsts and nbytes length mismatch")
        if (
            len(dsts) < 16  # numpy setup costs more than it saves
            or (
                self.n_nodes > 1
                and any(self.node_of(d) != self.node_of(src) for d in dsts)
            )
        ):
            return [
                self.transfer(src, d, nb, issue_ns)
                for d, nb in zip(dsts, nbytes)
            ]
        import numpy as np

        dpn = self.devices_per_node
        sl = src % dpn
        bw = self.link_bw_bytes_per_ns
        lat = self.hop_latency_ns
        arrivals = [0.0] * len(dsts)
        queued = [0.0] * len(dsts)
        # group by egress port (only two directions exist for one source),
        # preserving per-port emission order
        by_port: Dict[Tuple, Tuple[List[int], List[int], List[int]]] = {}
        for i, (dst, nb) in enumerate(zip(dsts, nbytes)):
            self._check(src, dst)
            hops, d = self._ring_route(sl, dst % dpn, dpn)
            idxs, hlist, blist = by_port.setdefault((src, d), ([], [], []))
            idxs.append(i)
            hlist.append(hops)
            blist.append(max(0, nb))
        for port, (idxs, hlist, blist) in by_port.items():
            b0 = self._busy_until_ns.get(port, 0.0)
            start0 = max(issue_ns, b0)
            # busy_k after burst k: start0 + ser_1 + ... + ser_k, accumulated
            # sequentially (np.cumsum) so each float add matches the loop
            chain = np.empty(len(idxs) + 1, dtype=np.float64)
            chain[0] = start0
            np.divide(blist, bw, out=chain[1:])
            busy = np.cumsum(chain)
            self._busy_until_ns[port] = float(busy[-1])
            # start of burst k is busy_{k-1}; arrival adds the hop latency
            for j, i in enumerate(idxs):
                arrivals[i] = float(busy[j + 1]) + hlist[j] * lat
                queued[i] = float(busy[j]) - issue_ns
        # totals accumulate in emission order, matching the sequential path's
        # float-add sequence exactly
        st = self.stats
        for i, nb in enumerate(nbytes):
            nb = max(0, nb)
            st["messages"] += 1
            st["bytes"] += nb
            st["queued_ns"] += queued[i]
            st["ici_messages"] += 1
            st["ici_bytes"] += nb
            st["ici_queued_ns"] += queued[i]
        return arrivals
