"""Interconnect topology and collective-cost model.

Maps the paper's xGMI fabric onto the TPU v5e target: a 2D ICI torus within a
pod (16x16 for the production mesh) and a lower-bandwidth inter-pod fabric for
the ``pod`` axis.  Collective costs use standard ring/bidirectional-ring
algebra; they feed the roofline's collective term cross-check and generate
arrival schedules for Eidola pod-scale replay (each ring step's completion is
one semaphore write — the TPU analogue of the paper's flag writes).

Hardware constants follow the assignment: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Sequence, Tuple

__all__ = ["HardwareSpec", "Topology", "CollectiveCost", "V5E"]

CollectiveKind = Literal[
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"
]


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_link_bw: float = 50e9           # bytes/s per link per direction
    ici_links_per_axis: int = 1         # links a ring along one axis can use
    ici_hop_latency_s: float = 1e-6
    dci_link_bw: float = 12.5e9         # inter-pod (pod axis) bandwidth
    dci_hop_latency_s: float = 10e-6
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3


V5E = HardwareSpec()


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    bytes_in: int          # per-device operand bytes
    axis_size: int
    link_bytes: int        # bytes crossing the busiest link
    time_s: float
    steps: int             # ring steps (used for arrival schedules)

    def arrival_times_s(self, start_s: float = 0.0) -> List[float]:
        """Completion time of each ring step (semaphore-write schedule)."""
        if self.steps <= 0:
            return [start_s]
        dt = self.time_s / self.steps
        return [start_s + dt * (i + 1) for i in range(self.steps)]


@dataclass(frozen=True)
class Topology:
    """A mesh of chips with per-axis fabric characteristics."""

    axis_sizes: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    hw: HardwareSpec = V5E
    # axes routed over the inter-pod fabric rather than intra-pod ICI
    dci_axes: Tuple[str, ...] = ("pod",)

    def __post_init__(self):
        if len(self.axis_sizes) != len(self.axis_names):
            raise ValueError("axis_sizes and axis_names length mismatch")

    @property
    def n_chips(self) -> int:
        return math.prod(self.axis_sizes)

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    def _fabric(self, axis: str) -> Tuple[float, float]:
        if axis in self.dci_axes:
            return self.hw.dci_link_bw, self.hw.dci_hop_latency_s
        return (
            self.hw.ici_link_bw * self.hw.ici_links_per_axis,
            self.hw.ici_hop_latency_s,
        )

    # ------------------------------------------------------------------
    # collective cost algebra (bidirectional ring per mesh axis)
    # ------------------------------------------------------------------

    def collective(self, kind: str, bytes_in: int, axis: str) -> CollectiveCost:
        """Cost of one collective of per-device operand size ``bytes_in``.

        bytes_in semantics per kind (per device):
          all-reduce      : the full reduced tensor's shard held per device
          all-gather      : the local shard that gets gathered
          reduce-scatter  : the full input that gets reduce-scattered
          all-to-all      : the full local buffer exchanged
          collective-permute : the buffer shifted to the neighbour
        """
        k = self.axis_size(axis)
        bw, lat = self._fabric(axis)
        if k <= 1:
            return CollectiveCost(kind, bytes_in, k, 0, 0.0, 0)
        if kind == "all-reduce":
            # reduce-scatter + all-gather, 2(k-1) steps of bytes/k
            link = 2 * bytes_in * (k - 1) // k
            steps = 2 * (k - 1)
        elif kind == "all-gather":
            link = bytes_in * (k - 1)
            steps = k - 1
        elif kind == "reduce-scatter":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "all-to-all":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "collective-permute":
            link = bytes_in
            steps = 1
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        time = link / bw + steps * lat
        return CollectiveCost(kind, bytes_in, k, link, time, steps)

    def flat_collective_seconds(self, total_bytes: int, axis: Optional[str] = None) -> float:
        """The assignment's flat roofline collective term:
        collective_bytes / link_bw (per chip)."""
        bw, _ = self._fabric(axis or self.axis_names[-1])
        return total_bytes / bw

    # ------------------------------------------------------------------

    def describe(self) -> str:
        axes = ", ".join(
            f"{n}={s}{' (DCI)' if n in self.dci_axes else ''}"
            for n, s in zip(self.axis_names, self.axis_sizes)
        )
        return f"<Topology {self.n_chips} chips: {axes}; {self.hw.name}>"
