"""Interconnect topology, collective-cost model, and the pluggable fabric.

Maps the paper's xGMI fabric onto the TPU v5e target: a 2D ICI torus within a
pod (16x16 for the production mesh) and a lower-bandwidth inter-pod fabric for
the ``pod`` axis.  Collective costs use standard ring/bidirectional-ring
algebra; they feed the roofline's collective term cross-check and generate
arrival schedules for Eidola pod-scale replay (each ring step's completion is
one semaphore write — the TPU analogue of the paper's flag writes).

:class:`FabricModel` is the closed-loop counterpart: per-message routing over
a graph-based fabric described by an
:class:`repro.core.interconnect.InterconnectSpec` — typed link classes,
first-class egress ports with their own serialization/contention state, and a
:class:`repro.core.interconnect.RoutingPolicy` whose per-pair legs are
memoized into a route table.  The :class:`repro.core.cluster.Cluster` uses it
to derive physical arrival times for emitted flag writes.
``Topology.flat_ring`` / ``two_tier`` / ``for_devices`` make tier
participation explicit, and ``FabricModel.from_topology`` derives the
closed-loop shape from them (``ring`` / ``two_tier`` presets, bit-identical
to the original hard-coded router); ``fabric="fat_tree"`` /
``"rail_optimized"`` / ``"torus2d"`` select the richer presets.

Hardware constants follow the assignment: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI (:class:`HardwareSpec` lives in
:mod:`repro.core.interconnect` and is re-exported here).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Literal, Optional, Sequence, Tuple

from .interconnect import (
    V5E,
    FabricLike,
    HardwareSpec,
    InterconnectSpec,
    Leg,
    _ring_route,
    build_fabric,
    resolve_fabric,
)

__all__ = ["HardwareSpec", "Topology", "CollectiveCost", "FabricModel", "V5E"]

CollectiveKind = Literal[
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute"
]


@dataclass(frozen=True)
class CollectiveCost:
    kind: str
    bytes_in: int          # per-device operand bytes
    axis_size: int
    link_bytes: int        # bytes crossing the busiest link
    time_s: float
    steps: int             # ring steps (used for arrival schedules)

    def arrival_times_s(self, start_s: float = 0.0) -> List[float]:
        """Completion time of each ring step (semaphore-write schedule)."""
        if self.steps <= 0:
            return [start_s]
        dt = self.time_s / self.steps
        return [start_s + dt * (i + 1) for i in range(self.steps)]


@dataclass(frozen=True)
class Topology:
    """A mesh of chips with per-axis fabric characteristics."""

    axis_sizes: Tuple[int, ...] = (16, 16)
    axis_names: Tuple[str, ...] = ("data", "model")
    hw: HardwareSpec = V5E
    # axes routed over the inter-pod fabric rather than intra-pod ICI
    dci_axes: Tuple[str, ...] = ("pod",)

    def __post_init__(self):
        if len(self.axis_sizes) != len(self.axis_names):
            raise ValueError("axis_sizes and axis_names length mismatch")

    @property
    def n_chips(self) -> int:
        return math.prod(self.axis_sizes)

    @property
    def devices_per_node(self) -> int:
        """Chips reachable over the intra-node (ICI) tier: the product of
        every axis NOT routed over the DCI fabric."""
        out = 1
        for n, s in zip(self.axis_names, self.axis_sizes):
            if n not in self.dci_axes:
                out *= s
        return out

    @property
    def n_nodes(self) -> int:
        """Number of nodes (DCI endpoints): the product of the DCI axes."""
        out = 1
        for n, s in zip(self.axis_names, self.axis_sizes):
            if n in self.dci_axes:
                out *= s
        return out

    def axis_size(self, name: str) -> int:
        return self.axis_sizes[self.axis_names.index(name)]

    # ------------------------------------------------------------------
    # tier-explicit constructors (scenarios use these instead of spelling
    # out dci_axes, so tier participation is always intentional)
    # ------------------------------------------------------------------

    @classmethod
    def flat_ring(cls, n: int, axis: str = "ring", hw: HardwareSpec = V5E) -> "Topology":
        """A single-tier ring of ``n`` chips: every hop is intra-node ICI."""
        if n < 1:
            raise ValueError("flat_ring needs at least 1 chip")
        return cls(axis_sizes=(n,), axis_names=(axis,), hw=hw, dci_axes=())

    @classmethod
    def two_tier(
        cls,
        n_nodes: int,
        devices_per_node: int,
        hw: HardwareSpec = V5E,
        *,
        intra_axis: str = "ici",
        inter_axis: str = "dcn",
    ) -> "Topology":
        """``n_nodes`` nodes of ``devices_per_node`` chips each: the intra
        axis rides ICI, the inter axis rides the DCI fabric."""
        if n_nodes < 1 or devices_per_node < 1:
            raise ValueError("n_nodes and devices_per_node must be >= 1")
        return cls(
            axis_sizes=(n_nodes, devices_per_node),
            axis_names=(inter_axis, intra_axis),
            hw=hw,
            dci_axes=(inter_axis,),
        )

    @classmethod
    def for_devices(
        cls,
        n_devices: int,
        devices_per_node: Optional[int] = None,
        hw: HardwareSpec = V5E,
        *,
        intra_axis: str = "ici",
        inter_axis: str = "dcn",
    ) -> "Topology":
        """The closed-loop shape knob: ``devices_per_node=None`` (or >= the
        device count) is the flat single-tier ring; anything smaller groups
        the devices into nodes with a DCI tier between them."""
        if devices_per_node is None or devices_per_node >= n_devices:
            return cls.flat_ring(n_devices, axis=intra_axis, hw=hw)
        if devices_per_node < 1 or n_devices % devices_per_node:
            raise ValueError(
                f"devices_per_node={devices_per_node} must divide "
                f"n_devices={n_devices}"
            )
        return cls.two_tier(
            n_devices // devices_per_node,
            devices_per_node,
            hw,
            intra_axis=intra_axis,
            inter_axis=inter_axis,
        )

    def _fabric(self, axis: str) -> Tuple[float, float]:
        if axis in self.dci_axes:
            return self.hw.dci_link_bw, self.hw.dci_hop_latency_s
        return (
            self.hw.ici_link_bw * self.hw.ici_links_per_axis,
            self.hw.ici_hop_latency_s,
        )

    # ------------------------------------------------------------------
    # collective cost algebra (bidirectional ring per mesh axis)
    # ------------------------------------------------------------------

    def collective(self, kind: str, bytes_in: int, axis: str) -> CollectiveCost:
        """Cost of one collective of per-device operand size ``bytes_in``.

        bytes_in semantics per kind (per device):
          all-reduce      : the full reduced tensor's shard held per device
          all-gather      : the local shard that gets gathered
          reduce-scatter  : the full input that gets reduce-scattered
          all-to-all      : the full local buffer exchanged
          collective-permute : the buffer shifted to the neighbour
        """
        k = self.axis_size(axis)
        bw, lat = self._fabric(axis)
        if k <= 1:
            return CollectiveCost(kind, bytes_in, k, 0, 0.0, 0)
        if kind == "all-reduce":
            # reduce-scatter + all-gather, 2(k-1) steps of bytes/k
            link = 2 * bytes_in * (k - 1) // k
            steps = 2 * (k - 1)
        elif kind == "all-gather":
            link = bytes_in * (k - 1)
            steps = k - 1
        elif kind == "reduce-scatter":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "all-to-all":
            link = bytes_in * (k - 1) // k
            steps = k - 1
        elif kind == "collective-permute":
            link = bytes_in
            steps = 1
        else:
            raise ValueError(f"unknown collective kind {kind!r}")
        time = link / bw + steps * lat
        return CollectiveCost(kind, bytes_in, k, link, time, steps)

    def flat_collective_seconds(self, total_bytes: int, axis: Optional[str] = None) -> float:
        """The assignment's flat roofline collective term:
        collective_bytes / link_bw (per chip)."""
        bw, _ = self._fabric(axis or self.axis_names[-1])
        return total_bytes / bw

    # ------------------------------------------------------------------

    def describe(self) -> str:
        axes = ", ".join(
            f"{n}={s}{' (DCI)' if n in self.dci_axes else ''}"
            for n, s in zip(self.axis_names, self.axis_sizes)
        )
        return f"<Topology {self.n_chips} chips: {axes}; {self.hw.name}>"


class FabricModel:
    """Per-message routing over a pluggable fabric, with per-port contention.

    This is the closed-loop counterpart of :meth:`Topology.collective`: instead
    of pricing a whole collective in closed form, it prices *one xGMI write
    burst* from ``src`` to ``dst`` at a concrete issue time, so the
    :class:`repro.core.cluster.Cluster` can register the write into the
    destination device's WTT at a physically-derived arrival time.

    The fabric's *shape* is an :class:`repro.core.interconnect.InterconnectSpec`:
    typed link classes, declared egress ports, and a routing policy whose
    per-pair legs are memoized into a route table (computed once per pair,
    never per message).  Pricing one message walks its legs — per leg:

    * store-and-forward serialization of the burst on the leg's egress port
      (``bytes / class_bw``), FIFO behind the port's previous burst
      (contention: back-to-back emissions queue up per port);
    * shortest-path hop count x the link class's hop latency.

    ``stats`` counts messages/bytes/queueing in total and per link class
    (``ici_*`` / ``dci_*`` / ``spine_*`` / ``rail_*`` / ...), and
    ``port_stats`` holds the same triple per egress port (the per-port sums
    equal the per-class sums — a tested invariant).

    The legacy constructor knobs build the ``ring`` / ``two_tier`` presets,
    bit-identical to the original hard-coded router: with one node
    (``devices_per_node >= n_devices``, the default when built from a device
    count) every message takes a single same-ring leg and the model is
    bit-for-bit the old flat ring.

    All state updates are deterministic in emission order, which both engines
    reproduce identically (writes before transitions, devices in id order), so
    cycle/event runs stay bit-identical.
    """

    def __init__(
        self,
        n_devices: Optional[int] = None,
        hw: HardwareSpec = V5E,
        *,
        devices_per_node: Optional[int] = None,
        hop_latency_ns: Optional[float] = None,
        link_bw_bytes_per_ns: Optional[float] = None,
        dci_hop_latency_ns: Optional[float] = None,
        dci_link_bw_bytes_per_ns: Optional[float] = None,
        spec: Optional[InterconnectSpec] = None,
    ):
        if isinstance(n_devices, InterconnectSpec):
            if spec is not None:
                raise ValueError("pass the spec once, not twice")
            spec, n_devices = n_devices, None
        if spec is None:
            if n_devices is None:
                raise ValueError("FabricModel needs n_devices or a spec")
            if n_devices < 2:
                raise ValueError("a fabric needs at least 2 devices")
            n_devices = int(n_devices)
            if devices_per_node is None or devices_per_node >= n_devices:
                devices_per_node = n_devices
            if devices_per_node < 1 or n_devices % devices_per_node:
                raise ValueError(
                    f"devices_per_node={devices_per_node} must divide "
                    f"n_devices={n_devices}"
                )
            link_bw: Dict[str, float] = {}
            link_lat: Dict[str, float] = {}
            if link_bw_bytes_per_ns is not None:
                link_bw["ici"] = float(link_bw_bytes_per_ns)
            if hop_latency_ns is not None:
                link_lat["ici"] = float(hop_latency_ns)
            if dci_link_bw_bytes_per_ns is not None:
                link_bw["dci"] = float(dci_link_bw_bytes_per_ns)
            if dci_hop_latency_ns is not None:
                link_lat["dci"] = float(dci_hop_latency_ns)
            spec = build_fabric(
                "two_tier" if devices_per_node < n_devices else "ring",
                n_devices,
                hw,
                devices_per_node=devices_per_node,
                link_bw=link_bw,
                link_latency_ns=link_lat,
            )
        elif n_devices is not None and int(n_devices) != spec.n_devices:
            raise ValueError(
                f"n_devices={n_devices} contradicts spec.n_devices="
                f"{spec.n_devices}"
            )
        self.spec = spec
        self.hw = hw
        self.n_devices = spec.n_devices
        self.devices_per_node = spec.devices_per_node
        self.n_nodes = spec.n_nodes
        # (bw_bytes_per_ns, hop_latency_ns) per link class, resolved once
        self._cls: Dict[str, Tuple[float, float]] = {
            name: (lc.bw_bytes_per_ns, lc.hop_latency_ns)
            for name, lc in spec.link_classes.items()
        }
        # memoized per-pair leg table (the RoutingPolicy runs once per pair)
        self._leg_table: Dict[Tuple[int, int], Tuple[Leg, ...]] = {}
        # egress port -> ns at which the port frees up
        self._busy_until_ns: Dict[Tuple, float] = {}
        self.stats = self._fresh_stats()
        # egress port -> [messages, bytes, queued_ns]
        self.port_stats: Dict[Tuple, List[float]] = self._fresh_port_stats()

    @classmethod
    def from_spec(cls, spec: InterconnectSpec) -> "FabricModel":
        """The fabric an :class:`InterconnectSpec` describes, verbatim."""
        return cls(spec=spec)

    @classmethod
    def from_topology(
        cls,
        topo: Topology,
        *,
        fabric: FabricLike = None,
        link_bw: Optional[Dict[str, float]] = None,
        link_latency_ns: Optional[Dict[str, float]] = None,
        **overrides,
    ) -> "FabricModel":
        """The closed-loop fabric a :class:`Topology` describes: its non-DCI
        axes collapse into the intra-node tier, its DCI axes into the
        inter-node tier (the ``ring``/``two_tier`` presets), with
        bandwidths/latencies from ``topo.hw``.

        ``fabric`` selects a different registered preset (or passes a
        ready-built spec); ``link_bw``/``link_latency_ns`` override per link
        *class* (bytes/ns == GB/s, and ns) — unknown class names raise an
        error listing the fabric's valid classes.  The legacy scalar keywords
        (``hop_latency_ns`` etc.) keep working as ici/dci aliases; anything
        else is rejected rather than silently ignored."""
        link_bw = dict(link_bw or {})
        link_latency_ns = dict(link_latency_ns or {})
        legacy = {
            "link_bw_bytes_per_ns": (link_bw, "ici"),
            "dci_link_bw_bytes_per_ns": (link_bw, "dci"),
            "hop_latency_ns": (link_latency_ns, "ici"),
            "dci_hop_latency_ns": (link_latency_ns, "dci"),
        }
        for key, val in overrides.items():
            if key not in legacy:
                raise ValueError(
                    f"unknown FabricModel override {key!r}; pass per-class "
                    "overrides via link_bw=/link_latency_ns= (valid keys: "
                    f"{sorted(legacy)})"
                )
            if val is not None:
                target, cls_name = legacy[key]
                target.setdefault(cls_name, float(val))
        spec = resolve_fabric(
            fabric,
            topo.n_chips,
            topo.hw,
            devices_per_node=topo.devices_per_node,
            link_bw=link_bw,
            link_latency_ns=link_latency_ns,
        )
        if spec is not None:
            return cls(spec=spec)
        return cls(
            topo.n_chips, topo.hw, devices_per_node=topo.devices_per_node
        )

    def _fresh_stats(self) -> Dict[str, float]:
        st: Dict[str, float] = {"messages": 0, "bytes": 0, "queued_ns": 0.0}
        # per-class leg counters (a multi-leg message counts one leg per
        # class it traverses; totals above count each message once), in
        # sorted class order so stats dicts diff stably across runs
        for name in sorted(self.spec.link_classes):
            st[name + "_messages"] = 0
            st[name + "_bytes"] = 0
            st[name + "_queued_ns"] = 0.0
        return st

    def _fresh_port_stats(self) -> Dict[Tuple, List[float]]:
        # every declared egress port pre-seeded at zero, in deterministic
        # order (port keys mix ints and strs, so sort by repr); ports a
        # routing policy synthesizes outside the declaration still appear on
        # first touch, after the declared block
        return {p: [0, 0, 0.0] for p in sorted(self.spec.ports, key=repr)}

    def reset(self) -> None:
        self._busy_until_ns.clear()
        self.stats = self._fresh_stats()
        self.port_stats = self._fresh_port_stats()

    # ------------------------------------------------------------------
    # routing
    # ------------------------------------------------------------------

    def _check(self, src: int, dst: int) -> None:
        n = self.n_devices
        if src == dst or not (0 <= src < n and 0 <= dst < n):
            raise ValueError(f"bad route {src} -> {dst} on {n}-device fabric")

    def node_of(self, device: int) -> int:
        return device // self.devices_per_node

    def legs(self, src: int, dst: int) -> Tuple[Leg, ...]:
        """The routed path of one device pair, from the memoized per-pair
        table (the :class:`RoutingPolicy` runs once per pair)."""
        self._check(src, dst)
        key = (src, dst)
        legs = self._leg_table.get(key)
        if legs is None:
            legs = tuple(self.spec.routing.legs(self.spec, src, dst))
            self._leg_table[key] = legs
        return legs

    def route_table(self) -> Dict[Tuple[int, int], Tuple[Leg, ...]]:
        """Materialize (and return) the full per-pair leg table."""
        n = self.n_devices
        for src in range(n):
            for dst in range(n):
                if src != dst:
                    self.legs(src, dst)
        return dict(self._leg_table)

    def route(self, src: int, dst: int) -> Tuple[int, int]:
        """(hops, direction) of the shortest same-ring path; +1 = ascending.

        Valid for same-node pairs (the intra ring; with one node that is every
        pair, matching the old flat model).  Cross-node pairs take a composed
        multi-leg path — see :meth:`route_legs`.
        """
        self._check(src, dst)
        dpn = self.devices_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn != dn:
            raise ValueError(
                f"route {src} -> {dst} crosses nodes {sn} -> {dn}; composed "
                "paths are described by route_legs()"
            )
        return _ring_route(sl, dl, dpn)

    def route_legs(self, src: int, dst: int) -> List[Tuple[str, Tuple, int]]:
        """The composed path as ``(link_class, egress_port, hops)`` legs.

        The legacy view of :meth:`legs` — e.g. on the ``two_tier`` preset a
        same-node pair is one ``("ici", (src, dir), hops)`` leg and a
        cross-node pair composes an optional intra leg to the source gateway,
        a ``("dci", ("dci", node, dir), hops)`` uplink leg between gateways,
        and an optional intra leg from the destination gateway (zero-hop legs
        are omitted).
        """
        return [(leg.cls, leg.port, leg.hops) for leg in self.legs(src, dst)]

    # ------------------------------------------------------------------
    # transfers
    # ------------------------------------------------------------------

    def _leg(
        self,
        tier: str,
        port: Tuple,
        nbytes: int,
        ready_ns: float,
        hops: int,
        bw: float,
        lat: float,
    ) -> float:
        """Serialize one burst on ``port`` (FIFO behind its previous burst)
        and propagate it ``hops`` hops; returns the leg's arrival time."""
        start = max(ready_ns, self._busy_until_ns.get(port, 0.0))
        ser_ns = nbytes / bw
        self._busy_until_ns[port] = start + ser_ns
        queued = start - ready_ns
        self.stats["queued_ns"] += queued
        self.stats[tier + "_messages"] += 1
        self.stats[tier + "_bytes"] += nbytes
        self.stats[tier + "_queued_ns"] += queued
        ps = self.port_stats.get(port)
        if ps is None:
            ps = self.port_stats[port] = [0, 0, 0.0]
        ps[0] += 1
        ps[1] += nbytes
        ps[2] += queued
        return start + ser_ns + hops * lat

    def transfer(self, src: int, dst: int, nbytes: int, issue_ns: float) -> float:
        """Arrival time (ns) of an ``nbytes`` burst issued at ``issue_ns``.

        Mutates the traversed egress ports' busy state (contention) and
        returns when the burst becomes *deliverable* at the destination
        directory.
        """
        nb = max(0, nbytes)
        legs = self.legs(src, dst)
        self.stats["messages"] += 1
        self.stats["bytes"] += nb
        t = issue_ns
        cls = self._cls
        for leg in legs:
            bw, lat = cls[leg.cls]
            t = self._leg(leg.cls, leg.port, nb, t, leg.hops, bw, lat)
        return t

    def transfer_batch(
        self,
        src: int,
        dsts: Sequence[int],
        nbytes: Sequence[int],
        issue_ns: float,
    ) -> List[float]:
        """Arrival times of ``len(dsts)`` bursts all issued by ``src`` at
        ``issue_ns`` — bit-identical to calling :meth:`transfer` once per
        destination in order, but priced per egress port in one vectorized
        pass.

        This is the ``all_to_all`` incast shape: a completing dispatch phase
        emits one burst to every peer at the same cycle, O(devices) messages
        per call and O(devices^2) per simulation, which per-message python
        routing made the closed-loop bottleneck.  Same-issue bursts on one
        egress port serialize back-to-back, so each port's queue is a prefix
        sum over its bursts' serialization times — computed here with one
        cumulative sum per port instead of a python transition per message.
        Batches with any multi-leg route fall back to the per-message path
        (their legs couple ports in issue order).
        """
        if len(dsts) != len(nbytes):
            raise ValueError("dsts and nbytes length mismatch")
        single = len(dsts) >= 16  # below that, numpy setup costs more
        if single:
            for d in dsts:
                if len(self.legs(src, d)) != 1:
                    single = False
                    break
        if not single:
            return [
                self.transfer(src, d, nb, issue_ns)
                for d, nb in zip(dsts, nbytes)
            ]
        import numpy as np

        arrivals = [0.0] * len(dsts)
        queued = [0.0] * len(dsts)
        # group by egress port, preserving per-port emission order
        by_port: Dict[Tuple, Tuple[str, List[int], List[int], List[int]]] = {}
        for i, (dst, nb) in enumerate(zip(dsts, nbytes)):
            (leg,) = self.legs(src, dst)
            entry = by_port.get(leg.port)
            if entry is None:
                entry = by_port[leg.port] = (leg.cls, [], [], [])
            _, idxs, hlist, blist = entry
            idxs.append(i)
            hlist.append(leg.hops)
            blist.append(max(0, nb))
        leg_cls = [None] * len(dsts)
        for port, (cname, idxs, hlist, blist) in by_port.items():
            bw, lat = self._cls[cname]
            b0 = self._busy_until_ns.get(port, 0.0)
            start0 = max(issue_ns, b0)
            # busy_k after burst k: start0 + ser_1 + ... + ser_k, accumulated
            # sequentially (np.cumsum) so each float add matches the loop
            chain = np.empty(len(idxs) + 1, dtype=np.float64)
            chain[0] = start0
            np.divide(blist, bw, out=chain[1:])
            busy = np.cumsum(chain)
            self._busy_until_ns[port] = float(busy[-1])
            ps = self.port_stats.get(port)
            if ps is None:
                ps = self.port_stats[port] = [0, 0, 0.0]
            # start of burst k is busy_{k-1}; arrival adds the hop latency
            for j, i in enumerate(idxs):
                arrivals[i] = float(busy[j + 1]) + hlist[j] * lat
                q = float(busy[j]) - issue_ns
                queued[i] = q
                leg_cls[i] = cname
                ps[0] += 1
                ps[1] += max(0, nbytes[i])
                ps[2] += q
        # totals accumulate in emission order, matching the sequential path's
        # float-add sequence exactly
        st = self.stats
        for i, nb in enumerate(nbytes):
            nb = max(0, nb)
            cname = leg_cls[i]
            st["messages"] += 1
            st["bytes"] += nb
            st["queued_ns"] += queued[i]
            st[cname + "_messages"] += 1
            st[cname + "_bytes"] += nb
            st[cname + "_queued_ns"] += queued[i]
        return arrivals
