"""Simulator + application configuration (mirrors the paper's Table 1)."""

from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Dict, Optional

__all__ = ["SyncPolicy", "EngineKind", "SimConfig"]


class SyncPolicy(str, enum.Enum):
    SPIN = "spin"          # baseline spin-wait polling loop (paper Fig. 6)
    SYNCMON = "syncmon"    # SyncMon-inspired monitor()/mwait() (paper Fig. 9)


class EngineKind(str, enum.Enum):
    CYCLE = "cycle"    # faithful per-cycle WTT head poll (paper §3.1)
    EVENT = "event"    # gem5-native event queue (paper §3.2.2, built here)
    VECTOR = "vector"  # vectorized batch replay (TPU-idiomatic rethink)


@dataclass(frozen=True)
class SimConfig:
    """Configuration for one Eidola kernel-launch simulation.

    Defaults reproduce the paper's Table 1:
      4 CUs in the simulated GPU, 3 emulated GPUs, 208 workgroups/GPU,
      M=256, K=8192, N=1.
    """

    # --- simulation configuration (Table 1, top half) ---
    n_cus: int = 4
    n_egpus: int = 3
    workgroups: int = 208
    clock_ghz: float = 1.5

    # --- application configuration (Table 1, bottom half) ---
    M: int = 256
    K: int = 8192          # TOTAL reduction dim; per-device slice is K/n_devices
    N: int = 1
    weak_scaling: bool = False  # if True, per-device slice is fixed at k_slice
    k_slice_override: Optional[int] = None

    # --- device timing model ---
    elem_bytes: int = 4
    sector_bytes: int = 32          # read granularity; 2 MB slice / 32 B = 65,536
    macs_per_cycle_per_cu: float = 128.0
    sectors_per_cycle_per_cu: float = 16.0
    dispatch_stagger_cycles: int = 8     # per-WG wave stagger on a CU
    flag_write_cycles: int = 8           # per peer-flag xGMI write issue
    reduce_cycles_per_row: int = 16
    broadcast_cycles_per_row: int = 4

    # --- synchronization model ---
    sync: SyncPolicy = SyncPolicy.SPIN
    poll_interval_cycles: int = 64  # spin loop period on an unset flag
    flag_check_cycles: int = 4      # observe-and-advance cost on a set flag
    wake_latency_cycles: int = 32   # SyncMon wake -> schedulable latency
    monitor_semantics: str = "mesa"
    # Calibrated race-window: cycles between the check read and the monitor
    # arming during which an arriving write causes an immediate mwait return
    # (and hence an extra validation read).  See EXPERIMENTS.md calibration.
    monitor_arm_cycles: int = 24

    # Woken wavefronts' first re-read is satisfied by the fill the waking
    # write triggered at the directory; simultaneous same-line validation
    # reads on one CU coalesce in pairs at the L1 MSHRs.  Subsequent
    # sequential flag checks miss (different lines, requeue jitter breaks
    # lockstep).  See EXPERIMENTS.md §SyncMon-calibration.
    wake_coalesce_width: int = 2
    requeue_jitter_mod: int = 16    # per-WG post-wake scheduler jitter (cycles)

    # xGMI directory visibility: a registered write issued at wakeupTime
    # becomes visible to the target's polls this much later (fabric hop +
    # directory processing under load).
    xgmi_enact_latency_ns: float = 1500.0

    # --- traffic replay ---
    include_data_writes: bool = True  # peers push partial tiles before flags
    data_write_lead_ns: float = 120.0  # partials land this long before the flag

    # --- engine selection ---
    engine: EngineKind = EngineKind.EVENT

    # --- reproducibility ---
    seed: int = 0

    # ------------------------------------------------------------------
    # derived quantities
    # ------------------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.n_egpus + 1

    @property
    def k_slice(self) -> int:
        """Per-device K slice (column-parallel GEMV partitioning)."""
        if self.k_slice_override is not None:
            return self.k_slice_override
        if self.weak_scaling:
            return self.K
        if self.K % self.n_devices:
            raise ValueError(
                f"K={self.K} not divisible by n_devices={self.n_devices}"
            )
        return self.K // self.n_devices

    @property
    def rows_per_device(self) -> int:
        if self.M % self.n_devices:
            raise ValueError(
                f"M={self.M} not divisible by n_devices={self.n_devices}"
            )
        return self.M // self.n_devices

    @property
    def wg_mac_throughput(self) -> float:
        """Effective MACs/cycle per workgroup (symmetric CU sharing)."""
        return self.macs_per_cycle_per_cu * self.n_cus / self.workgroups

    @property
    def wg_sector_throughput(self) -> float:
        return self.sectors_per_cycle_per_cu * self.n_cus / self.workgroups

    @property
    def sectors_per_row(self) -> int:
        import math

        return math.ceil(self.k_slice * self.elem_bytes / self.sector_bytes)

    @property
    def row_cycles(self) -> int:
        """Cycles for one workgroup to produce one output-row partial."""
        import math

        compute = self.k_slice * self.N / self.wg_mac_throughput
        memory = self.sectors_per_row / self.wg_sector_throughput
        return max(1, math.ceil(max(compute, memory)))

    def ns_to_cycles(self, ns: float) -> int:
        return int(round(ns * self.clock_ghz))

    def cycles_to_ns(self, cycles: float) -> float:
        return cycles / self.clock_ghz

    def with_(self, **kw) -> "SimConfig":
        return replace(self, **kw)

    def with_devices(self, devices: int) -> "SimConfig":
        """Total-device-count sugar: ``n_egpus = devices - 1``.

        The single conversion point for every ``devices=`` surface
        (``simulate``, ``SweepRunner`` grids, the ``--devices`` CLI flag).
        """
        if devices < 2:
            raise ValueError("devices must be >= 2 (one target + peers)")
        return self.with_(n_egpus=int(devices) - 1)

    def validate(self) -> "SimConfig":
        """Scenario-independent sanity checks.

        GEMV-specific divisibility constraints (M, K vs. n_devices) are no
        longer enforced here — they fire lazily from ``k_slice`` /
        ``rows_per_device`` when the gemv_allreduce workload model actually
        uses them, so non-GEMV scenarios are free to pick any device count.
        """
        if self.n_cus <= 0 or self.workgroups <= 0 or self.n_egpus <= 0:
            raise ValueError("n_cus, workgroups, n_egpus must be positive")
        return self
