"""Graph-based interconnect API: fabric topologies as *data*, not code.

Eidola's value is isolating communication behaviour under different
interconnect scenarios, but the original ``FabricModel`` hard-coded exactly
two shapes (a flat ring, and a two-tier ring-of-rings).  Echo
(arXiv:2412.12487) and network-infrastructure testing work (arXiv:2504.20854)
both show that rail-optimized and oversubscribed fat-tree fabrics
qualitatively change collective behaviour at scale — reproducing that needs
topology to be pluggable.  This module is the redesigned seam:

* :class:`LinkClass` — a *typed link*: name + bandwidth (bytes/ns) + per-hop
  latency (ns).  Every fabric declares the classes its links belong to
  (``ici``, ``dci``, ``spine``, ``rail``, ``x``/``y``...), and the
  :class:`repro.core.topology.FabricModel` counts messages/bytes/queueing per
  class — the generalization of the old hard-wired ``ici_*``/``dci_*``
  counters.
* **Ports** — first-class egress-serialization points.  A port is a hashable
  key with a link class; each burst crossing a port serializes at the class
  bandwidth FIFO behind the port's previous burst.  This is where contention
  (and oversubscription) lives.
* :class:`Leg` — one store-and-forward step of a routed path: the egress
  port it serializes on, the hop count it propagates over, and its graph
  endpoints (used by the routing-invariant property tests).
* :class:`RoutingPolicy` — the protocol replacing the old hard-coded
  ``route_legs``: ``legs(spec, src, dst)`` returns the composed path, and the
  fabric model memoizes it into a per-pair leg table (computed once per pair,
  never per message).
* :class:`InterconnectSpec` — the whole fabric as one value: device/node
  shape (every node has >= 1 NIC), link classes, declared ports, and the
  routing policy.
* a preset registry (:func:`register_fabric` / :func:`get_fabric` /
  :func:`list_fabrics` / :func:`build_fabric`) shipping ``ring``,
  ``two_tier`` (bit-identical to the legacy tiered fabric), ``fat_tree``
  (configurable oversubscription), ``rail_optimized`` (k NICs/node,
  rail-aligned cross-node paths), and ``torus2d``.

Scenario code selects a fabric by name (``fabric="rail_optimized"``) or
passes a ready-built spec; ``--fabric``/``--link CLASS=GBPS`` expose the same
knobs on the CLI.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

__all__ = [
    "HardwareSpec",
    "V5E",
    "LinkClass",
    "Leg",
    "RoutingPolicy",
    "InterconnectSpec",
    "register_fabric",
    "get_fabric",
    "list_fabrics",
    "build_fabric",
    "resolve_fabric",
    "FabricLike",
]


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12     # per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_link_bw: float = 50e9           # bytes/s per link per direction
    ici_links_per_axis: int = 1         # links a ring along one axis can use
    ici_hop_latency_s: float = 1e-6
    dci_link_bw: float = 12.5e9         # inter-pod (pod axis) bandwidth
    dci_hop_latency_s: float = 10e-6
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3


V5E = HardwareSpec()


# ---------------------------------------------------------------------------
# typed links, ports, legs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LinkClass:
    """One class of link: every port of this class serializes at
    ``bw_bytes_per_ns`` and propagates at ``hop_latency_ns`` per hop."""

    name: str
    bw_bytes_per_ns: float
    hop_latency_ns: float

    def __post_init__(self) -> None:
        if self.bw_bytes_per_ns <= 0:
            raise ValueError(
                f"link class {self.name!r} bandwidth must be > 0"
            )
        if self.hop_latency_ns < 0:
            raise ValueError(
                f"link class {self.name!r} hop latency must be >= 0"
            )


# Graph endpoints are labelled tuples: ("dev", i) for a device, ("leaf", l)
# for a fat-tree leaf switch, ... — only routing-invariant tests interpret
# them; the pricing engine ignores them entirely.
Endpoint = Tuple

PortKey = Tuple


@dataclass(frozen=True)
class Leg:
    """One store-and-forward step of a routed path.

    cls   link class the leg rides (keys ``InterconnectSpec.link_classes``).
    port  egress port the burst serializes on (FIFO behind prior bursts).
    hops  number of hops the burst propagates after serializing (>= 1).
    src   graph endpoint the leg leaves from (e.g. ``("dev", 3)``).
    dst   graph endpoint the leg arrives at.
    """

    cls: str
    port: PortKey
    hops: int
    src: Endpoint
    dst: Endpoint


class RoutingPolicy:
    """Protocol: compute the composed path of one (src, dst) device pair.

    Implementations must be *pure* (same legs for the same pair every call):
    the fabric model memoizes results into a per-pair leg table, so routing
    runs once per pair, never per message."""

    def legs(
        self, spec: "InterconnectSpec", src: int, dst: int
    ) -> Tuple[Leg, ...]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# the spec
# ---------------------------------------------------------------------------


@dataclass
class InterconnectSpec:
    """A complete fabric: shape, typed links, declared ports, and routing.

    ``devices_per_node`` groups consecutive device ids into nodes (the unit
    that owns NICs); ``nics_per_node`` is how many independent egress NICs
    each node drives (>= 1; ``rail_optimized`` uses k).  ``link_classes``
    maps class name -> :class:`LinkClass`; ``ports`` maps every declared
    egress-port key -> its class name.  ``routing`` computes per-pair legs.

    Treat instances as immutable: derive variants with
    :meth:`with_link_overrides`.
    """

    name: str
    n_devices: int
    devices_per_node: int
    routing: RoutingPolicy
    link_classes: Dict[str, LinkClass]
    ports: Dict[PortKey, str]
    nics_per_node: int = 1
    params: Dict[str, object] = field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.n_devices < 2:
            raise ValueError("a fabric needs at least 2 devices")
        if self.devices_per_node < 1 or self.n_devices % self.devices_per_node:
            raise ValueError(
                f"devices_per_node={self.devices_per_node} must divide "
                f"n_devices={self.n_devices}"
            )
        if self.nics_per_node < 1:
            raise ValueError("every node needs at least 1 NIC")
        for port, cls in self.ports.items():
            if cls not in self.link_classes:
                raise ValueError(
                    f"port {port!r} declares unknown link class {cls!r}"
                )

    @property
    def n_nodes(self) -> int:
        return self.n_devices // self.devices_per_node

    def check_link_classes(self, names, *, what: str = "link override") -> None:
        """Raise an actionable error for any name not declared by this
        fabric (the ``--ici-bw``/``--dci-bw``/``--link`` validation path)."""
        for name in names:
            if name not in self.link_classes:
                raise ValueError(
                    f"unknown link class {name!r} in {what} for fabric "
                    f"{self.name!r}; valid classes: "
                    f"{sorted(self.link_classes)}"
                )

    def with_link_overrides(
        self,
        link_bw: Optional[Dict[str, float]] = None,
        link_latency_ns: Optional[Dict[str, float]] = None,
    ) -> "InterconnectSpec":
        """A copy with per-class bandwidth (bytes/ns == GB/s) and/or hop
        latency (ns) overridden.  Unknown class names raise, listing the
        fabric's valid classes."""
        link_bw = dict(link_bw or {})
        link_latency_ns = dict(link_latency_ns or {})
        if not link_bw and not link_latency_ns:
            return self
        self.check_link_classes(link_bw, what="link_bw override")
        self.check_link_classes(
            link_latency_ns, what="link_latency_ns override"
        )
        classes = {
            name: LinkClass(
                name,
                float(link_bw.get(name, lc.bw_bytes_per_ns)),
                float(link_latency_ns.get(name, lc.hop_latency_ns)),
            )
            for name, lc in self.link_classes.items()
        }
        return InterconnectSpec(
            name=self.name,
            n_devices=self.n_devices,
            devices_per_node=self.devices_per_node,
            routing=self.routing,
            link_classes=classes,
            ports=self.ports,
            nics_per_node=self.nics_per_node,
            params=dict(self.params),
        )

    def describe(self) -> str:
        cls = ", ".join(
            f"{c.name}={c.bw_bytes_per_ns:g}B/ns"
            for c in self.link_classes.values()
        )
        return (
            f"<InterconnectSpec {self.name}: {self.n_devices} devices, "
            f"{self.n_nodes} nodes x {self.devices_per_node}, "
            f"{self.nics_per_node} NIC/node; {cls}>"
        )


# ---------------------------------------------------------------------------
# shared routing helpers
# ---------------------------------------------------------------------------


def _ring_route(src: int, dst: int, n: int) -> Tuple[int, int]:
    """(hops, direction) of the shortest path on an ``n``-ring."""
    fwd = (dst - src) % n
    bwd = (src - dst) % n
    return (fwd, +1) if fwd <= bwd else (bwd, -1)


def _dev(i: int) -> Endpoint:
    return ("dev", i)


def _ici_leg(src_dev: int, dst_dev: int, local_src: int, local_dst: int,
             ring: int, port_dev: int) -> Leg:
    hops, d = _ring_route(local_src, local_dst, ring)
    return Leg("ici", (port_dev, d), hops, _dev(src_dev), _dev(dst_dev))


def _ici_ports(n_devices: int) -> Dict[PortKey, str]:
    ports: Dict[PortKey, str] = {}
    for dev in range(n_devices):
        ports[(dev, +1)] = "ici"
        ports[(dev, -1)] = "ici"
    return ports


# ---------------------------------------------------------------------------
# preset registry
# ---------------------------------------------------------------------------

FabricBuilder = Callable[..., InterconnectSpec]
_FABRICS: Dict[str, FabricBuilder] = {}


def register_fabric(name: str) -> Callable[[FabricBuilder], FabricBuilder]:
    """Decorator: register a fabric-spec builder under ``name``.

    Builders take ``(n_devices, hw=V5E, *, devices_per_node=None,
    **params)`` and return an :class:`InterconnectSpec`."""

    def deco(fn: FabricBuilder) -> FabricBuilder:
        existing = _FABRICS.get(name)
        if existing is not None and existing is not fn:
            raise ValueError(f"fabric preset {name!r} already registered")
        _FABRICS[name] = fn
        return fn

    return deco


def get_fabric(name: str) -> FabricBuilder:
    try:
        return _FABRICS[name]
    except KeyError:
        raise KeyError(
            f"unknown fabric preset {name!r}; available: {sorted(_FABRICS)}"
        ) from None


def list_fabrics() -> List[str]:
    return sorted(_FABRICS)


def build_fabric(
    name: str,
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
    link_bw: Optional[Dict[str, float]] = None,
    link_latency_ns: Optional[Dict[str, float]] = None,
    **params,
) -> InterconnectSpec:
    """Build a registered preset and apply per-class link overrides.

    ``link_bw`` values are bytes/ns, which is numerically GB/s — the CLI's
    ``--link dci=6.25`` maps straight through."""
    spec = get_fabric(name)(
        n_devices, hw, devices_per_node=devices_per_node, **params
    )
    return spec.with_link_overrides(link_bw, link_latency_ns)


FabricLike = Union[None, str, InterconnectSpec]


def resolve_fabric(
    fabric: FabricLike,
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
    link_bw: Optional[Dict[str, float]] = None,
    link_latency_ns: Optional[Dict[str, float]] = None,
    **params,
) -> Optional[InterconnectSpec]:
    """Resolve a scenario's ``fabric=`` argument to a spec (or ``None``).

    ``None`` with no link overrides returns ``None`` — the legacy path where
    the :class:`repro.core.cluster.Cluster` derives a ``ring``/``two_tier``
    fabric from the scenario's :class:`repro.core.topology.Topology`.  A
    string names a registered preset; a ready-built spec passes through
    (validated against the device count, with overrides applied)."""
    if isinstance(fabric, InterconnectSpec):
        if fabric.n_devices != n_devices:
            raise ValueError(
                f"fabric spec {fabric.name!r} models {fabric.n_devices} "
                f"devices but the scenario simulates {n_devices}"
            )
        return fabric.with_link_overrides(link_bw, link_latency_ns)
    if fabric is None:
        if not link_bw and not link_latency_ns:
            return None
        # overrides without a named preset apply to the default shape the
        # topology would have produced — through the validated path
        fabric = (
            "two_tier"
            if devices_per_node is not None and devices_per_node < n_devices
            else "ring"
        )
    return build_fabric(
        fabric,
        n_devices,
        hw,
        devices_per_node=devices_per_node,
        link_bw=link_bw,
        link_latency_ns=link_latency_ns,
        **params,
    )


# ---------------------------------------------------------------------------
# presets
# ---------------------------------------------------------------------------


def _std_classes(hw: HardwareSpec) -> Dict[str, LinkClass]:
    """The legacy ici/dci class pair, numerically identical to the original
    hard-coded fabric constants."""
    return {
        "ici": LinkClass(
            "ici",
            hw.ici_link_bw * hw.ici_links_per_axis / 1e9,
            hw.ici_hop_latency_s * 1e9,
        ),
        "dci": LinkClass("dci", hw.dci_link_bw / 1e9, hw.dci_hop_latency_s * 1e9),
    }


class _RingRouting(RoutingPolicy):
    """Single bidirectional ring over all devices: one ICI leg per pair."""

    def legs(self, spec, src, dst):
        n = spec.n_devices
        hops, d = _ring_route(src, dst, n)
        return (Leg("ici", (src, d), hops, _dev(src), _dev(dst)),)


@register_fabric("ring")
def ring_spec(
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
) -> InterconnectSpec:
    """flat bidirectional ring; every hop is intra-node ICI (the classic
    single-tier fabric)"""
    # the ring has no node-boundary routing, but a requested node split is
    # honored as grouping metadata (node_of / report shape) rather than
    # silently flattened
    return InterconnectSpec(
        name="ring",
        n_devices=n_devices,
        devices_per_node=devices_per_node or n_devices,
        routing=_RingRouting(),
        link_classes=_std_classes(hw),
        ports=_ici_ports(n_devices),
    )


class _TwoTierRouting(RoutingPolicy):
    """The legacy tiered router: intra-node bidirectional ICI rings stitched
    by a bidirectional DCI ring over per-node gateway devices (local rank 0).
    Leg composition and port keys are bit-identical to the original
    hard-coded ``route_legs``."""

    def legs(self, spec, src, dst):
        dpn = spec.devices_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn == dn:
            return (_ici_leg(src, dst, sl, dl, dpn, src),)
        legs: List[Leg] = []
        if sl != 0:
            legs.append(_ici_leg(src, sn * dpn, sl, 0, dpn, src))
        nhops, nd = _ring_route(sn, dn, spec.n_nodes)
        legs.append(
            Leg(
                "dci",
                ("dci", sn, nd),
                nhops,
                _dev(sn * dpn),
                _dev(dn * dpn),
            )
        )
        if dl != 0:
            legs.append(_ici_leg(dn * dpn, dst, 0, dl, dpn, dn * dpn))
        return tuple(legs)


@register_fabric("two_tier")
def two_tier_spec(
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
) -> InterconnectSpec:
    """intra-node ICI rings + a DCI ring of per-node gateway uplinks (the
    legacy hierarchical fabric, bit-identical)"""
    dpn = devices_per_node
    if dpn is None or dpn >= n_devices:
        # one node: degenerates to the flat ring (matching the legacy model)
        return ring_spec(n_devices, hw)
    ports = _ici_ports(n_devices)
    for node in range(n_devices // dpn):
        ports[("dci", node, +1)] = "dci"
        ports[("dci", node, -1)] = "dci"
    return InterconnectSpec(
        name="two_tier",
        n_devices=n_devices,
        devices_per_node=dpn,
        routing=_TwoTierRouting(),
        link_classes=_std_classes(hw),
        ports=ports,
    )


class _FatTreeRouting(RoutingPolicy):
    """Node gateways hang off leaf switches; leaves meet at a spine.  The
    leaf's spine uplink carries ``oversubscription``x less bandwidth than the
    sum of its node downlinks — the classic DCN bottleneck."""

    def legs(self, spec, src, dst):
        dpn = spec.devices_per_node
        npl = spec.params["nodes_per_leaf"]
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn == dn:
            return (_ici_leg(src, dst, sl, dl, dpn, src),)
        s_leaf, d_leaf = sn // npl, dn // npl
        sgw, dgw = sn * dpn, dn * dpn
        legs: List[Leg] = []
        if sl != 0:
            legs.append(_ici_leg(src, sgw, sl, 0, dpn, src))
        # gateway -> leaf switch over the node's uplink NIC
        legs.append(
            Leg("dci", ("up", sn), 1, _dev(sgw), ("leaf", s_leaf))
        )
        if s_leaf != d_leaf:
            # leaf -> spine -> leaf: serialized on the (oversubscribed)
            # spine uplink of the source leaf
            legs.append(
                Leg(
                    "spine",
                    ("spine", s_leaf),
                    2,
                    ("leaf", s_leaf),
                    ("leaf", d_leaf),
                )
            )
        # leaf -> destination gateway over the leaf's node downlink
        legs.append(
            Leg("dci", ("down", dn), 1, ("leaf", d_leaf), _dev(dgw))
        )
        if dl != 0:
            legs.append(_ici_leg(dgw, dst, 0, dl, dpn, dgw))
        return tuple(legs)


@register_fabric("fat_tree")
def fat_tree_spec(
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
    oversubscription: float = 2.0,
    nodes_per_leaf: int = 2,
) -> InterconnectSpec:
    """leaf/spine fat tree over the nodes; the leaf->spine uplink is
    oversubscribed by the given factor (bandwidth / oversubscription)"""
    dpn = 1 if devices_per_node is None else int(devices_per_node)
    if dpn < 1 or n_devices % dpn:
        raise ValueError(
            f"devices_per_node={dpn} must divide n_devices={n_devices}"
        )
    if oversubscription < 1:
        raise ValueError("oversubscription must be >= 1")
    if nodes_per_leaf < 1:
        raise ValueError("nodes_per_leaf must be >= 1")
    n_nodes = n_devices // dpn
    n_leaves = math.ceil(n_nodes / nodes_per_leaf)
    classes = _std_classes(hw)
    classes["spine"] = LinkClass(
        "spine",
        classes["dci"].bw_bytes_per_ns / float(oversubscription),
        classes["dci"].hop_latency_ns,
    )
    ports = _ici_ports(n_devices)
    for node in range(n_nodes):
        ports[("up", node)] = "dci"
        ports[("down", node)] = "dci"
    for leaf in range(n_leaves):
        ports[("spine", leaf)] = "spine"
    return InterconnectSpec(
        name="fat_tree",
        n_devices=n_devices,
        devices_per_node=dpn,
        routing=_FatTreeRouting(),
        link_classes=classes,
        ports=ports,
        params={
            "oversubscription": float(oversubscription),
            "nodes_per_leaf": int(nodes_per_leaf),
            "n_leaves": n_leaves,
        },
    )


class _RailRouting(RoutingPolicy):
    """Rail-optimized: NIC ``r`` of every node attaches to the device with
    local rank ``r`` and to rail switch ``r``.  A cross-node message rides
    the *destination's* rail (``dl % rails``): hop intra-node to the rail's
    NIC owner if needed, cross on the rail, and land — rail-aligned pairs
    (same local rank) cross with zero intra-node hops, the PXN idiom."""

    def legs(self, spec, src, dst):
        dpn = spec.devices_per_node
        rails = spec.nics_per_node
        sn, sl = divmod(src, dpn)
        dn, dl = divmod(dst, dpn)
        if sn == dn:
            return (_ici_leg(src, dst, sl, dl, dpn, src),)
        r = dl % rails
        legs: List[Leg] = []
        if sl != r:
            legs.append(_ici_leg(src, sn * dpn + r, sl, r, dpn, src))
        legs.append(
            Leg(
                "rail",
                ("rail", sn, r),
                1,
                _dev(sn * dpn + r),
                _dev(dn * dpn + r),
            )
        )
        if dl != r:
            legs.append(
                _ici_leg(dn * dpn + r, dst, r, dl, dpn, dn * dpn + r)
            )
        return tuple(legs)


@register_fabric("rail_optimized")
def rail_optimized_spec(
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
    rails: Optional[int] = None,
) -> InterconnectSpec:
    """k NICs per node, one per rail switch; cross-node traffic rides the
    destination's rail with zero intra hops when local ranks align"""
    dpn = 1 if devices_per_node is None else int(devices_per_node)
    if dpn < 1 or n_devices % dpn:
        raise ValueError(
            f"devices_per_node={dpn} must divide n_devices={n_devices}"
        )
    rails = dpn if rails is None else int(rails)
    if not (1 <= rails <= dpn):
        raise ValueError(
            f"rails={rails} must be in [1, devices_per_node={dpn}]"
        )
    classes = {
        "ici": _std_classes(hw)["ici"],
        "rail": LinkClass(
            "rail", hw.dci_link_bw / 1e9, hw.dci_hop_latency_s * 1e9
        ),
    }
    ports = _ici_ports(n_devices)
    for node in range(n_devices // dpn):
        for r in range(rails):
            ports[("rail", node, r)] = "rail"
    return InterconnectSpec(
        name="rail_optimized",
        n_devices=n_devices,
        devices_per_node=dpn,
        routing=_RailRouting(),
        link_classes=classes,
        ports=ports,
        nics_per_node=rails,
        params={"rails": rails},
    )


class _Torus2DRouting(RoutingPolicy):
    """Dimension-ordered (X then Y) routing on a rows x cols torus; each
    device owns one egress port per axis per direction."""

    def legs(self, spec, src, dst):
        cols = spec.params["cols"]
        r1, c1 = divmod(src, cols)
        r2, c2 = divmod(dst, cols)
        legs: List[Leg] = []
        turn = src
        if c1 != c2:
            hops, d = _ring_route(c1, c2, cols)
            turn = r1 * cols + c2
            legs.append(Leg("x", ("x", src, d), hops, _dev(src), _dev(turn)))
        if r1 != r2:
            hops, d = _ring_route(r1, r2, spec.params["rows"])
            legs.append(Leg("y", ("y", turn, d), hops, _dev(turn), _dev(dst)))
        return tuple(legs)


@register_fabric("torus2d")
def torus2d_spec(
    n_devices: int,
    hw: HardwareSpec = V5E,
    *,
    devices_per_node: Optional[int] = None,
    rows: Optional[int] = None,
    cols: Optional[int] = None,
) -> InterconnectSpec:
    """rows x cols 2D torus of ICI links with dimension-ordered (X then Y)
    routing; per-axis link classes ``x``/``y``"""
    if rows is None and cols is None:
        rows = 1
        for r in range(int(math.isqrt(n_devices)), 0, -1):
            if n_devices % r == 0:
                rows = r
                break
        cols = n_devices // rows
    elif rows is None:
        if n_devices % cols:
            raise ValueError(f"cols={cols} must divide n_devices={n_devices}")
        rows = n_devices // cols
    elif cols is None:
        if n_devices % rows:
            raise ValueError(f"rows={rows} must divide n_devices={n_devices}")
        cols = n_devices // rows
    if rows * cols != n_devices:
        raise ValueError(
            f"rows x cols = {rows}x{cols} != n_devices = {n_devices}"
        )
    ici = _std_classes(hw)["ici"]
    classes = {
        "x": LinkClass("x", ici.bw_bytes_per_ns, ici.hop_latency_ns),
        "y": LinkClass("y", ici.bw_bytes_per_ns, ici.hop_latency_ns),
    }
    ports: Dict[PortKey, str] = {}
    for dev in range(n_devices):
        for d in (+1, -1):
            ports[("x", dev, d)] = "x"
            ports[("y", dev, d)] = "y"
    # torus routing is node-agnostic, but a requested node split is honored
    # as grouping metadata (node_of / report shape), not silently flattened
    return InterconnectSpec(
        name="torus2d",
        n_devices=n_devices,
        devices_per_node=devices_per_node or n_devices,
        routing=_Torus2DRouting(),
        link_classes=classes,
        ports=ports,
        params={"rows": int(rows), "cols": int(cols)},
    )
