"""Scenario API: pluggable per-GPU traffic patterns as data.

The paper's claim that Eidola "supports configurable per-GPU traffic patterns
and enables isolated performance analysis under different communication
scenarios" requires more than the one fused GEMV+AllReduce kernel the seed
hardwired.  This module is the redesigned public surface:

* :class:`PhaseSpec` / :class:`WGProgram` — per-workgroup *phase programs as
  data*: an ordered list of compute/write/wait steps with durations and
  closed-form traffic attribution.  :class:`repro.core.target.TargetDevice`
  interprets these programs instead of a hardcoded state machine, so the
  spin/SyncMon wait semantics, the WTT, and all three engines are shared by
  every scenario.
* :class:`Scenario` — owns (a) program generation for the detailed device and
  (b) eidolon :class:`TraceBundle` generation (the registered peer writes).
* a registry (:func:`register_scenario` / :func:`get_scenario` /
  :func:`list_scenarios`) of built-in and user scenarios, and
* :func:`simulate` — the unified entry point: name + config + params in,
  :class:`repro.core.simulator.Report` out — plus :class:`SweepRunner`, which
  fans one scenario across a parameter grid and engine set.

Built-in scenarios live in :mod:`repro.core.scenarios`; importing that package
(or calling any registry function) registers them.
"""

from __future__ import annotations

import abc
import itertools
from bisect import bisect_right
from dataclasses import dataclass, fields
from typing import (
    Callable,
    ClassVar,
    Dict,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
    Type,
    Union,
)

from .config import EngineKind, SimConfig
from .events import TraceBundle
from .interconnect import V5E, FabricLike, HardwareSpec, resolve_fabric
from .memory import AddressMap

__all__ = [
    "TrafficOp",
    "EmitOp",
    "PhaseSpec",
    "WGProgram",
    "Affine",
    "AffineRun",
    "EmitRun",
    "LoopEmit",
    "LoopPhase",
    "LoopSpec",
    "SymbolicProgram",
    "affine_of",
    "Scenario",
    "register_scenario",
    "get_scenario",
    "list_scenarios",
    "simulate",
    "SweepPoint",
    "SweepRunner",
]


# ---------------------------------------------------------------------------
# phase programs as data
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficOp:
    """Closed-form traffic accounted when the owning phase completes.

    kind        "reads" (non-flag device reads), "local_writes", or
                "xgmi_out" (writes pushed to peers over the fabric).
    n           number of homogeneous requests.
    bytes_each  payload bytes per request.
    """

    kind: str
    n: int
    bytes_each: int

    _KINDS = ("reads", "local_writes", "xgmi_out")

    def __post_init__(self) -> None:
        if self.kind not in self._KINDS:
            raise ValueError(f"traffic kind must be one of {self._KINDS}")
        if self.n < 0 or self.bytes_each < 0:
            raise ValueError("traffic counts must be non-negative")

    def apply(self, memory, times: int = 1) -> None:
        """Account this op ``times`` times (cohort batching: the counters are
        linear in ``n``, so ``times`` workgroups completing the same phase
        account exactly ``n * times`` requests)."""
        if self.kind == "reads":
            memory.bulk_reads(self.n * times, bytes_each=self.bytes_each)
        elif self.kind == "local_writes":
            memory.bulk_local_writes(self.n * times, bytes_each=self.bytes_each)
        else:
            memory.issue_xgmi_out(self.n * times, bytes_each=self.bytes_each)


def reads(n: int, bytes_each: int) -> TrafficOp:
    return TrafficOp("reads", n, bytes_each)


def local_writes(n: int, bytes_each: int) -> TrafficOp:
    return TrafficOp("local_writes", n, bytes_each)


def xgmi_out(n: int, bytes_each: int) -> TrafficOp:
    return TrafficOp("xgmi_out", n, bytes_each)


@dataclass(frozen=True)
class EmitOp:
    """An xGMI write *emitted into a peer device's WTT* when the owning phase
    completes — the closed-loop counterpart of a pre-scheduled trace write.

    In a :class:`repro.core.cluster.Cluster` simulation, a completing phase's
    ``emits`` are routed over the fabric model (per-hop latency + egress-link
    serialization/contention) and registered into device ``dst``'s Write
    Tracking Table at the physically-derived arrival time.  Outside a cluster
    (open-loop single-device runs) emits are inert.

    dst            destination device id.
    slot           flag slot: the write lands at ``amap.flag_addr(src, slot)``
                   in the destination's symmetric heap, where ``src`` is the
                   emitting device (flags are indexed by writer).
    data/size      written value and width (1..8 bytes, like RegisteredWrite).
    payload_bytes  data payload serialized on the link *ahead of* the flag; it
                   delays the flag's arrival but is NOT accounted as traffic
                   here (put the payload's ``xgmi_out`` in the phase's
                   TrafficOps) — only the flag write itself is accounted.
    data_writes    marker data writes registered into the destination WTT just
                   before the flag (mirrors the open-loop trace bundles'
                   ``include_data_writes`` decoration).
    coalesce       "last": emit once per device, when the final workgroup
                   completes this phase (requires all WGs of the device to
                   share program structure, i.e. the same phase index);
                   "each": emit once per workgroup.
    addr           explicit destination address, overriding the flag-slot
                   convention (e.g. raw data writes).
    """

    dst: int
    slot: int = 0
    data: int = 1
    size: int = 8
    payload_bytes: int = 0
    data_writes: int = 0
    coalesce: str = "last"
    addr: Optional[int] = None

    def __post_init__(self) -> None:
        if self.dst < 0:
            raise ValueError("EmitOp.dst must be a device id >= 0")
        if not (1 <= self.size <= 8):
            raise ValueError("EmitOp.size must be in [1, 8] bytes")
        if self.slot < 0 or self.payload_bytes < 0 or self.data_writes < 0:
            raise ValueError("EmitOp fields must be non-negative")
        if self.coalesce not in ("last", "each"):
            raise ValueError("EmitOp.coalesce must be 'last' or 'each'")


@dataclass(frozen=True)
class PhaseSpec:
    """One step of a workgroup's phase program.

    Two flavours:

    * timed phase — ``wait_addrs is None``: runs for ``duration_cycles``
      (perturbable via ``Perturb.scale_phase(wg, name, base)``), then accounts
      ``traffic`` in closed form.
    * wait phase — ``wait_addrs`` is an ordered tuple of flag *addresses* the
      workgroup observes sequentially under the configured sync policy
      (spin-poll or SyncMon monitor/mwait).  Flag-read traffic is accounted by
      the interpreter, not by ``traffic``; ``duration_cycles`` is ignored.

    ``emits`` fire at phase completion in closed-loop (cluster) simulations:
    each :class:`EmitOp` becomes a registered write in a *peer* device's WTT,
    which is how one device's perturbation ripples to the others.

    ``name`` doubles as the timeline segment label and the perturbation key;
    it must be registered via :func:`repro.core.events.register_phase`.
    """

    name: str
    duration_cycles: int = 0
    traffic: Tuple[TrafficOp, ...] = ()
    wait_addrs: Optional[Tuple[int, ...]] = None
    emits: Tuple[EmitOp, ...] = ()

    @property
    def is_wait(self) -> bool:
        return self.wait_addrs is not None


@dataclass(frozen=True)
class WGProgram:
    """The full phase program of one workgroup on the detailed device."""

    wg: int
    cu: int
    dispatch_cycle: int
    phases: Tuple[PhaseSpec, ...]

    def wait_addresses(self) -> List[int]:
        out: List[int] = []
        for ph in self.phases:
            if ph.wait_addrs:
                out.extend(ph.wait_addrs)
        return out


# ---------------------------------------------------------------------------
# symbolic program IR: compressed loop phases
# ---------------------------------------------------------------------------
#
# Flat closed-loop collectives build O(devices) phases for O(devices) ranks —
# quadratic PhaseSpec construction that dominated 1024-device wall time.  The
# IR below represents a *run* of ring/incast steps as one object with affine
# step-indexed fields.  ``SymbolicProgram`` is a drop-in replacement for a
# ``Tuple[PhaseSpec, ...]``: it supports ``len``/indexing/iteration/equality,
# materializes individual steps lazily (memoized, so step identity is stable
# for id-keyed engine caches), and ``expand()`` reproduces the pre-refactor
# flat tuple bit-identically.  Engines and the verifier read ``.segments``
# directly to advance or check whole loops without unrolling.


@dataclass(frozen=True)
class Affine:
    """An integer affine function ``base + step * k`` of the loop index."""

    base: int
    step: int = 0

    def at(self, k: int) -> int:
        return self.base + self.step * k


def affine_of(fn: Callable[[int], int], k0: int, count: int) -> Affine:
    """Derive the :class:`Affine` matching ``fn`` on ``[k0, k0+count)``.

    Sampled at the first two points and verified at the last, so non-affine
    layouts (e.g. a custom AddressMap) fail loudly instead of silently
    mis-compressing.
    """
    v0 = fn(k0)
    if count <= 1:
        return Affine(v0, 0)
    step = fn(k0 + 1) - v0
    last = k0 + count - 1
    if fn(last) != v0 + step * (count - 1):
        raise ValueError("function is not affine over the loop range")
    return Affine(v0 - step * k0, step)


@dataclass(frozen=True)
class AffineRun:
    """A compressed *within-phase* arithmetic run of ``count`` addresses
    ``start, start+stride, ...`` (e.g. the all-to-all wait list over peers)."""

    start: int
    stride: int
    count: int

    def __post_init__(self) -> None:
        if self.count < 0:
            raise ValueError("AffineRun.count must be >= 0")

    def expand(self) -> Tuple[int, ...]:
        return tuple(self.start + self.stride * j for j in range(self.count))


@dataclass(frozen=True)
class EmitRun:
    """``count`` :class:`EmitOp`\\ s whose dst/slot advance affinely with the
    member index ``j`` (shared payload/marker/coalesce fields) — the per-peer
    fan-out of an incast phase as one descriptor."""

    count: int
    dst0: int
    dst_stride: int = 1
    slot0: int = 0
    slot_stride: int = 0
    data: int = 1
    size: int = 8
    payload_bytes: int = 0
    data_writes: int = 0
    coalesce: str = "last"

    def __post_init__(self) -> None:
        if self.count < 0:
            raise ValueError("EmitRun.count must be >= 0")

    def expand(self) -> Tuple[EmitOp, ...]:
        return tuple(
            EmitOp(
                self.dst0 + j * self.dst_stride,
                slot=self.slot0 + j * self.slot_stride,
                data=self.data,
                size=self.size,
                payload_bytes=self.payload_bytes,
                data_writes=self.data_writes,
                coalesce=self.coalesce,
            )
            for j in range(self.count)
        )


@dataclass(frozen=True)
class LoopEmit:
    """An :class:`EmitOp` template whose dst/slot are :class:`Affine` in the
    loop index ``k`` (the ring step's downstream emit)."""

    dst: Affine
    slot: Affine = Affine(0)
    data: int = 1
    size: int = 8
    payload_bytes: int = 0
    data_writes: int = 0
    coalesce: str = "last"

    def at(self, k: int) -> EmitOp:
        return EmitOp(
            self.dst.at(k),
            slot=self.slot.at(k),
            data=self.data,
            size=self.size,
            payload_bytes=self.payload_bytes,
            data_writes=self.data_writes,
            coalesce=self.coalesce,
        )


#: wait entries a LoopPhase accepts: a literal address, an address affine in
#: the loop index, or a within-phase run of addresses (constant in k).
WaitEntry = Union[int, Affine, AffineRun]
#: emit entries a LoopPhase accepts.
EmitEntry = Union[EmitOp, LoopEmit, EmitRun]


@dataclass(frozen=True)
class LoopPhase:
    """A :class:`PhaseSpec` *template* evaluated at a loop index ``k``.

    ``traffic`` is loop-invariant (the built-in collectives move the same
    bytes every step); step-dependent addressing lives in ``wait_addrs`` /
    ``emits`` entries, which may be symbolic (:class:`Affine`,
    :class:`AffineRun`, :class:`LoopEmit`, :class:`EmitRun`).
    """

    name: str
    duration_cycles: int = 0
    traffic: Tuple[TrafficOp, ...] = ()
    wait_addrs: Optional[Tuple[WaitEntry, ...]] = None
    emits: Tuple[EmitEntry, ...] = ()

    @property
    def is_wait(self) -> bool:
        return self.wait_addrs is not None

    def at(self, k: int) -> PhaseSpec:
        waits: Optional[Tuple[int, ...]] = None
        if self.wait_addrs is not None:
            acc: List[int] = []
            for w in self.wait_addrs:
                if isinstance(w, AffineRun):
                    acc.extend(w.expand())
                elif isinstance(w, Affine):
                    acc.append(w.at(k))
                else:
                    acc.append(w)
            waits = tuple(acc)
        ems: List[EmitOp] = []
        for e in self.emits:
            if isinstance(e, EmitRun):
                ems.extend(e.expand())
            elif isinstance(e, LoopEmit):
                ems.append(e.at(k))
            else:
                ems.append(e)
        return PhaseSpec(self.name, self.duration_cycles, self.traffic, waits, tuple(ems))


@dataclass(frozen=True)
class LoopSpec:
    """``count`` iterations of ``body`` with the loop index running
    ``k = k0, k0+1, ..., k0+count-1`` — one object standing for
    ``count * len(body)`` phases."""

    count: int
    body: Tuple[LoopPhase, ...]
    k0: int = 0

    def __post_init__(self) -> None:
        if self.count < 0:
            raise ValueError("LoopSpec.count must be >= 0")
        if not self.body:
            raise ValueError("LoopSpec.body must be non-empty")
        for ph in self.body:
            if not isinstance(ph, LoopPhase):
                raise TypeError("LoopSpec.body entries must be LoopPhase")

    @property
    def n_phases(self) -> int:
        return self.count * len(self.body)


#: a SymbolicProgram segment: a literal phase, a single compressed phase
#: (evaluated at k = 0), or a counted loop of compressed phases.
Segment = Union[PhaseSpec, LoopPhase, LoopSpec]


class SymbolicProgram:
    """A compressed per-rank phase program.

    Drop-in replacement for a flat ``Tuple[PhaseSpec, ...]`` in
    :class:`WGProgram.phases`: sequence protocol (``len``/index/iterate),
    value equality against other programs *and* flat tuples, and a
    bit-identical :meth:`expand`.  Individual phases materialize lazily and
    are memoized, so ``program[i] is program[i]`` — engine caches keyed by
    phase identity keep working.  Bulk engines skip materialization entirely
    and read :attr:`segments`.

    Note: equality with flat tuples is supported but hashes differ — don't
    mix symbolic and materialized programs as keys of one dict.
    """

    __slots__ = ("segments", "group", "_starts", "_len", "_memo", "_hash")

    def __init__(self, segments: Iterable[Segment], group: Optional[str] = None):
        segs: List[Segment] = []
        starts: List[int] = []
        n = 0
        for s in segments:
            if isinstance(s, LoopSpec):
                cnt = s.n_phases
                if cnt == 0:
                    continue  # empty loops contribute no phases
            elif isinstance(s, (PhaseSpec, LoopPhase)):
                cnt = 1
            else:
                raise TypeError(
                    "SymbolicProgram segments must be PhaseSpec, LoopPhase, or LoopSpec"
                )
            segs.append(s)
            starts.append(n)
            n += cnt
        self.segments: Tuple[Segment, ...] = tuple(segs)
        #: Optional group-uniformity label stamped by the scenario: ranks
        #: sharing a label are claimed to run programs that are uniform under
        #: an affine rank remapping.  Advisory metadata for the lockstep
        #: group classifier — excluded from equality and hashing.
        self.group: Optional[str] = group
        self._starts: Tuple[int, ...] = tuple(starts)
        self._len = n
        self._memo: Dict[int, PhaseSpec] = {}
        self._hash: Optional[int] = None

    # -- sequence protocol --------------------------------------------------

    def __len__(self) -> int:
        return self._len

    def __getitem__(self, i):
        if isinstance(i, slice):
            return tuple(self[j] for j in range(*i.indices(self._len)))
        if i < 0:
            i += self._len
        if not 0 <= i < self._len:
            raise IndexError("phase index out of range")
        got = self._memo.get(i)
        if got is None:
            si = bisect_right(self._starts, i) - 1
            seg = self.segments[si]
            if isinstance(seg, PhaseSpec):
                got = seg
            elif isinstance(seg, LoopPhase):
                got = seg.at(0)
            else:
                k, b = divmod(i - self._starts[si], len(seg.body))
                got = seg.body[b].at(seg.k0 + k)
            self._memo[i] = got
        return got

    def __iter__(self) -> Iterator[PhaseSpec]:
        for i in range(self._len):
            yield self[i]

    def __eq__(self, other):
        if self is other:
            return True
        if isinstance(other, SymbolicProgram):
            if self.segments == other.segments:
                return True
            if self._len != other._len:
                return False
            return all(a == b for a, b in zip(self, other))
        if isinstance(other, tuple):
            if len(other) != self._len:
                return False
            return all(a == b for a, b in zip(self, other))
        return NotImplemented

    def __hash__(self) -> int:
        if self._hash is None:
            self._hash = hash(self.segments)
        return self._hash

    def __repr__(self) -> str:
        tag = f", group={self.group!r}" if self.group is not None else ""
        return f"SymbolicProgram({self._len} phases, {len(self.segments)} segments{tag})"

    # -- materialization and summaries --------------------------------------

    def expand(self) -> Tuple[PhaseSpec, ...]:
        """Materialize the flat phase tuple — bit-identical to the
        pre-refactor construction."""
        return tuple(self[i] for i in range(self._len))

    def wait_runs(self) -> Tuple[List[int], List[Tuple[int, int, int]]]:
        """Every wait address as a literal or a ``(start, stride, count)``
        arithmetic run, in O(#segments) — never O(steps).  Membership
        summary for engine watch sets."""
        literals: List[int] = []
        runs: List[Tuple[int, int, int]] = []
        for seg in self.segments:
            if isinstance(seg, PhaseSpec):
                if seg.wait_addrs:
                    literals.extend(seg.wait_addrs)
                continue
            if isinstance(seg, LoopPhase):
                body: Tuple[LoopPhase, ...] = (seg,)
                count, k0 = 1, 0
            else:
                body, count, k0 = seg.body, seg.count, seg.k0
            for ph in body:
                if not ph.wait_addrs:
                    continue
                for w in ph.wait_addrs:
                    if isinstance(w, AffineRun):
                        # constant in k: the same run re-awaited each
                        # iteration — one membership run suffices.
                        if w.count:
                            runs.append((w.start, w.stride, w.count))
                    elif isinstance(w, Affine):
                        if w.step == 0 or count == 1:
                            literals.append(w.at(k0))
                        else:
                            runs.append((w.at(k0), w.step, count))
                    else:
                        literals.append(w)
        return literals, runs


def as_symbolic(phases) -> Optional[SymbolicProgram]:
    """Return ``phases`` as a :class:`SymbolicProgram` if it is one."""
    return phases if isinstance(phases, SymbolicProgram) else None


# ---------------------------------------------------------------------------
# the Scenario base class
# ---------------------------------------------------------------------------


class Scenario(abc.ABC):
    """A communication scenario: phase programs + eidolon write traces.

    Subclasses set ``name`` (the registry key), accept their swept parameters
    as keyword arguments, and implement :meth:`programs` and :meth:`traces`.
    ``params`` holds whatever keyword arguments the constructor accepted, for
    reporting.

    A scenario runs in one of two modes:

    * **open loop** (default, ``closed_loop = False``): exactly one detailed
      device (device 0); peers are eidolons whose writes are synthesized up
      front by :meth:`traces` and replayed from the WTT.
    * **closed loop** (``closed_loop = True``, set by scenarios that support
      it): every device runs its own phase-program interpreter inside a
      :class:`repro.core.cluster.Cluster`; flags are *emitted* by completing
      phases (:class:`EmitOp`) instead of pre-scheduled, so perturbations on
      one device propagate to the others.  Closed-loop scenarios override
      :meth:`programs_for`.
    """

    name: str = ""
    closed_loop: bool = False  # instances flip this when built closed-loop
    #: Class-level capability flag: True on scenarios that accept
    #: ``closed_loop=True`` and run per-rank phase programs in a Cluster.
    #: Registering such a class records a layout-proof obligation (see
    #: ``LAYOUT_PROOF_OBLIGATIONS``) discharged by the parametric prover in
    #: :mod:`repro.analysis.layout`.
    closed_loop_capable: ClassVar[bool] = False
    #: Device-count ceiling the layout prover certifies this scenario's
    #: address layout up to (flag/partial/marker disjointness, unique
    #: writers, wait coverage, for every constructible n <= max_devices).
    max_devices: ClassVar[int] = 4096

    def __init__(self, cfg: SimConfig, amap: Optional[AddressMap] = None):
        self.cfg = cfg
        self.amap = amap or self.default_amap(cfg)
        self.params: Dict[str, object] = {}
        # Closed-loop fabric shape: scenarios that take a ``devices_per_node``
        # knob set this to a tier-explicit Topology (see
        # ``Topology.for_devices``); the Cluster derives its FabricModel from
        # it.  ``None`` means the flat single-tier ring over cfg.n_devices.
        self.topology = None  # type: ignore[assignment]
        # Pluggable fabric: scenarios built with ``fabric=``/link overrides
        # resolve an InterconnectSpec here (see :meth:`_setup_fabric`), which
        # the Cluster prefers over ``topology``.  ``None`` keeps the legacy
        # topology-derived ring/two_tier shape.
        self.interconnect = None  # type: ignore[assignment]
        self.fabric_name: Optional[str] = None

    @classmethod
    def default_amap(cls, cfg: SimConfig) -> AddressMap:
        # clearance is a no-op for the single-slot default map; it makes
        # "partial region starts above the flag pool" a base-class invariant
        # for any subclass that forgets to re-base a wider pool
        return AddressMap(n_devices=cfg.n_devices).with_partial_clearance()

    def _setup_fabric(
        self,
        *,
        devices_per_node: Optional[int] = None,
        hw: HardwareSpec = V5E,
        fabric: FabricLike = None,
        link_bw: Optional[Dict[str, float]] = None,
        link_latency_ns: Optional[Dict[str, float]] = None,
        **fabric_params,
    ) -> None:
        """Resolve the closed-loop fabric: sets ``self.topology`` (the legacy
        tier-explicit shape) and — when ``fabric`` names a registered preset
        (e.g. ``"fat_tree"``), is a ready
        :class:`repro.core.interconnect.InterconnectSpec`, or any per-class
        link override is given — ``self.interconnect``, which the
        :class:`repro.core.cluster.Cluster` prefers.  ``link_bw`` maps link
        class -> bytes/ns (== GB/s); unknown classes raise, listing the
        fabric's valid ones."""
        from .topology import Topology  # late import (topology is heavier)

        n = self.cfg.n_devices
        self.topology = Topology.for_devices(n, devices_per_node, hw=hw)
        self.interconnect = resolve_fabric(
            fabric,
            n,
            hw,
            devices_per_node=devices_per_node,
            link_bw=link_bw,
            link_latency_ns=link_latency_ns,
            **fabric_params,
        )
        self.fabric_name = (
            self.interconnect.name if self.interconnect is not None else None
        )

    @abc.abstractmethod
    def programs(self) -> List[WGProgram]:
        """Per-workgroup phase programs for the detailed device (device 0)."""

    @abc.abstractmethod
    def traces(self) -> TraceBundle:
        """Registered peer writes the eidolons replay (including every flag
        write some program waits on — otherwise the run deadlocks)."""

    # -- multi-device hooks (closed-loop scenarios override) -----------------

    def programs_for(self, device: int) -> List[WGProgram]:
        """Phase programs for one device of a multi-device simulation.

        Open-loop scenarios model only device 0, for which this defers to
        :meth:`programs`; closed-loop scenarios override this with genuinely
        per-rank programs (whose phases carry :class:`EmitOp`\\ s).
        """
        if self.closed_loop:
            raise NotImplementedError(
                f"scenario {self.name!r} sets closed_loop but does not "
                "implement programs_for()"
            )
        if device == 0:
            return self.programs()
        raise ValueError(
            f"open-loop scenario {self.name!r} models only device 0 in "
            f"detail (got device {device}); build it with closed_loop=True "
            "if supported"
        )

    def traces_for(self, device: int) -> TraceBundle:
        """Seed writes pre-registered into ``device``'s WTT before the run.

        Open loop: device 0 gets the full eidolon bundle (:meth:`traces`),
        peers get nothing — the degenerate case where an eidolon is just a
        device whose program replays a bundle.  Closed loop: empty by default,
        because flags are emitted by completing phases at run time.
        """
        if self.closed_loop:
            return TraceBundle(meta={"scenario": self.name, "closed_loop": True})
        return self.traces() if device == 0 else TraceBundle()

    # -- optional hooks ------------------------------------------------------

    def run_vectorized(self, sim) -> Optional["object"]:
        """Return a Report from a scenario-specific closed-form engine, or
        ``None`` if the scenario only supports the cycle/event engines."""
        return None

    def describe(self) -> str:
        ps = ", ".join(f"{k}={v!r}" for k, v in self.params.items())
        return f"<{type(self).__name__} {self.name}({ps})>"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Type[Scenario]] = {}

#: Registration-time layout-proof obligations.  Every closed-loop-capable
#: scenario registered below must have its address layout *proven* — flag
#: pool / partial region / marker windows pairwise disjoint, one writer per
#: flag value epoch, every wait family fed by an earlier emission family —
#: for all device counts up to its ``max_devices`` bound.  The obligation is
#: discharged by :func:`repro.analysis.layout.prove_registry`, wired into
#: ``python -m repro.analysis`` and CI's verify-scenarios job.
LAYOUT_PROOF_OBLIGATIONS: List[str] = []


def register_scenario(cls: Type[Scenario]) -> Type[Scenario]:
    """Class decorator: register a Scenario subclass under ``cls.name``."""
    if not cls.name:
        raise ValueError(f"{cls.__name__} must set a non-empty .name")
    existing = _REGISTRY.get(cls.name)
    if existing is not None and existing is not cls:
        raise ValueError(f"scenario {cls.name!r} already registered")
    _REGISTRY[cls.name] = cls
    if cls.closed_loop_capable and cls.name not in LAYOUT_PROOF_OBLIGATIONS:
        LAYOUT_PROOF_OBLIGATIONS.append(cls.name)
    return cls


def _load_builtins() -> None:
    # importing the package registers the built-in scenarios
    from . import scenarios  # noqa: F401


def get_scenario(name: str) -> Type[Scenario]:
    _load_builtins()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown scenario {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def list_scenarios() -> List[str]:
    _load_builtins()
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# unified entry point
# ---------------------------------------------------------------------------

ScenarioLike = Union[str, Scenario, Type[Scenario]]


def _resolve(scenario: ScenarioLike, cfg: SimConfig, params: Dict) -> Scenario:
    if isinstance(scenario, Scenario):
        if params:
            raise ValueError(
                "pass scenario params to the constructor when providing an "
                "instance, not to simulate()"
            )
        return scenario
    cls = get_scenario(scenario) if isinstance(scenario, str) else scenario
    return cls(cfg, **params)


def _resolve_shape(
    devices: Optional[int],
    nodes: Optional[int],
    devices_per_node: Optional[int],
) -> Tuple[Optional[int], Optional[int]]:
    """Resolve the (devices, devices_per_node) pair from any two of the
    ``devices`` / ``nodes`` / ``devices_per_node`` knobs."""
    if nodes is not None and nodes < 1:
        raise ValueError("nodes must be >= 1")
    if devices_per_node is not None and devices_per_node < 1:
        raise ValueError("devices_per_node must be >= 1")
    if nodes is None:
        return devices, devices_per_node
    if devices_per_node is not None:
        total = nodes * devices_per_node
        if devices is not None and devices != total:
            raise ValueError(
                f"devices={devices} contradicts nodes={nodes} x "
                f"devices_per_node={devices_per_node}"
            )
        return total, devices_per_node
    if devices is None:
        raise ValueError(
            "nodes= needs devices= or devices_per_node= to fix the shape"
        )
    if devices % nodes:
        raise ValueError(f"devices={devices} not divisible by nodes={nodes}")
    return devices, devices // nodes


def simulate(
    scenario: ScenarioLike,
    cfg: Optional[SimConfig] = None,
    *,
    perturb=None,
    collect_segments: bool = True,
    devices: Optional[int] = None,
    nodes: Optional[int] = None,
    devices_per_node: Optional[int] = None,
    sanitize: bool = False,
    timeline: Optional[bool] = None,
    lockstep: Optional[bool] = None,
    _plan_cache=None,
    _plan_key=None,
    **params,
):
    """Simulate one kernel launch of ``scenario`` under ``cfg``.

    ``scenario`` may be a registered name (see :func:`list_scenarios`), a
    Scenario subclass, or a ready-built instance (whose own cfg is then used;
    passing a *different* cfg alongside an instance is an error).  Extra
    keyword arguments are forwarded to the scenario constructor (e.g.
    ``flag_delays_ns=...`` for ``gemv_allreduce``, or ``closed_loop=True``
    for the scenarios that support running every device in detail).

    ``devices`` overrides the total device count (``cfg.n_egpus`` becomes
    ``devices - 1``), e.g. ``simulate("ring_allreduce", cfg, devices=8,
    closed_loop=True)``.

    ``nodes`` / ``devices_per_node`` fix the tiered fabric shape: any two of
    (``devices``, ``nodes``, ``devices_per_node``) determine the third, and
    the resolved ``devices_per_node`` is forwarded to the scenario (which
    builds its :class:`repro.core.topology.Topology` from it), e.g.
    ``simulate("hierarchical_allreduce", nodes=4, devices_per_node=4)``.

    ``fabric=`` (a registered interconnect preset name such as
    ``"fat_tree"`` or ``"rail_optimized"``, or a ready
    :class:`repro.core.interconnect.InterconnectSpec`) and ``link_bw=``
    (per-link-class bandwidth overrides, validated) are ordinary scenario
    parameters on every closed-loop scenario — the same workload runs over
    any fabric, e.g. ``simulate("all_to_all", devices=16, nodes=4,
    closed_loop=True, fabric="rail_optimized")``.

    Scenarios built with ``closed_loop=True`` run in a
    :class:`repro.core.cluster.Cluster` (every device program-driven, flags
    routed over the fabric); otherwise the single-detailed-device
    :class:`repro.core.simulator.Eidola` replay path is used.  Both return a
    :class:`repro.core.simulator.Report`.

    ``sanitize=True`` (closed loop only) runs the
    :class:`repro.analysis.sanitize.TrafficSanitizer` alongside the engines:
    byte conservation, calendar monotonicity, and exactly-once flag delivery
    are asserted at the end of the run (raising ``SanitizerError`` on
    violation) without perturbing any simulated state.

    ``timeline`` (closed loop only) selects the pod-scale timeline engine
    (:mod:`repro.core.cohort_timeline`): ``None`` (default) auto-enables it
    whenever the lockstep-lane invariant holds, ``True`` requires it (error
    when ineligible), ``False`` always uses the per-phase interpreter.

    ``lockstep`` (closed loop only) is the same tri-state for the bulk
    lockstep solvers, which substitute for the timeline engine — whole
    loops advance as closed forms instead of per-phase interpretation.
    The flat solver (:mod:`repro.core.lockstep`) covers globally
    rank-uniform programs on the single-tier ring; the tiered solver
    (:mod:`repro.core.lockstep_tiered`) covers group-uniform programs
    (leaders vs. workers, the uniform collectives) over the ``two_tier``,
    ``fat_tree``, and ``rail_optimized`` presets, pricing real multi-leg
    routes.  Together they make 1024-4096 device collectives — flat and
    tiered — practical; ``Report.meta["lockstep_reason"]`` records either
    ``"engaged"`` or the exact reason the solvers declined.
    """
    from .simulator import Eidola  # late import: simulator imports target

    devices, dpn = _resolve_shape(devices, nodes, devices_per_node)
    if dpn is not None:
        params.setdefault("devices_per_node", dpn)
    if devices is not None:
        cfg = (cfg or SimConfig()).with_devices(devices)
    if isinstance(scenario, Scenario):
        # the instance's programs/traces were built from its cfg; running the
        # engines under another cfg would silently mix two configurations
        if cfg is not None and cfg != scenario.cfg:
            raise ValueError(
                "scenario instance was built with a different SimConfig than "
                "the one passed to simulate(); rebuild the scenario or drop "
                "the cfg/devices arguments"
            )
        cfg = scenario.cfg
    cfg = (cfg or SimConfig()).validate()
    sc = _resolve(scenario, cfg, params)
    if sc.closed_loop:
        from .cluster import Cluster  # late import: cluster imports target

        return Cluster(
            cfg,
            sc,
            perturb=perturb,
            collect_segments=collect_segments,
            sanitize=sanitize,
            timeline=timeline,
            lockstep=lockstep,
            plan_cache=_plan_cache,
            plan_key=_plan_key,
        ).run()
    if sanitize:
        raise ValueError(
            "sanitize=True requires a closed-loop scenario (the sanitizer "
            "shadows the cluster's fabric and directory accounting)"
        )
    if timeline is True:
        raise ValueError(
            "timeline=True requires a closed-loop scenario (the timeline "
            "engine drives a Cluster of lockstep lanes)"
        )
    if lockstep is True:
        raise ValueError(
            "lockstep=True requires a closed-loop scenario (the bulk solver "
            "advances a Cluster of rank-uniform symbolic programs)"
        )
    return Eidola(
        cfg,
        sc.traces(),
        scenario=sc,
        amap=sc.amap,
        perturb=perturb,
        collect_segments=collect_segments,
    ).run()


# ---------------------------------------------------------------------------
# parameter sweeps
# ---------------------------------------------------------------------------

# SimConfig field names: any sweep/CLI key in this set is a config override,
# everything else is a scenario constructor parameter (the CLI reuses this)
SIM_CONFIG_FIELDS = frozenset(f.name for f in fields(SimConfig))
_CFG_FIELDS = SIM_CONFIG_FIELDS


@dataclass
class SweepPoint:
    """One (scenario params x config overrides x engine) simulation."""

    scenario: str
    engine: str
    overrides: Dict[str, object]
    params: Dict[str, object]
    report: object  # Report (typed loosely to avoid the circular import)

    def row(self) -> Dict[str, object]:
        r = self.report
        return {
            "scenario": self.scenario,
            "engine": self.engine,
            **self.overrides,
            **self.params,
            "flag_reads": r.flag_reads,
            "nonflag_reads": r.nonflag_reads,
            "kernel_span_ns": r.kernel_span_ns,
            "wall_time_s": r.wall_time_s,
        }


class SweepRunner:
    """Fan one scenario across a parameter grid and a set of engines.

    Grid keys naming :class:`SimConfig` fields become config overrides; all
    other keys are forwarded to the scenario constructor.  The cross product
    of the grid runs once per engine.
    """

    def __init__(
        self,
        scenario: Union[str, Type[Scenario]],
        base_cfg: Optional[SimConfig] = None,
        *,
        engines: Sequence[EngineKind] = (EngineKind.EVENT,),
        perturb=None,
        collect_segments: bool = False,
    ):
        self.scenario_cls = (
            get_scenario(scenario) if isinstance(scenario, str) else scenario
        )
        self.base_cfg = base_cfg or SimConfig()
        self.engines = tuple(engines)
        self.perturb = perturb
        self.collect_segments = collect_segments
        # compiled lockstep plans keyed by the point's full (scenario,
        # engine, config, params) identity; plans are read-only at run
        # time, so revisiting a shape (e.g. sweeping a non-structural
        # parameter per repeat) skips recompilation.  Perturbed sweeps
        # bypass the cache: a perturbation may reroute the run entirely.
        self._plan_cache: Dict[tuple, object] = {}

    def run(self, grid: Optional[Dict[str, Iterable]] = None, **grid_kw) -> List[SweepPoint]:
        grid = dict(grid or {})
        grid.update(grid_kw)
        keys = sorted(grid)
        combos = list(itertools.product(*(list(grid[k]) for k in keys))) or [()]
        points: List[SweepPoint] = []
        for combo in combos:
            assignment = dict(zip(keys, combo))
            # "devices"/"nodes" are sugar for the fabric shape (as in
            # simulate()); the resolved devices_per_node stays a scenario
            # parameter so it reaches the constructor and the sweep row
            devices, dpn = _resolve_shape(
                assignment.pop("devices", None),
                assignment.pop("nodes", None),
                assignment.get("devices_per_node"),
            )
            if dpn is not None:
                assignment["devices_per_node"] = dpn
            overrides = {k: v for k, v in assignment.items() if k in _CFG_FIELDS}
            if devices is not None:
                overrides["n_egpus"] = SimConfig().with_devices(devices).n_egpus
            params = {k: v for k, v in assignment.items() if k not in _CFG_FIELDS}
            for eng in self.engines:
                cfg = self.base_cfg.with_(engine=eng, **overrides)
                plan_key = (
                    (
                        self.scenario_cls.name,
                        repr(cfg),
                        tuple(
                            sorted((k, repr(v)) for k, v in params.items())
                        ),
                    )
                    if self.perturb is None
                    else None
                )
                report = simulate(
                    self.scenario_cls,
                    cfg,
                    perturb=self.perturb,
                    collect_segments=self.collect_segments,
                    _plan_cache=(
                        self._plan_cache if plan_key is not None else None
                    ),
                    _plan_key=plan_key,
                    **params,
                )
                points.append(
                    SweepPoint(
                        scenario=self.scenario_cls.name,
                        engine=EngineKind(eng).value,
                        overrides=overrides,
                        params=params,
                        report=report,
                    )
                )
        return points

    @staticmethod
    def to_csv(points: Sequence[SweepPoint]) -> str:
        if not points:
            return ""

        def cell(v) -> str:
            s = str(v)
            if any(ch in s for ch in ",\"\n"):
                s = '"' + s.replace('"', '""') + '"'
            return s

        cols: List[str] = []
        for p in points:
            for k in p.row():
                if k not in cols:
                    cols.append(k)
        lines = [",".join(cell(c) for c in cols)]
        for p in points:
            row = p.row()
            lines.append(",".join(cell(row.get(c, "")) for c in cols))
        return "\n".join(lines)
