"""Eidola simulator facade.

Wires together the address map, directory memory, Monitor Log, workload model,
WTT, and the selected engine; produces a :class:`Report` with the quantities
the paper measures (flag/non-flag reads, kernel span, per-WG timelines,
wall-clock simulation time).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .config import EngineKind, SimConfig, SyncPolicy
from .engine import CyclePollEngine, EventQueueEngine
from .events import Segment, TraceBundle, effective_writes
from .memory import AddressMap, DirectoryMemory
from .monitor import MonitorLog
from .scenario import Scenario
from .target import TargetDevice
from .wtt import WriteTrackingTable

__all__ = ["Report", "Eidola", "run_gemv_allreduce"]


@dataclass
class Report:
    engine: str
    sync: str
    traffic: Dict[str, int]
    flag_reads: int
    nonflag_reads: int
    kernel_span_ns: float
    sim_cycles: int
    wall_time_s: float
    wtt_registered: int
    wtt_enacted: int
    wtt_head_polls: int
    scenario: str = "gemv_allreduce"
    monitor_stats: Dict[str, int] = field(default_factory=dict)
    segments: List[Segment] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)
    # multi-device (closed-loop cluster) breakdown; open-loop runs keep the
    # defaults (one detailed device, aggregate == device 0)
    n_devices: int = 1
    per_device: Dict[int, Dict[str, int]] = field(default_factory=dict)
    closed_loop: bool = False

    def summary(self) -> str:
        mode = f"|{self.n_devices}dev closed" if self.closed_loop else ""
        return (
            f"[{self.scenario}|{self.engine}/{self.sync}{mode}] "
            f"flag_reads={self.flag_reads} "
            f"nonflag_reads={self.nonflag_reads} "
            f"kernel={self.kernel_span_ns:.0f}ns "
            f"wall={self.wall_time_s * 1e3:.1f}ms"
        )

    def device_summary(self) -> str:
        """One line per device: flag/non-flag reads and xGMI in/out."""
        lines = []
        for d in sorted(self.per_device):
            t = self.per_device[d]
            lines.append(
                f"  device {d}: flag_reads={t.get('flag_reads', 0)} "
                f"nonflag_reads={t.get('nonflag_reads', 0)} "
                f"xgmi_in={t.get('xgmi_writes_in', 0)} "
                f"xgmi_out={t.get('xgmi_writes_out', 0)}"
            )
        return "\n".join(lines)


class Eidola:
    """One simulated kernel launch on a multi-device system.

    ``traces`` carries the eidolons' registered writes (the setup-kernel
    payload).  The simulation enacts each write at
    ``wakeup_ns + cfg.xgmi_enact_latency_ns`` — the paper's wakeupTime is the
    *issue* time; visibility at the target directory includes the fabric hop.

    ``scenario`` selects the detailed device's phase programs (see
    :mod:`repro.core.scenario`); when omitted, the registered
    ``gemv_allreduce`` scenario is used, preserving the seed behaviour of
    raw-trace runs.  Most callers should prefer
    :func:`repro.core.scenario.simulate`, which builds matching traces too.
    """

    def __init__(
        self,
        cfg: SimConfig,
        traces: TraceBundle,
        *,
        scenario: Optional[Scenario] = None,
        amap: Optional[AddressMap] = None,
        perturb=None,
        collect_segments: bool = True,
    ):
        self.cfg = cfg.validate()
        self.traces = traces
        if scenario is not None and amap is not None and scenario.amap != amap:
            raise ValueError("scenario and Eidola were given different AddressMaps")
        if scenario is None:
            from .scenarios.gemv_allreduce import GemvAllReduceScenario

            scenario = GemvAllReduceScenario(
                cfg, amap or AddressMap(n_devices=cfg.n_devices)
            )
        self.scenario = scenario
        self.amap = scenario.amap
        self.perturb = perturb
        self.collect_segments = collect_segments

    def _build(self):
        cfg = self.cfg
        memory = DirectoryMemory(self.amap)
        monitor = (
            MonitorLog(
                memory,
                semantics=cfg.monitor_semantics,  # type: ignore[arg-type]
                wake_latency_cycles=cfg.wake_latency_cycles,
            )
            if cfg.sync == SyncPolicy.SYNCMON
            else None
        )
        device = TargetDevice(
            cfg, self.scenario, memory, monitor, perturb=self.perturb
        )
        wtt = WriteTrackingTable(clock_ghz=cfg.clock_ghz)
        wtt.register_many(
            effective_writes(
                self.traces,
                latency_ns=cfg.xgmi_enact_latency_ns,
                perturb=self.perturb,
            )
        )
        return memory, monitor, device, wtt

    def run(self) -> Report:
        cfg = self.cfg
        if cfg.engine == EngineKind.VECTOR:
            report = self.scenario.run_vectorized(self)
            if report is None:
                raise NotImplementedError(
                    f"scenario {self.scenario.name!r} has no vectorized engine; "
                    "use EngineKind.CYCLE or EngineKind.EVENT"
                )
            return report
        memory, monitor, device, wtt = self._build()
        engine = (
            CyclePollEngine() if cfg.engine == EngineKind.CYCLE else EventQueueEngine()
        )
        res = engine.run(device, wtt)
        return Report(
            engine=engine.name,
            sync=cfg.sync.value,
            traffic=memory.traffic.as_dict(),
            flag_reads=memory.traffic.flag_reads,
            nonflag_reads=memory.traffic.nonflag_reads,
            kernel_span_ns=cfg.cycles_to_ns(device.kernel_end_cycle),
            sim_cycles=res.sim_cycles,
            wall_time_s=res.wall_time_s,
            wtt_registered=wtt.stats.registered,
            wtt_enacted=wtt.stats.enacted,
            wtt_head_polls=res.head_polls,
            scenario=self.scenario.name,
            monitor_stats=dict(monitor.stats) if monitor else {},
            segments=device.collect_segments() if self.collect_segments else [],
            meta=dict(self.traces.meta),
            n_devices=1,
            per_device={0: memory.traffic.as_dict()},
            closed_loop=False,
        )


def run_gemv_allreduce(
    cfg: SimConfig,
    flag_delays_ns: Sequence[float] | float,
    *,
    perturb=None,
    collect_segments: bool = True,
) -> Report:
    """Convenience: build Table-1-style traces for ``cfg`` and simulate.

    Kept as a thin wrapper over the registered ``gemv_allreduce`` scenario;
    new code should call :func:`repro.core.scenario.simulate`.
    """
    from .scenarios.gemv_allreduce import GemvAllReduceScenario

    scenario = GemvAllReduceScenario(cfg, flag_delays_ns=flag_delays_ns)
    return Eidola(
        cfg,
        scenario.traces(),
        scenario=scenario,
        perturb=perturb,
        collect_segments=collect_segments,
    ).run()
