"""Runtime-variability models (paper §1, Figs. 1 vs 2).

The paper's characterization shows that *identical* kernels on *identical*
hardware exhibit very different timelines run-to-run because of transient
network traffic and contention.  Eidola supports studying this by perturbing
(a) per-workgroup phase durations (clock/contention jitter on the detailed
device) and (b) registered-write timestamps (network-induced delay on the
eidolons' writes).  All perturbations are deterministic functions of
(seed, workgroup/write identity) so every engine sees the same perturbation.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from .events import RegisteredWrite

__all__ = ["NullPerturb", "GaussianPerturb", "PeerDelayPerturb", "compose"]


def _rng(seed: int, *key) -> np.random.Generator:
    h = zlib.crc32(("|".join(str(k) for k in key) + f"#{seed}").encode())
    return np.random.default_rng(h)


class NullPerturb:
    def scale_phase(self, wg: int, state: str, base_cycles: int) -> int:
        return base_cycles

    def jitter_write(self, w: RegisteredWrite) -> RegisteredWrite:
        return w


@dataclass
class GaussianPerturb:
    """Multiplicative lognormal-ish jitter on phases and additive on writes."""

    seed: int = 0
    phase_sigma: float = 0.0       # relative sigma on phase durations
    write_sigma_ns: float = 0.0    # additive sigma on write wakeups

    def scale_phase(self, wg: int, state: str, base_cycles: int) -> int:
        if self.phase_sigma <= 0:
            return base_cycles
        g = _rng(self.seed, "phase", wg, state).normal(0.0, self.phase_sigma)
        return max(1, int(round(base_cycles * float(np.exp(g)))))

    def jitter_write(self, w: RegisteredWrite) -> RegisteredWrite:
        if self.write_sigma_ns <= 0:
            return w
        d = float(
            _rng(self.seed, "write", w.src, w.seq).normal(0.0, self.write_sigma_ns)
        )
        return RegisteredWrite(
            wakeup_ns=max(0.0, w.wakeup_ns + d),
            addr=w.addr,
            data=w.data,
            size=w.size,
            src=w.src,
            seq=w.seq,
        )


@dataclass
class PeerDelayPerturb:
    """Delay specific eidolons' writes (the paper's Fig. 2 non-ideal case,
    where GPUs 2 and 3 are held up by transient fabric contention)."""

    extra_delay_ns: Dict[int, float] = field(default_factory=dict)

    def scale_phase(self, wg: int, state: str, base_cycles: int) -> int:
        return base_cycles

    def jitter_write(self, w: RegisteredWrite) -> RegisteredWrite:
        d = self.extra_delay_ns.get(w.src, 0.0)
        if not d:
            return w
        return RegisteredWrite(
            wakeup_ns=w.wakeup_ns + d,
            addr=w.addr,
            data=w.data,
            size=w.size,
            src=w.src,
            seq=w.seq,
        )


class compose:
    """Apply several perturbations in sequence."""

    def __init__(self, *perturbs):
        self.perturbs = perturbs

    def scale_phase(self, wg: int, state: str, base_cycles: int) -> int:
        for p in self.perturbs:
            base_cycles = p.scale_phase(wg, state, base_cycles)
        return base_cycles

    def jitter_write(self, w: RegisteredWrite) -> RegisteredWrite:
        for p in self.perturbs:
            w = p.jitter_write(w)
        return w
