"""Compatibility shim — this module is now :mod:`repro.core.trace_render`.

``repro.core.timeline`` historically held the Chrome-trace/CSV/ASCII
*rendering* helpers, which made it too easy to confuse with
:mod:`repro.core.cohort_timeline`, the pod-scale timeline *engine*.  The
rendering code lives in :mod:`repro.core.trace_render`; import from there.
"""

from __future__ import annotations

from .trace_render import (  # noqa: F401
    ascii_timeline,
    phase_totals,
    to_chrome_trace,
    to_csv,
)

__all__ = ["to_chrome_trace", "to_csv", "ascii_timeline", "phase_totals"]
