"""Trace *rendering* and export (paper Figs. 1, 2, 8).

Per-workgroup phase segments can be exported as a Chrome-trace / Perfetto
JSON (openable at ui.perfetto.dev), as CSV, or rendered as a terminal ASCII
strip chart for quick inspection of ideal vs. non-ideal executions.

Not to be confused with :mod:`repro.core.cohort_timeline`, the pod-scale
timeline *engine* that advances cohorts of devices between synchronization
events.  This module only draws/exports ``Segment`` lists a simulation has
already produced; it was previously named ``repro.core.timeline`` (a thin
compatibility shim remains under that name).
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence

from .events import PHASE_COLORS, PHASE_GLYPHS as _GLYPH, Segment

__all__ = ["to_chrome_trace", "to_csv", "ascii_timeline", "phase_totals"]


def to_chrome_trace(
    segments: Sequence[Segment], *, device: int = 0, label: str = "GPU"
) -> str:
    """Chrome trace-event JSON; one tid per workgroup row, like the figures.

    Closed-loop (multi-device) segment lists map each simulated device to its
    own Chrome-trace process; ``device`` offsets the pid numbering.
    """
    events = []
    pids = set()
    for s in segments:
        pid = device + s.device
        pids.add(pid)
        events.append(
            {
                "name": s.phase,
                "cat": PHASE_COLORS.get(s.phase, "unknown"),
                "ph": "X",
                "ts": s.start_ns / 1000.0,  # chrome traces are in us
                "dur": max(s.dur_ns, 1e-3) / 1000.0,
                "pid": pid,
                "tid": s.wg,
                "args": {"phase": s.phase},
            }
        )
    meta = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": pid,
            "args": {"name": f"{label}{pid}"},
        }
        for pid in sorted(pids or {device})
    ]
    return json.dumps({"traceEvents": meta + events})


def to_csv(segments: Sequence[Segment]) -> str:
    """CSV export; a ``device`` column is appended only for multi-device
    segment lists, keeping the single-device header stable."""
    multi = any(s.device for s in segments)
    lines = ["wg,phase,start_ns,end_ns" + (",device" if multi else "")]
    for s in segments:
        row = f"{s.wg},{s.phase},{s.start_ns:.3f},{s.end_ns:.3f}"
        if multi:
            row += f",{s.device}"
        lines.append(row)
    return "\n".join(lines)


def ascii_timeline(
    segments: Sequence[Segment],
    *,
    width: int = 100,
    max_rows: int = 16,
    row_stride: Optional[int] = None,
) -> str:
    """Terminal strip chart: one row per (sampled) workgroup.

    Glyphs: g/G compute (remote/local tiles), B flag write, r spin-wait,
    b reduce, ^ broadcast, . descheduled — mirroring the paper's palette.
    """
    if not segments:
        return "(no segments)"
    t_end = max(s.end_ns for s in segments)
    t_end = max(t_end, 1e-9)
    multi = any(s.device for s in segments)
    by_row: Dict[tuple, List[Segment]] = {}
    for s in segments:
        by_row.setdefault((s.device, s.wg), []).append(s)
    keys = sorted(by_row)
    stride = row_stride or max(1, len(keys) // max_rows)
    rows = []
    for dev, wg in keys[::stride][:max_rows]:
        row = [" "] * width
        for s in sorted(by_row[(dev, wg)], key=lambda x: x.start_ns):
            a = int(s.start_ns / t_end * (width - 1))
            b = int(s.end_ns / t_end * (width - 1))
            for i in range(a, max(a, b) + 1):
                row[i] = _GLYPH.get(s.phase, "?")
        tag = f"d{dev} wg{wg:4d}" if multi else f"wg{wg:4d}"
        rows.append(f"{tag} |" + "".join(row) + "|")
    header = f"t=0 {'-' * (width - 14)} t={t_end / 1000.0:.2f}us"
    return "\n".join([header] + rows)


def phase_totals(segments: Sequence[Segment]) -> Dict[str, float]:
    """Total ns spent per phase across all workgroups."""
    out: Dict[str, float] = {}
    for s in segments:
        out[s.phase] = out.get(s.phase, 0.0) + s.dur_ns
    return out
