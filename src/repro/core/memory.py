"""Directory memory model with flag-region traffic accounting.

The paper models inter-GPU synchronization flags as *non-cacheable* memory:
peer writes complete atomically at the target GPU's cache directory, and local
polling reads always observe the latest value (§2.2).  We reproduce exactly
that contract — a flat byte-addressed space with a designated flag region,
where enacted xGMI writes are serialized against polling reads — without
modeling L1/L2 structure (the paper's measured quantities never depend on it).

Traffic accounting mirrors the paper's Figures 6/9: every read is classified as
a *flag read* (spin-wait / monitor-validation traffic) or a *non-flag read*
(general memory traffic: matrix sectors, vector, partial tiles).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from .events import RegisteredWrite

__all__ = ["AddressMap", "DirectoryMemory", "TrafficCounters"]

LINE_BYTES = 64  # coherence line size used for Monitor Log line addresses


@dataclass(frozen=True)
class AddressMap:
    """Layout of the target device's simulated address space.

    Mirrors a rocSHMEM-style symmetric heap: every participating device sees
    the same layout, so flag addresses computed on one device are valid pointers
    on its peers (§2.2: "allocates a single symmetric heap across all
    participating GPUs ... ensures a uniform address layout").

    Regions (byte offsets, half-open):
      [flag_base, flag_base + flag_slots*n_devices*flag_stride)  flag variables
      [partial_base, ...)                              peer partial-tile buffers
      [data_base, ...)                                 everything else

    ``flag_slots`` generalises the single ``flags[src]`` array of the fused
    GEMV+AllReduce kernel to scenarios that synchronise more than once per
    peer (e.g. one flag per ring step, or per pipeline microbatch): slot ``s``
    is a second index into the flag region, and ``flag_addr(src)`` with the
    default slot 0 is byte-identical to the original layout.
    """

    flag_base: int = 0x3F_D004_F00
    flag_stride: int = LINE_BYTES  # padded flags to prevent false sharing
    n_devices: int = 4
    flag_slots: int = 1
    flags_share_line: bool = False  # paper Fig. 7 shows both layouts exist
    partial_base: int = 0x3F_E000_000
    data_base: int = 0x100_000

    def claim_flag_block(self, label: str, slot_lo: int, slot_hi: int) -> None:
        """Claim slots ``[slot_lo, slot_hi)`` across *all* devices.

        Equivalent to ``claim_flag_slots(label, ((d, s) for d in
        range(n_devices) for s in range(slot_lo, slot_hi)))`` but recorded as
        a slot interval, so pod-scale scenarios (devices × slots in the
        millions) pay O(#claims) for the collision guarantee instead of
        O(devices × slots).
        """
        if not (0 <= slot_lo <= slot_hi <= self.flag_slots):
            raise ValueError(
                f"flag-slot claim {label!r}: slot range [{slot_lo}, "
                f"{slot_hi}) out of range (flag_slots={self.flag_slots})"
            )
        blocks = self.__dict__.get("_slot_blocks")
        if blocks is None:
            # the dataclass is frozen; the claim registry is bookkeeping, not
            # layout state, so it lives outside the declared fields
            blocks = []
            object.__setattr__(self, "_slot_blocks", blocks)
        for lo, hi, owner in blocks:
            if owner != label and slot_lo < hi and lo < slot_hi:
                raise ValueError(
                    f"flag slot collision: slots [{max(slot_lo, lo)}, "
                    f"{min(slot_hi, hi)}) already allocated to {owner!r}, "
                    f"now claimed by {label!r} — give each synchronization "
                    "stage its own slot range"
                )
        claims = self.__dict__.get("_slot_claims")
        if claims:
            for (device, slot), owner in claims.items():
                if owner != label and slot_lo <= slot < slot_hi:
                    raise ValueError(
                        f"flag slot collision: (device={device}, "
                        f"slot={slot}) already allocated to {owner!r}, now "
                        f"claimed by {label!r} — give each synchronization "
                        "stage its own slot range"
                    )
        blocks.append((slot_lo, slot_hi, label))

    def claim_flag_slots(self, label: str, pairs) -> None:
        """Register ``(device, slot)`` flag allocations under ``label``.

        Scenario builders call this for every slot range they lay out, so a
        collision — two different allocation sites landing on the same
        ``(device, slot)`` — fails loudly at scenario-construction time with
        both owners named, instead of surfacing as confusing runtime behavior
        (a flag satisfied by the wrong stage).  Re-claiming a pair under the
        same label is idempotent (builders may run per rank).  Full-device ×
        slot-interval claims should prefer :meth:`claim_flag_block`, which
        records an interval instead of one entry per pair.
        """
        claims = self.__dict__.get("_slot_claims")
        if claims is None:
            claims = {}
            object.__setattr__(self, "_slot_claims", claims)
        new = dict.fromkeys(pairs, label)  # C-speed dedup of the pair stream
        nd = self.n_devices
        ns = self.flag_slots
        for device, slot in new:
            if not (0 <= device < nd):
                raise ValueError(
                    f"flag-slot claim {label!r}: device {device} out of "
                    f"range for {nd} devices"
                )
            if not (0 <= slot < ns):
                raise ValueError(
                    f"flag-slot claim {label!r}: slot {slot} out of range "
                    f"(flag_slots={ns})"
                )
        blocks = self.__dict__.get("_slot_blocks")
        if blocks:
            for lo, hi, owner in blocks:
                if owner == label:
                    continue
                for device, slot in new:
                    if lo <= slot < hi:
                        raise ValueError(
                            f"flag slot collision: (device={device}, "
                            f"slot={slot}) already allocated to {owner!r}, "
                            f"now claimed by {label!r} — give each "
                            "synchronization stage its own slot range"
                        )
        if claims:
            for key in new.keys() & claims.keys():
                owner = claims[key]
                if owner != label:
                    device, slot = key
                    raise ValueError(
                        f"flag slot collision: (device={device}, "
                        f"slot={slot}) already allocated to {owner!r}, now "
                        f"claimed by {label!r} — give each synchronization "
                        "stage its own slot range"
                    )
        claims.update(new)

    def flag_addr(self, src_device: int, slot: int = 0) -> int:
        """Address of ``flags[slot][src_device]`` in the target's memory."""
        if not (0 <= src_device < self.n_devices):
            raise ValueError(f"device {src_device} out of range")
        if not (0 <= slot < self.flag_slots):
            raise ValueError(f"flag slot {slot} out of range")
        idx = slot * self.n_devices + src_device
        if self.flags_share_line:
            # 8-byte flags packed into one line (monitor-mask exercise)
            return self.flag_base + 8 * idx
        return self.flag_base + self.flag_stride * idx

    def flag_linear(self) -> Tuple[int, int]:
        """``(base, unit)`` of the flag pool's linear address form.

        ``flag_addr(src, slot) == base + unit * (slot * n_devices + src)``
        for every in-range pair — the affine family the parametric layout
        prover (:mod:`repro.analysis.layout`) reasons over without
        enumerating slots.  ``unit`` is the per-flag pitch (8 bytes when
        flags share a line, else ``flag_stride``).
        """
        unit = 8 if self.flags_share_line else self.flag_stride
        return (self.flag_base, unit)

    def flag_region(self) -> Tuple[int, int]:
        n_flags = self.n_devices * self.flag_slots
        if self.flags_share_line:
            hi = self.flag_base + 8 * n_flags
        else:
            hi = self.flag_base + self.flag_stride * n_flags
        return (self.flag_base, hi)

    def is_flag(self, addr: int) -> bool:
        lo, hi = self.flag_region()
        return lo <= addr < hi

    def decode_flag(self, addr: int) -> Optional[Tuple[int, int]]:
        """Inverse of :meth:`flag_addr`: ``(src_device, slot)`` or ``None``.

        Returns ``None`` for addresses outside the flag region or not aligned
        to a flag base (diagnostics must not misattribute stray addresses).
        """
        lo, hi = self.flag_region()
        if not (lo <= addr < hi):
            return None
        stride = 8 if self.flags_share_line else self.flag_stride
        off = addr - self.flag_base
        if off % stride:
            return None
        idx = off // stride
        return (idx % self.n_devices, idx // self.n_devices)

    def line_of(self, addr: int) -> int:
        return addr & ~(LINE_BYTES - 1)

    def with_partial_clearance(self) -> "AddressMap":
        """Return a map whose partial-tile region starts above the flag
        region.

        The default bases leave ~16 MB between ``flag_base`` and
        ``partial_base``; a pod-scale flag pool (``flag_slots * n_devices *
        flag_stride`` bytes) can overrun that gap, and data-marker writes —
        allocated upward from ``partial_base`` — then *alias high flag
        slots*, so a stale marker satisfies a flag wait long before the
        real emission arrives.  Scenarios with per-step flag slots must
        call this when constructing their map so the two regions never
        overlap.  A no-op (returns ``self``) when the gap already clears.
        """
        hi = self.flag_region()[1]
        if hi <= self.partial_base:
            return self
        page = 0x1000
        bumped = (hi + page - 1) // page * page
        return replace(self, partial_base=bumped)


@dataclass
class TrafficCounters:
    """Read/write accounting in the categories the paper reports."""

    flag_reads: int = 0
    nonflag_reads: int = 0
    local_writes: int = 0
    xgmi_writes_in: int = 0   # peer writes enacted at this device's directory
    xgmi_writes_out: int = 0  # writes this device issued to peers
    xgmi_bytes_in: int = 0
    xgmi_bytes_out: int = 0
    read_bytes: int = 0
    write_bytes: int = 0

    @property
    def total_reads(self) -> int:
        return self.flag_reads + self.nonflag_reads

    def as_dict(self) -> Dict[str, int]:
        return {
            "flag_reads": self.flag_reads,
            "nonflag_reads": self.nonflag_reads,
            "total_reads": self.total_reads,
            "local_writes": self.local_writes,
            "xgmi_writes_in": self.xgmi_writes_in,
            "xgmi_writes_out": self.xgmi_writes_out,
            "xgmi_bytes_in": self.xgmi_bytes_in,
            "xgmi_bytes_out": self.xgmi_bytes_out,
            "read_bytes": self.read_bytes,
            "write_bytes": self.write_bytes,
        }


class DirectoryMemory:
    """Flat memory + directory semantics for the detailed target device."""

    def __init__(self, amap: AddressMap):
        self.amap = amap
        self._mem: Dict[int, int] = {}  # byte address -> byte value
        self.traffic = TrafficCounters()
        # Observers called on every enacted peer write (the Monitor Log hooks
        # here: "each memory write that completes at the cache directory is
        # compared against the entries in the Monitor Log").
        self._write_observers: List[Callable[[int, int, int, int], None]] = []

    # -- observer registration ------------------------------------------------

    def add_write_observer(self, fn: Callable[[int, int, int, int], None]) -> None:
        """fn(addr, data, size, cycle) called after each directory write."""
        self._write_observers.append(fn)

    # -- raw value plumbing ----------------------------------------------------

    def _store(self, addr: int, data: int, size: int) -> None:
        mem = self._mem
        try:
            # int.to_bytes does the little-endian byte split in C
            bts = data.to_bytes(size, "little")
        except OverflowError:  # negative or wider than size: masked split
            for i in range(size):
                mem[addr + i] = (data >> (8 * i)) & 0xFF
            return
        for i, b in enumerate(bts):
            mem[addr + i] = b

    def _load(self, addr: int, size: int) -> int:
        val = 0
        for i in range(size):
            val |= self._mem.get(addr + i, 0) << (8 * i)
        return val

    # -- the architectural operations ------------------------------------------

    def read(self, addr: int, size: int = 4, *, count: bool = True) -> int:
        """A read issued by the detailed device (polling or data)."""
        val = self._load(addr, size)
        if count:
            if self.amap.is_flag(addr):
                self.traffic.flag_reads += 1
            else:
                self.traffic.nonflag_reads += 1
            self.traffic.read_bytes += size
        return val

    def bulk_reads(self, n: int, *, bytes_each: int, flag: bool = False) -> None:
        """Account ``n`` homogeneous reads without simulating each one.

        Used by the closed-form phases of the workload model (matrix sector
        streaming), where per-request simulation adds nothing the paper
        measures.  Counts are identical to issuing ``read`` n times.
        """
        if flag:
            self.traffic.flag_reads += n
        else:
            self.traffic.nonflag_reads += n
        self.traffic.read_bytes += n * bytes_each

    def write_local(self, addr: int, data: int, size: int = 4) -> None:
        self._store(addr, data, size)
        self.traffic.local_writes += 1
        self.traffic.write_bytes += size

    def bulk_local_writes(self, n: int, *, bytes_each: int) -> None:
        self.traffic.local_writes += n
        self.traffic.write_bytes += n * bytes_each

    def issue_xgmi_out(self, n: int, *, bytes_each: int) -> None:
        """Writes the detailed device pushes to a peer (partials, flags)."""
        self.traffic.xgmi_writes_out += n
        self.traffic.xgmi_bytes_out += n * bytes_each

    def enact_xgmi_write(self, w: RegisteredWrite, cycle: int) -> None:
        """Enact a registered peer write at the directory (atomic).

        This is the WTT -> memory handoff of §3.1: 'the write transaction
        completes at the cache directory level ... the memory state of the
        receiving GPU is updated to reflect the new flag value'.
        """
        self._store(w.addr, w.data, w.size)
        self.traffic.xgmi_writes_in += 1
        self.traffic.xgmi_bytes_in += w.size
        for fn in self._write_observers:
            fn(w.addr, w.data, w.size, cycle)

    def enact_xgmi_group(
        self, group: List[RegisteredWrite], cycle: int
    ) -> None:
        """Enact one WTT timestamp group: identical to calling
        :meth:`enact_xgmi_write` per write in order, with the counter adds
        coalesced (store order and observer order are preserved)."""
        mem = self._mem
        obs = self._write_observers
        nbytes = 0
        for w in group:
            data = w.data
            size = w.size
            addr = w.addr
            try:
                bts = data.to_bytes(size, "little")
            except OverflowError:
                bts = ((data >> (8 * i)) & 0xFF for i in range(size))
            for i, b in enumerate(bts):
                mem[addr + i] = b
            nbytes += size
            for fn in obs:
                fn(addr, data, size, cycle)
        self.traffic.xgmi_writes_in += len(group)
        self.traffic.xgmi_bytes_in += nbytes

    def enact_xgmi_run(
        self, addrs: List[int], cycles: List[int], data: int, size: int
    ) -> None:
        """Enact a bulk-popped run prefix: same-payload writes at per-write
        cycles (see ``WriteTrackingTable.pop_due_run``).  Identical to
        per-write :meth:`enact_xgmi_write` calls in order, with the byte
        split and counter adds done once for the batch."""
        mem = self._mem
        obs = self._write_observers
        try:
            bts = tuple(enumerate(data.to_bytes(size, "little")))
        except OverflowError:  # negative or wider than size: masked split
            bts = tuple(
                (i, (data >> (8 * i)) & 0xFF) for i in range(size)
            )
        if obs:
            for addr, cyc in zip(addrs, cycles):
                for i, b in bts:
                    mem[addr + i] = b
                for fn in obs:
                    fn(addr, data, size, cyc)
        else:
            for addr in addrs:
                for i, b in bts:
                    mem[addr + i] = b
        n = len(addrs)
        self.traffic.xgmi_writes_in += n
        self.traffic.xgmi_bytes_in += n * size

    # -- debugging convenience --------------------------------------------------

    def peek(self, addr: int, size: int = 4) -> int:
        """Uncounted read (simulator introspection, not device traffic)."""
        return self._load(addr, size)
