"""Closed-loop multi-device simulation: N program-driven devices, one fabric.

The paper's headline claim is modeling "synchronization behavior across large
multi-GPU configurations", but open-loop replay can never show one device's
perturbation rippling to another: eidolon flag-write times are synthesized up
front.  A :class:`Cluster` closes the loop — every device runs its own
phase-program interpreter (:class:`repro.core.target.TargetDevice` with its
own :class:`DirectoryMemory`, :class:`MonitorLog`, and
:class:`WriteTrackingTable`), and a completing phase *emits* xGMI writes
(:class:`repro.core.scenario.EmitOp`) that are routed over the fabric model
(:class:`repro.core.topology.FabricModel`: per-hop latency + per-egress-link
serialization/contention) and registered into the destination device's WTT.
Step-k flags are therefore written only when the emitting device actually
finishes step k, so a slow reduce on one rank measurably delays every
downstream rank.

Open-loop replay remains the degenerate case: a cluster of one detailed
device whose WTT was pre-loaded with a trace bundle is exactly the classic
:class:`repro.core.simulator.Eidola` run (same engines, same node type).

Determinism: emissions happen at phase completions, whose global order is
identical under both engines (writes before transitions, devices in id
order), and the fabric's contention state is updated in that order — so
cycle/event runs stay bit-identical, which the tests assert per scenario.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Union

from .config import EngineKind, SimConfig, SyncPolicy
from .engine import CyclePollEngine, EventQueueEngine
from .events import RegisteredWrite, Segment
from .interconnect import InterconnectSpec, build_fabric
from .memory import DirectoryMemory
from .monitor import MonitorLog
from .scenario import EmitOp, PhaseSpec, Scenario, SymbolicProgram
from .target import TargetDevice
from .topology import V5E, FabricModel, Topology
from .wtt import LazyWriteRun, RegistrationLike, WriteTrackingTable

__all__ = ["Cluster", "ClusterNode", "resolve_cluster_fabric"]

# perturb may be one object applied to every device, or a per-device mapping
PerturbLike = Union[None, object, Dict[int, object]]


def resolve_cluster_fabric(
    cfg: SimConfig,
    scenario: Scenario,
    fabric: Union[None, str, InterconnectSpec, FabricModel] = None,
    topology: Optional[Topology] = None,
) -> FabricModel:
    """The fabric a cluster run of ``scenario`` would route over.

    Priority order (shared by :class:`Cluster` and the static verifier's
    reachability check, so both always see the same fabric): an explicit
    ``fabric`` argument (ready :class:`FabricModel`, an
    :class:`InterconnectSpec`, or a registered preset name), then the
    scenario's ``interconnect`` spec, then its :class:`Topology`, then the
    flat single-tier ring over ``cfg.n_devices``.
    """
    topo = topology or getattr(scenario, "topology", None)
    if fabric is None:
        spec = getattr(scenario, "interconnect", None)
        if spec is not None:
            fabric = FabricModel.from_spec(spec)
        elif topo is not None:
            if topo.n_chips != cfg.n_devices:
                raise ValueError(
                    f"topology spans {topo.n_chips} chips but the cluster "
                    f"simulates {cfg.n_devices} devices"
                )
            fabric = FabricModel.from_topology(topo)
        else:
            fabric = FabricModel(
                cfg.n_devices, hw=getattr(scenario, "hw", V5E)
            )
    elif isinstance(fabric, str):
        # forward the scenario's node split only when it has one; a flat
        # topology (n_nodes == 1) leaves the preset's own default (e.g.
        # one-device nodes for fat_tree/rail_optimized) so a named
        # fabric never silently degenerates to a single node
        dpn = (
            topo.devices_per_node
            if topo is not None and topo.n_nodes > 1
            else None
        )
        fabric = FabricModel.from_spec(
            build_fabric(
                fabric,
                cfg.n_devices,
                getattr(scenario, "hw", V5E),
                devices_per_node=dpn,
            )
        )
    elif isinstance(fabric, InterconnectSpec):
        fabric = FabricModel.from_spec(fabric)
    if fabric.n_devices != cfg.n_devices:
        raise ValueError(
            f"fabric models {fabric.n_devices} devices but the cluster "
            f"simulates {cfg.n_devices}"
        )
    return fabric


@dataclass
class ClusterNode:
    """One simulated device: interpreter + private memory/monitor/WTT."""

    device_id: int
    memory: DirectoryMemory
    monitor: Optional[MonitorLog]
    target: TargetDevice
    wtt: WriteTrackingTable


class Cluster:
    """N detailed devices in one closed simulation loop.

    ``scenario`` must have been built with ``closed_loop=True`` (its
    ``programs_for(d)`` yields per-rank programs whose phases carry
    :class:`EmitOp`\\ s); ``scenario.traces_for(d)`` seeds each device's WTT
    (normally empty in closed loop — flags are emitted at run time).

    ``perturb`` may be a single perturbation object (applied to every device;
    note phase jitter is then *correlated* across devices because it is keyed
    by (wg, phase) only) or a mapping ``{device_id: perturb}`` to disturb
    specific ranks — the knob the propagation experiments turn.

    The fabric resolves in priority order: an explicit ``fabric=`` argument
    (a ready :class:`FabricModel`, an
    :class:`repro.core.interconnect.InterconnectSpec`, or a registered preset
    *name* such as ``"fat_tree"``), then the scenario's ``interconnect`` spec
    (set when it was built with ``fabric=``/link overrides), then the
    scenario's :class:`Topology` (its ``topology`` attribute, or an explicit
    ``topology=`` argument: non-DCI axes form the intra-node tier, DCI axes
    the inter-node tier — the ``ring``/``two_tier`` presets).  Without any of
    those the fabric degenerates to the flat single-tier ring over
    ``cfg.n_devices`` (the pre-tiered behaviour).
    """

    def __init__(
        self,
        cfg: SimConfig,
        scenario: Scenario,
        *,
        perturb: PerturbLike = None,
        collect_segments: bool = True,
        fabric: Union[None, str, InterconnectSpec, FabricModel] = None,
        topology: Optional[Topology] = None,
        cohorts: bool = True,
        sanitize: bool = False,
        timeline: Optional[bool] = None,
        lockstep: Optional[bool] = None,
        plan_cache=None,
        plan_key=None,
    ):
        self.cfg = cfg.validate()
        self.scenario = scenario
        self.amap = scenario.amap
        self.perturb = perturb
        self.collect_segments = collect_segments
        # optional cross-run lockstep plan cache (sweeps revisiting the
        # same shape skip recompilation; plans are read-only at run time)
        self._plan_cache = plan_cache
        self._plan_key = plan_key
        # None = auto (use the timeline engine when eligible), True = require
        # it (error when ineligible), False = never
        self._timeline = timeline
        # same tri-state for the bulk lockstep solver, which substitutes for
        # the timeline engine on rank-uniform symbolic programs
        self._lockstep = lockstep
        self._cohorts_flag = cohorts
        self.fabric = resolve_cluster_fabric(
            self.cfg, scenario, fabric=fabric, topology=topology
        )
        if sanitize:
            # late import: repro.analysis imports this module
            from repro.analysis.sanitize import TrafficSanitizer

            self._san = TrafficSanitizer(
                self.amap, self.fabric, cfg.n_devices
            )
        else:
            self._san = None
        self._seq = 0  # cluster-wide emission seq counter (plain int: hot path)
        # (src_device, phase_idx, emit_idx) -> completions seen (coalescing)
        self._emit_counts: Dict[tuple, int] = {}
        # dst device -> marker data writes placed so far (address spacing)
        self._data_marks: Dict[int, int] = {}

        t0 = time.perf_counter()
        self.nodes: List[ClusterNode] = []
        for d in range(cfg.n_devices):
            memory = DirectoryMemory(self.amap)
            monitor = (
                MonitorLog(
                    memory,
                    semantics=cfg.monitor_semantics,  # type: ignore[arg-type]
                    wake_latency_cycles=cfg.wake_latency_cycles,
                )
                if cfg.sync == SyncPolicy.SYNCMON
                else None
            )
            target = TargetDevice(
                cfg,
                scenario,
                memory,
                monitor,
                perturb=self._perturb_for(d),
                device_id=d,
                emit_sink=self._on_emit,
                cohorts=cohorts,
            )
            wtt = WriteTrackingTable(clock_ghz=cfg.clock_ghz)
            if self._san is not None:
                memory.add_write_observer(self._san.observer_for(d))
            self.nodes.append(ClusterNode(d, memory, monitor, target, wtt))

        # seed traces (the open-loop degenerate case / warm-start writes) get
        # the same xGMI visibility treatment as the Eidola facade
        for node in self.nodes:
            for w in scenario.traces_for(node.device_id):
                eff = replace(
                    w, wakeup_ns=w.wakeup_ns + cfg.xgmi_enact_latency_ns
                )
                p = self._perturb_for(node.device_id)
                if p is not None:
                    eff = p.jitter_write(eff)
                if self._san is not None:
                    self._san.note_seed_write(node.device_id, eff.addr)
                node.wtt.register(eff)
        # program-construction wall (nodes + seed traces), surfaced in
        # Report.meta["program_stats"] — symbolic programs keep this O(1)
        # per rank in step count where flat construction was O(steps)
        self._construct_wall_s = time.perf_counter() - t0

    # ------------------------------------------------------------------
    # emission: phase completion -> fabric -> destination WTT
    # ------------------------------------------------------------------

    def _perturb_for(self, device: int):
        if isinstance(self.perturb, dict):
            return self.perturb.get(device)
        return self.perturb

    def _on_emit(
        self,
        src: int,
        wg_id: int,
        phase_idx: int,
        spec: PhaseSpec,
        cycle: int,
        count: int = 1,
    ) -> None:
        """TargetDevice sink: fire ``spec.emits`` for a completed phase.

        ``count`` is the number of workgroups the completing cohort stands
        for: "last" coalescing advances its completion counter by that many,
        and "each" emission routes one message per represented workgroup (in
        the same order the per-workgroup interpreter would have).
        """
        n_wgs = self.nodes[src].target.n_wgs
        fire: List[EmitOp] = []
        for i, op in enumerate(spec.emits):
            if op.coalesce == "last":
                key = (src, phase_idx, i)
                seen = self._emit_counts.get(key, 0) + count
                self._emit_counts[key] = seen
                if seen < n_wgs:
                    continue
                fire.append(op)
            else:  # "each": one message per represented workgroup
                fire.extend([op] * count)
        if len(fire) > 1:
            self._route_batch(src, fire, cycle)
        elif fire:
            self._route(src, fire[0], cycle)

    def _route(self, src: int, op: EmitOp, cycle: int) -> None:
        cfg = self.cfg
        if op.dst >= cfg.n_devices:
            raise ValueError(
                f"EmitOp.dst {op.dst} out of range for {cfg.n_devices} devices"
            )
        # the flag write itself is fabric traffic out of the emitting device;
        # payload bytes are accounted by the phase's own TrafficOps
        self.nodes[src].memory.issue_xgmi_out(1, bytes_each=op.size)
        issue_ns = cfg.cycles_to_ns(cycle)
        arrival_ns = self.fabric.transfer(
            src, op.dst, op.payload_bytes + op.size, issue_ns
        )
        if self._san is not None:
            self._san.note_emission(
                src,
                op.dst,
                op.addr if op.addr is not None
                else self.amap.flag_addr(src, op.slot),
                op.payload_bytes + op.size,
                issue_ns,
                arrival_ns,
            )
        self.nodes[op.dst].wtt.register_many(
            self._emit_writes(src, op, arrival_ns, cycle)
        )

    def _route_batch(self, src: int, ops: List[EmitOp], cycle: int) -> None:
        """Route all of one completion's emissions in a single fabric pass.

        The ``all_to_all`` incast fires O(devices) same-cycle bursts per
        completing dispatch phase (O(devices^2) per run); pricing them with
        :meth:`FabricModel.transfer_batch` replaces that many python routing
        calls with one cumulative sum per egress port, and the resulting
        marker+flag writes land per destination through
        :meth:`WriteTrackingTable.register_many` — one heap restructure and
        one calendar hook per (source, destination) pair instead of ~9 of
        each.  Bit-identical to the sequential path: registration order,
        seqs, per-table reg_nos, and port FIFO order are all preserved.
        """
        cfg = self.cfg
        for op in ops:
            if op.dst >= cfg.n_devices:
                raise ValueError(
                    f"EmitOp.dst {op.dst} out of range for "
                    f"{cfg.n_devices} devices"
                )
        mem = self.nodes[src].memory
        for op in ops:
            mem.issue_xgmi_out(1, bytes_each=op.size)
        issue_ns = cfg.cycles_to_ns(cycle)
        arrivals = self.fabric.transfer_batch(
            src,
            [op.dst for op in ops],
            [op.payload_bytes + op.size for op in ops],
            issue_ns,
        )
        if self._san is not None:
            for op, arrival_ns in zip(ops, arrivals):
                self._san.note_emission(
                    src,
                    op.dst,
                    op.addr if op.addr is not None
                    else self.amap.flag_addr(src, op.slot),
                    op.payload_bytes + op.size,
                    issue_ns,
                    arrival_ns,
                )
        # writes are built in emission order (Cluster seqs identical to the
        # per-op path) and grouped per destination WTT; within one table the
        # batch preserves that order, so reg_nos — the pop tie-break — are
        # assigned exactly as sequential registration would have
        per_dst: Dict[int, List[RegistrationLike]] = {}
        for op, arrival_ns in zip(ops, arrivals):
            ws = self._emit_writes(src, op, arrival_ns, cycle)
            bucket = per_dst.get(op.dst)
            if bucket is None:
                per_dst[op.dst] = ws
            else:
                bucket.extend(ws)
        for dst, ws in per_dst.items():
            self.nodes[dst].wtt.register_many(ws)

    def _emit_writes(
        self, src: int, op: EmitOp, arrival_ns: float, cycle: int
    ) -> List[RegistrationLike]:
        """The registered writes (markers + flag) of one routed emission,
        enforcing causality: a write emitted at ``cycle`` can never become
        visible in the same cycle (jitter perturbations could otherwise pull
        it into the past, which the two engines would order differently).

        Without a perturbation on the destination, the marker burst is
        returned as one :class:`LazyWriteRun` descriptor instead of
        ``data_writes`` materialized dataclasses — the WTT synthesizes the
        members at enactment with the identical wakeup expression and a
        contiguous seq/reg_no block, so pop order and counters are
        bit-identical (the incast registration cost drops from O(devices^2)
        dataclasses per run to O(devices) descriptors).
        """
        cfg = self.cfg
        arrival_ns += cfg.xgmi_enact_latency_ns
        addr = op.addr if op.addr is not None else self.amap.flag_addr(src, op.slot)
        # per-destination constants hoisted out of the marker loop (the
        # all_to_all incast builds O(devices^2) marker writes per run)
        p = self._perturb_for(op.dst)
        min_ns = cfg.cycles_to_ns(cycle + 1)
        seq = self._seq
        out: List[RegistrationLike] = []
        if cfg.include_data_writes and op.data_writes > 0:
            lead = min(cfg.data_write_lead_ns, arrival_ns)
            t0 = arrival_ns - lead
            base = self._data_marks.get(op.dst, 0)
            self._data_marks[op.dst] = base + op.data_writes
            mark_data = 0xC0 + (src % 16)
            mark_base = self.amap.partial_base + base * 64
            if p is None:
                out.append(
                    LazyWriteRun(
                        count=op.data_writes,
                        base_ns=t0,
                        span_ns=lead,
                        addr_base=mark_base,
                        addr_stride=64,
                        data=mark_data,
                        size=8,
                        src=src,
                        seq0=seq,
                        min_ns=min_ns,
                    )
                )
                seq += op.data_writes
            else:
                for k in range(op.data_writes):
                    w = RegisteredWrite(
                        wakeup_ns=t0 + lead * (k + 1) / (op.data_writes + 1),
                        addr=mark_base + k * 64,
                        data=mark_data,
                        size=8,
                        src=src,
                        seq=seq,
                    )
                    seq += 1
                    w = p.jitter_write(w)
                    if w.wakeup_ns < min_ns:
                        w = replace(w, wakeup_ns=min_ns)
                    out.append(w)
        w = RegisteredWrite(
            wakeup_ns=arrival_ns,
            addr=addr,
            data=op.data,
            size=op.size,
            src=src,
            seq=seq,
        )
        seq += 1
        if p is not None:
            w = p.jitter_write(w)
        if w.wakeup_ns < min_ns:
            w = replace(w, wakeup_ns=min_ns)
        out.append(w)
        self._seq = seq
        return out

    # ------------------------------------------------------------------
    # running
    # ------------------------------------------------------------------

    def run(self):
        """Drive all devices to completion; return an aggregate Report."""
        from .simulator import Report  # late import (simulator imports target)

        cfg = self.cfg
        if cfg.engine == EngineKind.VECTOR:
            raise NotImplementedError(
                "closed-loop cluster simulation requires EngineKind.CYCLE or "
                "EngineKind.EVENT (the vectorized engine is replay-only)"
            )
        # The timeline engine is a faster implementation of the event
        # engine's semantics (bit-identical counters/segments), so it
        # substitutes for EngineKind.EVENT when the lockstep-lane invariant
        # holds; timeline=True makes ineligibility an error instead of a
        # silent fallback.
        use_timeline = False
        lockstep_used = False
        tl_reason: Optional[str] = None
        if cfg.engine == EngineKind.EVENT and self._timeline is not False:
            if not self._cohorts_flag:
                tl_reason = "cohorts=False forces the per-workgroup interpreter"
            else:
                from .cohort_timeline import timeline_support

                tl_reason = timeline_support(self)
            use_timeline = tl_reason is None
        elif self._timeline is True:
            tl_reason = "timeline engine requires EngineKind.EVENT"
        if self._timeline is True and not use_timeline:
            raise ValueError(
                f"timeline engine requested but unavailable: {tl_reason}"
            )
        if self._lockstep is True and not use_timeline:
            raise ValueError(
                "lockstep solver requested but unavailable: it substitutes "
                "for the timeline engine, which is not in use here "
                f"({tl_reason or 'engine is not EngineKind.EVENT'})"
            )
        lockstep_reason: Optional[str] = None
        if use_timeline:
            # the bulk lockstep solver substitutes for the timeline engine
            # when every rank (or every rank of each program group, on the
            # multi-tier presets) runs a group-uniform symbolic program;
            # anything else falls back to the generic timeline
            ls_reason: Optional[str] = None
            ls_engine = None
            if self._lockstep is not False:
                from .lockstep import LockstepEngine, lockstep_support

                ls_reason = lockstep_support(self)
                if ls_reason is None:
                    ls_engine = LockstepEngine(self)
                    cache = self._plan_cache
                    key = self._plan_key
                    cached = (
                        cache.get(key)
                        if cache is not None and key is not None
                        else None
                    )
                    ls_reason = ls_engine.compile(reuse=cached)
                    if (
                        ls_reason is None
                        and cached is None
                        and cache is not None
                        and key is not None
                    ):
                        cache[key] = ls_engine.plan_handle()
            else:
                ls_reason = "lockstep=False disables the bulk solver"
            if self._lockstep is True and ls_reason is not None:
                raise ValueError(
                    f"lockstep solver requested but unavailable: {ls_reason}"
                )
            res = None
            if ls_reason is None:
                from .lockstep import UnsupportedProgram

                try:
                    res = ls_engine.run()
                    lockstep_used = True
                except UnsupportedProgram as exc:
                    # the solver mutates cluster state only in its final
                    # write-back, so a mid-solve refusal (e.g. a run-time
                    # route spot-check) falls back to the timeline cleanly
                    ls_reason = f"lockstep solve failed: {exc}"
                    if self._lockstep is True:
                        raise ValueError(
                            "lockstep solver requested but unavailable: "
                            f"{ls_reason}"
                        ) from exc
            if res is None:
                from .cohort_timeline import TimelineEngine

                res = TimelineEngine(self).run()
            lockstep_reason = "engaged" if lockstep_used else ls_reason
            engine_name = "event"  # same semantics & counters as the event
            # engine; meta["engine_impl"] records the implementation
        else:
            engine = (
                CyclePollEngine()
                if cfg.engine == EngineKind.CYCLE
                else EventQueueEngine()
            )
            res = engine.run_nodes([(n.target, n.wtt) for n in self.nodes])
            engine_name = engine.name
            why = tl_reason or "engine is not EngineKind.EVENT"
            lockstep_reason = (
                "lockstep solver substitutes for the timeline engine, "
                f"which is not in use here ({why})"
            )
        if self._san is not None:
            self._san.check()

        traffic: Dict[str, int] = {}
        per_device: Dict[int, Dict[str, int]] = {}
        monitor_stats: Dict[str, int] = {}
        segments: List[Segment] = []
        spans: Dict[int, float] = {}
        for node in self.nodes:
            td = node.memory.traffic.as_dict()
            per_device[node.device_id] = td
            for k, v in td.items():
                traffic[k] = traffic.get(k, 0) + v
            if node.monitor is not None:
                for k, v in node.monitor.stats.items():
                    monitor_stats[k] = monitor_stats.get(k, 0) + v
            spans[node.device_id] = cfg.cycles_to_ns(
                node.target.kernel_end_cycle
            )
            if self.collect_segments:
                segments.extend(node.target.collect_segments())
        # symbolic-vs-materialized program accounting (after the run, so the
        # materialized count reflects what the engines actually expanded)
        progs: Dict[int, object] = {}
        for node in self.nodes:
            for c in node.target.cohorts:
                progs.setdefault(id(c.phases), c.phases)
        sym = [p for p in progs.values() if isinstance(p, SymbolicProgram)]
        program_stats = {
            "symbolic_programs": len(sym),
            "flat_programs": len(progs) - len(sym),
            "segments": sum(len(p.segments) for p in sym),
            "program_phases": sum(len(p) for p in progs.values()),
            "materialized_phases": sum(len(p._memo) for p in sym)
            + sum(
                len(p)
                for p in progs.values()
                if not isinstance(p, SymbolicProgram)
            ),
            "construct_wall_s": self._construct_wall_s,
            "lockstep": lockstep_used,
        }
        return Report(
            engine=engine_name,
            sync=cfg.sync.value,
            traffic=traffic,
            flag_reads=traffic.get("flag_reads", 0),
            nonflag_reads=traffic.get("nonflag_reads", 0),
            kernel_span_ns=max(spans.values()) if spans else 0.0,
            sim_cycles=res.sim_cycles,
            wall_time_s=res.wall_time_s,
            wtt_registered=sum(n.wtt.stats.registered for n in self.nodes),
            wtt_enacted=sum(n.wtt.stats.enacted for n in self.nodes),
            wtt_head_polls=res.head_polls,
            scenario=self.scenario.name,
            monitor_stats=monitor_stats,
            segments=segments,
            meta={
                "closed_loop": True,
                "sanitized": self._san is not None,
                "engine_impl": "timeline" if use_timeline else engine_name,
                "lockstep_reason": lockstep_reason,
                "program_stats": program_stats,
                **(
                    {"wall_breakdown": res.breakdown}
                    if res.breakdown is not None
                    else {}
                ),
                "device_spans_ns": spans,
                "fabric": dict(self.fabric.stats),
                "fabric_name": self.fabric.spec.name,
                "n_nodes": self.fabric.n_nodes,
                "devices_per_node": self.fabric.devices_per_node,
                **{f"param_{k}": v for k, v in self.scenario.params.items()},
            },
            n_devices=cfg.n_devices,
            per_device=per_device,
            closed_loop=True,
        )
