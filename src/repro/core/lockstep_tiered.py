"""Tiered lockstep: group-uniform bulk solving over multi-tier fabrics.

The flat solver (:mod:`repro.core.lockstep`) requires one globally
rank-uniform program on the single-tier ring.  This module generalizes both
axes at once:

* **groups** — ranks partition by ``SymbolicProgram.group`` (leaders vs.
  workers in ``hierarchical_allreduce``, the single ``ring``/``all`` group of
  the uniform collectives).  Structural uniformity — segment kinds, loop
  bounds, phase names/durations/traffic, emit parameters — is required only
  *within* a group; rank-varying peers and flag addresses stay per-group
  vectors.  Cross-group dependencies (worker handoff -> leader barrier,
  leader broadcast -> worker wait) are stitched by a compile-time worklist
  that orders every group's stage instances so each wait follows the
  emission(s) that write its flags, and fails loudly (naming the blocked
  group, rank, phase, and flag) when no such order exists — which is exactly
  the pipelined cross-rank chain the timeline engine keeps handling.

* **multi-leg route families** — emissions are priced over the fabric's real
  leg sequences (intra-node ICI, DCI uplinks, fat-tree spine, rails) by a
  vectorized replica of the routing policy, spot-checked against
  ``fab.legs`` at compile time.  Two pricers cover every supported family:

  - *elementwise*: when no two messages of a stage share an egress port
    (ring steps, hierarchical stages on all presets), each leg is one
    ``max``/``add`` pass over per-port busy vectors — identical IEEE-754 ops
    to the event engine's sequential ``_leg`` calls, which factor into
    independent per-port chains because every port has a single producer
    rank whose issue cycles are monotone in program order.

  - *ordered*: when messages share ports (the all-to-all incast's single
    dispatch stage, the broadcast fan-out), messages are priced in the event
    engine's global order — ``(cycle, device, dst-run position)`` — by a
    port-wavefront: each sweep extends every port's priced prefix with the
    touches whose upstream legs resolved, using restart-segment ``cumsum``
    chains that reproduce the scalar ``start = max(ready, busy)``;
    ``busy = start + ser`` sequence bit-exactly.  The supported topologies
    route leg ``i`` classes strictly before leg ``i+1`` classes, so the
    sweep count is bounded by the leg depth, not the message count.

Divergences from the event engine match the flat solver's documented set
(no ``_mem``/``flag_set_cycle`` mirrors, aggregate float ``queued_ns`` in
stage order, ``wtt_head_polls`` 0); per-port busy chains, set cycles, and
every integer counter stay bit-identical.
"""

from __future__ import annotations

import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .engine import EngineResult
from .scenario import (
    Affine,
    AffineRun,
    EmitOp,
    EmitRun,
    LoopEmit,
    LoopSpec,
    as_symbolic,
)

__all__ = ["compile_tiered", "run_tiered"]

_SUPPORTED = {
    "ring": "_RingRouting",
    "two_tier": "_TwoTierRouting",
    "fat_tree": "_FatTreeRouting",
    "rail_optimized": "_RailRouting",
}


def _unsupported(msg):
    from .lockstep import UnsupportedProgram

    return UnsupportedProgram(msg)


def _uniform(values, what, ids=None):
    """First value, or raise naming the first divergent rank."""
    vals = list(values)
    first = vals[0]
    for i, v in enumerate(vals[1:], 1):
        if v != first:
            who = ids[i] if ids is not None else i
            who0 = ids[0] if ids is not None else 0
            raise _unsupported(
                f"{what} varies across ranks (rank {who} differs from "
                f"rank {who0})"
            )
    return first


# ---------------------------------------------------------------------------
# port space + vectorized routing replicas
# ---------------------------------------------------------------------------


class _Ports:
    """Dense integer port ids + per-port link-class tables for one fabric.

    Encodings (id -> tuple is materialized in ``tuples`` for write-back):

    * ici ``(dev, +-1)``   -> ``dev*2 + (0 if +1 else 1)``
    * two_tier ``("dci", node, +-1)`` -> ``2n + node*2 + (0 if +1 else 1)``
    * fat_tree ``("up", node)`` / ``("down", node)`` / ``("spine", leaf)``
    * rail ``("rail", node, r)``
    """

    def __init__(self, fab):
        spec = fab.spec
        self.kind = spec.name
        n = self.n = spec.n_devices
        self.dpn = spec.devices_per_node
        self.n_nodes = n // self.dpn
        self.params = dict(getattr(spec, "params", {}) or {})
        tuples: List[tuple] = []
        cls: List[str] = []
        for dev in range(n):
            tuples.append((dev, 1))
            tuples.append((dev, -1))
            cls.extend(("ici", "ici"))
        nn = self.n_nodes
        if self.kind == "two_tier":
            for node in range(nn):
                tuples.append(("dci", node, 1))
                tuples.append(("dci", node, -1))
                cls.extend(("dci", "dci"))
        elif self.kind == "fat_tree":
            self.npl = int(self.params["nodes_per_leaf"])
            self.n_leaves = int(self.params["n_leaves"])
            for node in range(nn):
                tuples.append(("up", node))
                cls.append("dci")
            for node in range(nn):
                tuples.append(("down", node))
                cls.append("dci")
            for leaf in range(self.n_leaves):
                tuples.append(("spine", leaf))
                cls.append("spine")
        elif self.kind == "rail_optimized":
            self.rails = int(spec.nics_per_node)
            for node in range(nn):
                for r in range(self.rails):
                    tuples.append(("rail", node, r))
                    cls.append("rail")
        self.tuples = tuples
        self.P = len(tuples)
        names = sorted(set(cls))
        self.cls_names = names
        cid = {c: i for i, c in enumerate(names)}
        self.port_cls = np.array([cid[c] for c in cls], np.int64)
        missing = [c for c in names if c not in fab._cls]
        if missing:
            raise _unsupported(
                f"fabric lacks link class(es) {missing} the solver prices"
            )
        self.cls_bw = np.array([fab._cls[c][0] for c in names])
        self.cls_lat = np.array([fab._cls[c][1] for c in names])

    # -- vectorized port encoders ---------------------------------------
    def ici(self, dev, d):
        return dev * 2 + (d != 1)

    def dci(self, node, nd):
        return 2 * self.n + node * 2 + (nd != 1)

    def up(self, node):
        return 2 * self.n + node

    def down(self, node):
        return 2 * self.n + self.n_nodes + node

    def spine(self, leaf):
        return 2 * self.n + 2 * self.n_nodes + leaf

    def rail(self, node, r):
        return 2 * self.n + node * self.rails + r


def _ring_vec(src, dst, n):
    """(hops, dir) arrays of the shortest ring path — ``_ring_route``."""
    fwd = (dst - src) % n
    bwd = (src - dst) % n
    take_fwd = fwd <= bwd
    hops = np.where(take_fwd, fwd, bwd)
    d = np.where(take_fwd, 1, -1)
    return hops, d


def _legs_csr(ports: _Ports, src, dst):
    """Vectorized leg expansion: CSR of (port, hops, cls) per message, legs
    in traversal order.  Replicates the routing policies of the supported
    presets; ``_spot_check`` verifies samples against the real ``fab.legs``.
    """
    n = ports.n
    dpn = ports.dpn
    m = len(src)
    # candidate leg sets in traversal order (append order IS the per-message
    # leg order: a message matches either the same-node set or the cross-node
    # sets, and the cross sets are appended rank-ascending)
    cand: List[tuple] = []  # (mask, port_all, hops_all, cls_id)
    cid = {c: i for i, c in enumerate(ports.cls_names)}
    ici_c = cid["ici"]

    def add_sel(mask, rank, port_all, hops_all, cls_id):
        """port/hops given over all m; select by mask (rank is implied by
        append order and kept only for readability at call sites)."""
        cand.append((mask, port_all, hops_all, cls_id))

    if ports.kind == "ring":
        hops, d = _ring_vec(src, dst, n)
        full = np.ones(m, bool)
        add_sel(full, 0, ports.ici(src, d), hops, ici_c)
    else:
        idt = src.dtype
        sn, sl = np.divmod(src, dpn)
        dn, dl = np.divmod(dst, dpn)
        same = sn == dn
        lhops, ld = _ring_vec(sl, dl, dpn)
        add_sel(same, 0, ports.ici(src, ld), lhops, ici_c)
        cross = ~same
        if ports.kind == "two_tier":
            dci_c = cid["dci"]
            h1, d1 = _ring_vec(sl, np.zeros(m, idt), dpn)
            add_sel(cross & (sl != 0), 0, ports.ici(src, d1), h1, ici_c)
            nhops, nd = _ring_vec(sn, dn, ports.n_nodes)
            add_sel(cross, 1, ports.dci(sn, nd), nhops, dci_c)
            gw = dn * dpn
            h3, d3 = _ring_vec(np.zeros(m, idt), dl, dpn)
            add_sel(cross & (dl != 0), 2, ports.ici(gw, d3), h3, ici_c)
        elif ports.kind == "fat_tree":
            dci_c = cid["dci"]
            spine_c = cid["spine"]
            npl = ports.npl
            s_leaf = sn // npl
            d_leaf = dn // npl
            h1, d1 = _ring_vec(sl, np.zeros(m, idt), dpn)
            add_sel(cross & (sl != 0), 0, ports.ici(src, d1), h1, ici_c)
            ones = np.ones(m, idt)
            add_sel(cross, 1, ports.up(sn), ones, dci_c)
            add_sel(
                cross & (s_leaf != d_leaf), 2, ports.spine(s_leaf),
                2 * ones, spine_c,
            )
            add_sel(cross, 3, ports.down(dn), ones, dci_c)
            gw = dn * dpn
            h5, d5 = _ring_vec(np.zeros(m, idt), dl, dpn)
            add_sel(cross & (dl != 0), 4, ports.ici(gw, d5), h5, ici_c)
        elif ports.kind == "rail_optimized":
            rail_c = cid["rail"]
            rails = ports.rails
            r = dl % rails
            h1, d1 = _ring_vec(sl, r, dpn)
            add_sel(cross & (sl != r), 0, ports.ici(src, d1), h1, ici_c)
            add_sel(
                cross, 1, ports.rail(sn, r), np.ones(m, idt), rail_c
            )
            nic = dn * dpn + r
            h3, d3 = _ring_vec(r, dl, dpn)
            add_sel(cross & (dl != r), 2, ports.ici(nic, d3), h3, ici_c)
        else:  # pragma: no cover - gated by _SUPPORTED
            raise _unsupported(f"unsupported fabric kind {ports.kind!r}")

    # direct CSR construction: leg (msg i, set r) lands at
    # offs[i] + (earlier sets present for i) — no sort over the leg table
    # int32 throughout: the leg table reaches ~66M rows at 4096 devices on
    # fat_tree, and every downstream pass (sorts, gathers, chains) is
    # memory-bandwidth bound; all values fit comfortably in 31 bits
    counts = np.zeros(m, np.int32)
    for mask, _p, _h, _c in cand:
        counts += mask
    offs = np.zeros(m + 1, np.int64)
    np.cumsum(counts, out=offs[1:])
    L = int(offs[m])
    msg = np.repeat(np.arange(m, dtype=np.int32), counts)
    port = np.empty(L, np.int32)
    hops = np.empty(L, np.int32)
    cls = np.empty(L, np.int32)
    prior = np.zeros(m, np.int32)
    for mask, port_all, hops_all, cls_id in cand:
        idx = np.flatnonzero(mask)
        if not idx.size:
            continue
        pos = offs[idx] + prior[idx]
        port[pos] = port_all[idx]
        hops[pos] = hops_all[idx]
        cls[pos] = cls_id
        prior += mask
    return {
        "msg": msg, "port": port, "hops": hops, "cls": cls, "offs": offs,
    }


def _spot_check(ports: _Ports, fab, src, dst, legs) -> None:
    """Verify sampled messages' replicated legs against ``fab.legs``."""
    m = len(src)
    if m == 0:
        return
    samples = sorted({0, m // 3, m // 2, (2 * m) // 3, m - 1})
    offs = legs["offs"]
    for i in samples:
        got = fab.legs(int(src[i]), int(dst[i]))
        lo, hi = int(offs[i]), int(offs[i + 1])
        if len(got) != hi - lo:
            raise _unsupported(
                "fabric routes diverge from the solver's replicated router"
            )
        for j, leg in enumerate(got):
            t = lo + j
            ok = (
                leg.cls == ports.cls_names[int(legs["cls"][t])]
                and leg.port == ports.tuples[int(legs["port"][t])]
                and leg.hops == int(legs["hops"][t])
            )
            if not ok:
                raise _unsupported(
                    "fabric routes diverge from the solver's replicated "
                    "router"
                )


# ---------------------------------------------------------------------------
# group-aligned program
# ---------------------------------------------------------------------------


class _GEmit:
    """One group's emission family at one aligned phase position.

    kind: "single" (one message per rank, k-invariant dst), "run" (a
    contiguous per-rank dst run sharing one flag address), or "fanout_all"
    (the all-peers incast, group == all ranks).
    """

    __slots__ = (
        "kind", "payload", "size", "dw", "dst", "addr_base", "addr_step",
        "cnt",
    )

    def __init__(self, kind, payload, size, dw, dst, addr_base, addr_step,
                 cnt=1):
        self.kind = kind
        self.payload = payload
        self.size = size
        self.dw = dw
        self.dst = dst              # int64[g] dst device (base for "run")
        self.addr_base = addr_base  # int64[g] flag addr at k=0
        self.addr_step = addr_step  # int, addr advance per k
        self.cnt = cnt              # messages per rank ("run")


class _GPhase:
    __slots__ = ("name", "is_wait", "dur", "tdelta", "wait", "emit")

    def __init__(self, name, is_wait, dur, tdelta, wait, emit):
        self.name = name
        self.is_wait = is_wait
        self.dur = dur
        self.tdelta = tdelta
        # wait: None | ("cols", [(base_vec, kstep), ...])
        #            | ("allpeers", alpha, beta)
        self.wait = wait
        self.emit = emit


class _GSeg:
    __slots__ = ("count", "k0", "body")

    def __init__(self, count, k0, body):
        self.count = count
        self.k0 = k0
        self.body = body


class _Group:
    __slots__ = ("name", "devs", "segs", "counts", "dispatch", "total",
                 "tdf")

    def __init__(self, name, devs):
        self.name = name
        self.devs = devs  # int64[g], ascending device ids
        self.segs: List[_GSeg] = []
        self.counts = None
        self.dispatch = None
        self.total = 0
        self.tdf = None


def _wait_cols(specs, devs, k0, count, gname, phname):
    """Classify one aligned wait position into ordered address columns.

    Each rank's ``wait_addrs`` entries normalize to (base, kstep) columns:
    ints and ``AffineRun`` members are k-invariant, an ``Affine`` advances
    by its step per loop iteration.  Column structure must match across the
    group; bases become per-rank vectors.
    """
    g = len(specs)
    per_rank: List[List[Tuple[int, int]]] = []
    for i, sp in enumerate(specs):
        cols: List[Tuple[int, int]] = []
        for e in sp.wait_addrs:
            if isinstance(e, AffineRun):
                for p in range(e.count):
                    cols.append((e.start + e.stride * p, 0))
            elif isinstance(e, Affine):
                if count > 1:
                    cols.append((e.base, e.step))
                else:
                    cols.append((e.at(k0), 0))
            elif isinstance(e, (int, np.integer)):
                cols.append((int(e), 0))
            else:
                raise _unsupported(
                    f"unsupported wait entry {type(e).__name__} in phase "
                    f"{phname!r} of group {gname!r}"
                )
        per_rank.append(cols)
    ncols = _uniform(
        (len(c) for c in per_rank), f"wait width of phase {phname!r}",
        ids=devs,
    )
    out = []
    for c in range(ncols):
        kstep = _uniform(
            (per_rank[i][c][1] for i in range(g)),
            f"wait address step of phase {phname!r}", ids=devs,
        )
        base = np.array([per_rank[i][c][0] for i in range(g)], np.int64)
        out.append((base, kstep))
    return ("cols", out)


def _try_allpeers_wait(specs, devs, k0, count, n):
    """("allpeers", alpha, beta) when the group is all ranks and the wait is
    the all-peers barrier; None otherwise."""
    if len(devs) != n or devs[0] != 0 or devs[-1] != n - 1:
        return None
    total = 0
    for e in specs[0].wait_addrs:
        total += e.count if isinstance(e, AffineRun) else 1
    if total != n - 1 or n - 1 <= 1:
        return None
    from .lockstep import UnsupportedProgram, _classify_wait

    try:
        w = _classify_wait(specs, k0, count, n)
    except UnsupportedProgram:
        return None
    return w if w[0] == "allpeers" else None


def _classify_emit_group(amap, specs, devs, k0, count, n, gname, phname):
    """None, or a :class:`_GEmit` for the aligned emission position."""
    if not specs[0].emits:
        for i, sp in enumerate(specs):
            if sp.emits:
                raise _unsupported(
                    f"emit presence of phase {phname!r} varies across ranks "
                    f"(rank {devs[i]} differs from rank {devs[0]})"
                )
        return None
    g = len(specs)
    blame = f"phase {phname!r} of group {gname!r}"
    all_single = all(
        len(sp.emits) == 1 and isinstance(sp.emits[0], (LoopEmit, EmitOp))
        for sp in specs
    )
    all_run = all(
        len(sp.emits) == 1 and isinstance(sp.emits[0], EmitRun)
        for sp in specs
    )
    if all_single:
        dst = np.empty(g, np.int64)
        slots: List[Tuple[int, int]] = []
        payloads, sizes, dws = set(), set(), set()
        for i, sp in enumerate(specs):
            e = sp.emits[0]
            if isinstance(e, LoopEmit):
                if e.coalesce != "last":
                    raise _unsupported(
                        f"per-workgroup ('each') emission in {blame}"
                    )
                if e.dst.step != 0 and count > 1:
                    raise _unsupported(
                        f"k-varying emission destination in {blame} on a "
                        "multi-tier fabric"
                    )
                dst[i] = e.dst.at(k0)
                slots.append(
                    (e.slot.base, e.slot.step) if count > 1
                    else (e.slot.at(k0), 0)
                )
            elif isinstance(e, EmitOp):
                if e.coalesce != "last":
                    raise _unsupported(
                        f"per-workgroup ('each') emission in {blame}"
                    )
                if e.addr is not None:
                    raise _unsupported(
                        f"explicit EmitOp.addr override in {blame}"
                    )
                dst[i] = e.dst
                slots.append((e.slot, 0))
            else:
                raise _unsupported(
                    f"unsupported emit entry {type(e).__name__} in {blame}"
                )
            payloads.add(e.payload_bytes)
            sizes.add(e.size)
            dws.add(e.data_writes)
        if len(payloads) != 1 or len(sizes) != 1 or len(dws) != 1:
            raise _unsupported(f"emit parameters of {blame} vary across ranks")
        addr_base = np.empty(g, np.int64)
        addr_steps = set()
        for i, (sb, ss) in enumerate(slots):
            src_dev = int(devs[i])
            a0 = amap.flag_addr(src_dev, sb + ss * k0)
            if count > 1:
                a1 = amap.flag_addr(src_dev, sb + ss * (k0 + 1))
                step = a1 - a0
                klast = k0 + count - 1
                if amap.flag_addr(src_dev, sb + ss * klast) != a0 + step * (
                    count - 1
                ):
                    raise _unsupported(
                        f"flag address of {blame} is not affine over the "
                        "loop range"
                    )
            else:
                step = 0
            addr_steps.add(step)
            addr_base[i] = a0 - step * k0
        if len(addr_steps) != 1:
            raise _unsupported(
                f"flag address step of {blame} varies across ranks"
            )
        if dst.min() < 0 or dst.max() >= n:
            raise _unsupported(f"emit destination out of range in {blame}")
        if np.any(dst == devs):
            bad = int(devs[np.flatnonzero(dst == devs)[0]])
            raise _unsupported(
                f"self-directed emission in {blame} (rank {bad})"
            )
        return _GEmit(
            "single", payloads.pop(), sizes.pop(), dws.pop(), dst,
            addr_base, addr_steps.pop(),
        )
    # ---- contiguous per-rank dst run sharing one flag address ----------
    if all_run:
        if count > 1:
            raise _unsupported(
                f"EmitRun fan-out inside a k-loop in {blame} rewrites the "
                "same flags every iteration"
            )
        dst0 = np.empty(g, np.int64)
        cnts, slot0s, payloads, sizes, dws = set(), set(), set(), set(), set()
        for i, sp in enumerate(specs):
            e = sp.emits[0]
            if e.coalesce != "last":
                raise _unsupported(
                    f"per-workgroup ('each') emission in {blame}"
                )
            if e.count > 1 and e.dst_stride != 1 or e.slot_stride != 0:
                raise _unsupported(
                    f"non-contiguous EmitRun fan-out in {blame}"
                )
            dst0[i] = e.dst0
            cnts.add(e.count)
            slot0s.add(e.slot0)
            payloads.add(e.payload_bytes)
            sizes.add(e.size)
            dws.add(e.data_writes)
        if len(cnts) != 1 or len(slot0s) != 1 or len(payloads) != 1 \
                or len(sizes) != 1 or len(dws) != 1:
            raise _unsupported(f"fan-out parameters of {blame} vary across ranks")
        cnt = cnts.pop()
        if cnt < 1:
            return None
        slot0 = slot0s.pop()
        if dst0.min() < 0 or int(dst0.max()) + cnt - 1 >= n:
            raise _unsupported(f"emit destination out of range in {blame}")
        for i in range(g):
            if dst0[i] <= devs[i] < dst0[i] + cnt:
                raise _unsupported(
                    f"self-directed emission in {blame} (rank {int(devs[i])})"
                )
        addr_base = np.array(
            [amap.flag_addr(int(d), slot0) for d in devs], np.int64
        )
        return _GEmit(
            "run", payloads.pop(), sizes.pop(), dws.pop(), dst0,
            addr_base, 0, cnt=cnt,
        )
    # ---- all-peers fan-out (group must cover every rank) ---------------
    if len(devs) == n and devs[0] == 0:
        from .lockstep import UnsupportedProgram, _classify_emit

        try:
            e = _classify_emit(amap, specs, k0, count, n)
        except UnsupportedProgram as exc:
            raise _unsupported(f"{exc} ({blame})")
        if type(e).__name__ == "_FanoutEmit":
            if count > 1:
                raise _unsupported(
                    f"all-peers fan-out inside a k-loop in {blame}"
                )
            return _GEmit(
                "fanout_all", e.payload, e.size, e.dw, None, e.addr_vec, 0,
            )
    raise _unsupported(f"unsupported emission pattern in {blame}")


def _align_group(amap, n, group: _Group, progs) -> None:
    """Fill ``group.segs`` with the aligned per-phase classification."""
    devs = group.devs
    gname = group.name
    nsegs = _uniform(
        (len(p.segments) for p in progs),
        f"segment count of group {gname!r}", ids=devs,
    )
    tdf = group.tdf
    for j in range(nsegs):
        col = [p.segments[j] for p in progs]
        s0 = col[0]
        if isinstance(s0, LoopSpec):
            for i, s in enumerate(col):
                if not isinstance(s, LoopSpec) or s.count != s0.count \
                        or s.k0 != s0.k0 or len(s.body) != len(s0.body):
                    raise _unsupported(
                        f"loop structure of group {gname!r} varies across "
                        f"ranks (rank {devs[i]} differs from rank {devs[0]})"
                    )
            body = [
                _gphase(
                    amap, n, tdf, [s.body[b] for s in col], devs, gname,
                    s0.k0, s0.count,
                )
                for b in range(len(s0.body))
            ]
            group.segs.append(_GSeg(s0.count, s0.k0, body))
        else:
            for i, s in enumerate(col):
                if isinstance(s, LoopSpec):
                    raise _unsupported(
                        f"segment kinds of group {gname!r} vary across "
                        f"ranks (rank {devs[i]} differs from rank {devs[0]})"
                    )
            group.segs.append(
                _GSeg(1, 0, [_gphase(amap, n, tdf, col, devs, gname, 0, 1)])
            )


def _gphase(amap, n, tdf, specs, devs, gname, k0, count) -> _GPhase:
    s0 = specs[0]
    name = s0.name
    is_wait = s0.wait_addrs is not None
    for i, sp in enumerate(specs):
        if sp.name != name or (sp.wait_addrs is not None) != is_wait:
            raise _unsupported(
                f"phase structure of group {gname!r} varies across ranks "
                f"(rank {devs[i]} differs from rank {devs[0]})"
            )
    dur = 0 if is_wait else _uniform(
        (sp.duration_cycles for sp in specs),
        f"duration of phase {name!r} in group {gname!r}", ids=devs,
    )
    _uniform(
        (sp.traffic for sp in specs),
        f"traffic of phase {name!r} in group {gname!r}", ids=devs,
    )
    tdelta = tdf(s0) if tdf is not None else None
    wait = emit = None
    if is_wait:
        for i, sp in enumerate(specs):
            if sp.emits:
                raise _unsupported(
                    f"wait phase {name!r} of group {gname!r} has emissions "
                    f"(rank {devs[i]})"
                )
        wait = _try_allpeers_wait(specs, devs, k0, count, n)
        if wait is None:
            wait = _wait_cols(specs, devs, k0, count, gname, name)
    else:
        emit = _classify_emit_group(
            amap, specs, devs, k0, count, n, gname, name
        )
    return _GPhase(name, is_wait, dur, tdelta, wait, emit)


# ---------------------------------------------------------------------------
# emission families + compiled plan
# ---------------------------------------------------------------------------


class _Fam:
    """One aligned emission position's route family, shared by its k
    instances.  Messages are enumerated source-major (group row order, dst
    ascending within a rank's run) — the event engine's per-firing op order.
    """

    __slots__ = (
        "gi", "fid", "kind", "pricing", "payload", "size", "dw", "nb",
        "m", "cnt", "src_row", "src_dev", "dst", "addr_rel", "addr_step",
        "legs", "leg_slots", "keys_sorted", "keys_order", "dst_unique",
        "addr_vec", "cls_legs",
    )


class _Rec:
    """One emission instance awaiting its consumer wait(s)."""

    __slots__ = ("uid", "fam", "k", "consumed", "live")

    def __init__(self, uid, fam, k):
        self.uid = uid
        self.fam = fam
        self.k = k
        self.consumed = np.zeros(fam.m, bool)
        self.live = fam.m


class _TieredPlan:
    __slots__ = ("ports", "groups", "instrs", "refs")

    def __init__(self, ports, groups, instrs, refs):
        self.ports = ports
        self.groups = groups
        # ("p", gi, dur, tdelta, fam|None, uid, k)  non-wait phase
        # ("w", gi, cols, tdelta)  cols: [[(uid, idx, rows), ...], ...]
        # ("aw", gi, uid, tdelta)  all-peers barrier on a fanout record
        self.instrs = instrs
        self.refs = refs  # int64[n_uids]: runtime gathers per record


def _build_fam(ports, fab, grp, gi, fid, e: _GEmit, n) -> _Fam:
    fam = _Fam()
    fam.gi = gi
    fam.fid = fid
    fam.kind = e.kind
    fam.payload = e.payload
    fam.size = e.size
    fam.dw = e.dw
    fam.nb = e.payload + e.size
    fam.addr_step = e.addr_step
    fam.leg_slots = None
    fam.keys_sorted = None
    fam.addr_vec = None
    g = len(grp.devs)
    if e.kind == "fanout_all":
        fam.pricing = "ordered"
        fam.m = n * (n - 1)
        fam.cnt = n - 1
        fam.addr_vec = e.addr_base
        fam.legs = None  # built lazily at the (single) run instance
        fam.src_row = fam.src_dev = fam.dst = fam.addr_rel = None
        fam.dst_unique = False
        fam.cls_legs = None
        return fam
    if e.kind == "single":
        fam.cnt = 1
        fam.src_row = np.arange(g, dtype=np.int64)
        fam.src_dev = grp.devs
        fam.dst = e.dst
        fam.addr_rel = e.addr_base
    else:  # run
        fam.cnt = e.cnt
        fam.src_row = np.repeat(np.arange(g, dtype=np.int64), e.cnt)
        fam.src_dev = grp.devs[fam.src_row]
        fam.dst = (
            e.dst[:, None] + np.arange(e.cnt, dtype=np.int64)
        ).ravel()
        fam.addr_rel = np.repeat(e.addr_base, e.cnt)
    fam.m = len(fam.dst)
    fam.legs = _legs_csr(ports, fam.src_dev, fam.dst)
    _spot_check(ports, fab, fam.src_dev, fam.dst, fam.legs)
    # matching keys: (flag addr at k=0, dst) must identify each message
    keys = fam.addr_rel * np.int64(n) + fam.dst
    order = np.argsort(keys, kind="stable")
    skeys = keys[order]
    if fam.m > 1 and np.any(skeys[1:] == skeys[:-1]):
        raise _unsupported(
            f"duplicate (flag, destination) pair in an emission of group "
            f"{grp.name!r}"
        )
    fam.keys_sorted = skeys
    fam.keys_order = order
    fam.dst_unique = np.unique(fam.dst).size == fam.m
    # pricing: elementwise when no two messages of the instance share a
    # port; ordered per-port chains otherwise
    prt = fam.legs["port"]
    if np.unique(prt).size == prt.size:
        fam.pricing = "elem"
        offs = fam.legs["offs"]
        local = np.arange(len(prt), dtype=np.int64) - offs[fam.legs["msg"]]
        slots = []
        for s in range(int(local.max()) + 1 if len(prt) else 0):
            sel = np.flatnonzero(local == s)
            slots.append((
                fam.legs["msg"][sel], prt[sel],
                fam.legs["hops"][sel], fam.legs["cls"][sel],
            ))
        fam.leg_slots = slots
    else:
        fam.pricing = "ordered"
    fam.cls_legs = np.bincount(
        fam.legs["cls"], minlength=len(ports.cls_names)
    )
    return fam


def _register_ports(own, fam, gname):
    """Record port ownership; every port must have a single producer rank
    unless all its touches are priced in-order within one instance."""
    if fam.legs is None:
        return
    prt = fam.legs["port"]
    src = fam.src_dev[fam.legs["msg"]]
    pairs = np.unique(np.stack((prt, src)), axis=1)
    seen_ports, first = np.unique(pairs[0], return_index=True)
    if fam.pricing == "elem" and seen_ports.size != pairs.shape[1]:
        raise _unsupported(
            f"link port shared across source ranks in an emission of "
            f"group {gname!r}"
        )
    for p, s in zip(pairs[0], pairs[1]):
        p = int(p)
        s = int(s)
        prev = own.get(p)
        if prev is not None and prev != s:
            raise _unsupported(
                f"link port shared across source ranks {prev} and {s} "
                f"(group {gname!r}); cross-rank port interleaving stays on "
                "the timeline engine"
            )
        own[p] = s


class _Cursor:
    """Unrolled (segment, iteration, body position) walker for one group."""

    __slots__ = ("grp", "si", "kk", "bi", "done")

    def __init__(self, grp):
        self.grp = grp
        self.si = 0
        self.kk = 0
        self.bi = 0
        self.done = not grp.segs
        self._skip_empty()

    def _skip_empty(self):
        while not self.done and self.grp.segs[self.si].count <= 0:
            self.si += 1
            if self.si >= len(self.grp.segs):
                self.done = True

    def phase(self):
        seg = self.grp.segs[self.si]
        return seg.body[self.bi], seg.k0 + self.kk

    def advance(self):
        seg = self.grp.segs[self.si]
        self.bi += 1
        if self.bi >= len(seg.body):
            self.bi = 0
            self.kk += 1
            if self.kk >= seg.count:
                self.kk = 0
                self.si += 1
                if self.si >= len(self.grp.segs):
                    self.done = True
                    return
                self._skip_empty()


def _decode_flag(amap, n, addr):
    """Best-effort (writer, slot) of a flag address, for blame text."""
    try:
        base = amap.flag_addr(0, 0)
        dstride = amap.flag_addr(1, 0) - base
        idx, rem = divmod(int(addr) - base, dstride)
        if rem == 0 and idx >= 0:
            return idx % n, idx // n
    except Exception:
        pass
    return None, None


def _check_flag_reuse(progs, amap, cfg):
    """Decline programs where a flag address the solver stitches to an
    emission can also be set by an *earlier, unrelated* write.

    The event and timeline engines resolve waits by *value*: once a flag
    address holds data, every later wait on it completes at the next poll.
    The solver instead stitches each wait to its affine-matched emission, so
    any second writer of a stitched address makes the two disagree — either
    a *flag rewrite* (two emission instances targeting one (rank, flag)) or
    *marker aliasing* (``EmitOp.data_writes`` markers growing up from
    ``partial_base`` into a flag pool that overran the gap).

    The actual analysis lives in the parametric layout prover
    (:func:`repro.analysis.layout.check_programs`) — one implementation,
    shared with ``verify_scenario``/``prove_layout`` — and this gate cites
    the prover's finding verbatim.  Declined shapes stay on the timeline
    engine, which reproduces the engines' stale-flag timing exactly.
    """
    # analysis builds on core; import lazily to keep core import-light and
    # cycle-free
    from repro.analysis.layout import check_programs

    findings = check_programs(progs, amap, cfg)
    for f in findings:
        if f.severity != "error":
            continue
        tail = (
            "; stale-flag waits stay on the timeline engine"
            if f.kind == "flag-reuse"
            else "; stale-flag visibility stays on the timeline engine"
        )
        raise _unsupported(f.message + tail)


def _match_col(open_recs, want_addr, want_dst, n, cache):
    """Resolve one wait column against open emission records, latest first.

    Returns (segments, pend) — segments are (uid, idx, rows) gathers, pend
    the deferred consumption marks — or (None, blocked_row) when some rank's
    flag has no unconsumed earlier emission.
    """
    g = len(want_addr)
    remaining = np.ones(g, bool)
    segments = []
    pend = []
    for rec in reversed(open_recs):
        fam = rec.fam
        if fam.keys_sorted is None:
            continue
        rel = want_addr - fam.addr_step * rec.k
        ck = (fam.fid, rel.tobytes(), want_dst.tobytes())
        rows = cache.get(ck)
        if rows is None:
            keys = rel * np.int64(n) + want_dst
            pos = np.searchsorted(fam.keys_sorted, keys)
            pos_c = np.minimum(pos, fam.m - 1)
            hit = fam.keys_sorted[pos_c] == keys
            rows = np.where(hit, fam.keys_order[pos_c], -1)
            cache[ck] = rows
        valid = remaining & (rows >= 0)
        vi = np.flatnonzero(valid)
        if not vi.size:
            continue
        rr = rows[vi]
        free = ~rec.consumed[rr]
        vi = vi[free]
        if not vi.size:
            continue
        segments.append((rec.uid, vi, rows[vi]))
        pend.append((rec, rows[vi]))
        remaining[vi] = False
        if not remaining.any():
            return segments, pend
    return None, int(np.flatnonzero(remaining)[0])


def compile_tiered(cluster) -> _TieredPlan:
    """Group-align, classify, and schedule the pod's symbolic programs over
    a multi-tier fabric.  Raises :class:`UnsupportedProgram` with the
    offending group/rank/phase when the shape doesn't fit."""
    cfg = cluster.cfg
    n = cfg.n_devices
    amap = cluster.amap
    fab = cluster.fabric
    rcls = type(fab.spec.routing).__name__
    if _SUPPORTED.get(fab.spec.name) != rcls:
        raise _unsupported(
            f"fabric {fab.spec.name!r} (routing {rcls}) is outside the "
            "tiered solver's presets"
        )
    if amap.flag_addr(0, 0) >= (1 << 62) // max(2, n):
        raise _unsupported("flag address space too large for match keys")
    ports = _Ports(fab)
    progs = [
        as_symbolic(node.target.cohorts[0].phases) for node in cluster.nodes
    ]
    gorder: List[str] = []
    gmap: Dict[str, List[int]] = {}
    for dev, p in enumerate(progs):
        gname = p.group if p.group is not None else "ranks"
        if gname not in gmap:
            gmap[gname] = []
            gorder.append(gname)
        gmap[gname].append(dev)
    groups: List[_Group] = []
    for gname in gorder:
        devs = np.array(gmap[gname], np.int64)
        grp = _Group(gname, devs)
        tgt0 = cluster.nodes[int(devs[0])].target
        c0 = tgt0.cohorts
        grp.counts = np.array([c.count for c in c0], np.int64)
        grp.dispatch = np.array(
            [c.program.dispatch_cycle for c in c0], np.int64
        )
        grp.total = int(grp.counts.sum())
        grp.tdf = tgt0._tdelta_for
        for d in devs[1:]:
            cs = cluster.nodes[int(d)].target.cohorts
            if len(cs) != len(c0) or any(
                a.count != b.count
                or a.program.dispatch_cycle != b.program.dispatch_cycle
                for a, b in zip(cs, c0)
            ):
                raise _unsupported(
                    f"cohort shapes vary across ranks of group {gname!r} "
                    f"(rank {int(d)})"
                )
        _align_group(amap, n, grp, [progs[int(d)] for d in devs])
        groups.append(grp)

    # ---- worklist: order every group's phase instances -----------------
    fams: Dict[tuple, _Fam] = {}
    own: Dict[int, int] = {}
    recs: List[_Rec] = []
    open_recs: List[_Rec] = []
    instrs: List[tuple] = []
    refs: List[int] = []
    cursors = [_Cursor(grp) for grp in groups]
    cache: Dict[tuple, np.ndarray] = {}
    arrc: Dict[bytes, np.ndarray] = {}
    blocked: List[Optional[tuple]] = [None] * len(groups)

    def share(a):
        b = arrc.get(a.tobytes())
        if b is None:
            arrc[a.tobytes()] = a
            return a
        return b

    ar = np.arange(n, dtype=np.int64)
    while True:
        progress = False
        alldone = True
        for gi, (grp, cur) in enumerate(zip(groups, cursors)):
            while not cur.done:
                ph, k = cur.phase()
                if not ph.is_wait:
                    fam = uid = None
                    if ph.emit is not None:
                        fkey = (gi, cur.si, cur.bi)
                        fam = fams.get(fkey)
                        if fam is None:
                            fam = _build_fam(
                                ports, fab, grp, gi, len(fams), ph.emit, n
                            )
                            _register_ports(own, fam, grp.name)
                            fams[fkey] = fam
                        uid = len(recs)
                        rec = _Rec(uid, fam, k)
                        recs.append(rec)
                        open_recs.append(rec)
                        refs.append(0)
                    instrs.append(("p", gi, ph.dur, ph.tdelta, fam, uid, k))
                    cur.advance()
                    progress = True
                    continue
                if ph.wait[0] == "allpeers":
                    alpha, beta = ph.wait[1], ph.wait[2]
                    want = alpha + beta * ar
                    hit = None
                    for rec in reversed(open_recs):
                        if rec.fam.addr_vec is not None and rec.live and \
                                np.array_equal(rec.fam.addr_vec, want):
                            hit = rec
                            break
                    if hit is None:
                        blocked[gi] = (ph.name, k, int(grp.devs[0]), None)
                        break
                    hit.live = 0
                    refs[hit.uid] += 1
                    instrs.append(("aw", gi, hit.uid, ph.tdelta))
                else:
                    cols = []
                    fail = None
                    done_pend = []
                    for base, kstep in ph.wait[1]:
                        want_addr = base + kstep * k
                        segs, pend = _match_col(
                            open_recs, want_addr, grp.devs, n, cache
                        )
                        if segs is None:
                            fail = (want_addr, pend)
                            break
                        cols.append([
                            (u, share(i), share(r)) for u, i, r in segs
                        ])
                        done_pend.extend(pend)
                    if fail is not None:
                        addr = int(fail[0][fail[1]])
                        blocked[gi] = (
                            ph.name, k, int(grp.devs[fail[1]]), addr
                        )
                        break
                    for rec, rr in done_pend:
                        rec.consumed[rr] = True
                        rec.live -= len(rr)
                    for col in cols:
                        for u, _i, _r in col:
                            refs[u] += 1
                    instrs.append(("w", gi, cols, ph.tdelta))
                open_recs = [r for r in open_recs if r.live]
                cur.advance()
                progress = True
            if not cur.done:
                alldone = False
        if alldone:
            break
        if not progress:
            for gi, b in enumerate(blocked):
                if b is not None and not cursors[gi].done:
                    name, k, dev, addr = b
                    if addr is None:
                        raise _unsupported(
                            f"all-peers wait phase {name!r} (k={k}) of "
                            f"group {groups[gi].name!r} has no matching "
                            "earlier fan-out emission"
                        )
                    w, s = _decode_flag(amap, n, addr)
                    flag = (
                        f"flag (writer {w}, slot {s})" if w is not None
                        else f"flag 0x{addr:x}"
                    )
                    raise _unsupported(
                        f"wait phase {name!r} (k={k}) of group "
                        f"{groups[gi].name!r}: rank {dev} observes {flag} "
                        "with no earlier emission; cross-rank pipelined "
                        "chains stay on the timeline engine"
                    )
            raise _unsupported(
                "no group can advance (cyclic cross-group dependency)"
            )  # pragma: no cover

    if any(f.kind == "fanout_all" for f in fams.values()) and len(fams) > 1:
        raise _unsupported(
            "all-peers fan-out cannot share link ports with other "
            "emission stages"
        )
    _check_flag_reuse(progs, amap, cfg)
    return _TieredPlan(
        ports, groups, instrs, np.array(refs, np.int64)
    )


# ---------------------------------------------------------------------------
# the solver runtime
# ---------------------------------------------------------------------------


def _chain(b0, rdy, ser):
    """Price one port's resolved touch prefix: the scalar
    ``start = max(ready, busy); busy = start + ser`` sequence, vectorized as
    restart-segment cumsums (``np.cumsum`` accumulates left-to-right, so each
    segment's floats equal the event engine's sequential adds exactly).

    Two regimes, both bit-exact:

    - ready-dominant (the port drains between touches): a restarting
      element's busy is a single add ``rdy + ser``, so the run-continues
      test ``rdy[t+1] > rdy[t] + ser`` is elementwise and the whole run
      vectorizes (the intermediate busies never accumulate).
    - busy-dominant: cumsum a bounded chunk seeded with the
      exactly-carried busy value — crossing a chunk boundary reproduces
      the sequential float adds bit-for-bit, so chunking changes cost
      (quadratic -> amortized linear), never values.  The chunk doubles
      while segments run long and snaps back small on a restart."""
    mlen = rdy.size
    starts = np.empty(mlen)
    # iso[t]: element t+1 restarts given element t restarted
    # (rdy[t+1] > busy_t = rdy[t] + ser, a single exact add)
    iso = np.empty(mlen, bool)
    if mlen > 1:
        np.greater(rdy[1:], rdy[:-1] + ser, out=iso[: mlen - 1])
    iso[mlen - 1] = False
    # nf[t]: first index >= t with iso False (run terminator)
    idx = np.arange(mlen, dtype=np.int64)
    nf = np.where(iso, mlen, idx)
    nf = np.minimum.accumulate(nf[::-1])[::-1]
    i = 0
    b = float(b0)
    chunk = 32
    while i < mlen:
        r0 = rdy[i]
        if r0 > b:
            # maximal restart run: every element's start is its own ready
            t = int(nf[i]) - i + 1
            starts[i: i + t] = rdy[i: i + t]
            b = float(rdy[i + t - 1]) + ser
            i += t
            continue
        rem = mlen - i
        c = chunk if chunk < rem else rem
        ch = np.empty(c + 1)
        ch[0] = b
        ch[1:] = ser
        bs = np.cumsum(ch)
        viol = np.flatnonzero(rdy[i + 1: i + c] > bs[1:c])
        if viol.size:
            t = int(viol[0]) + 1
            chunk = 32
        else:
            t = c
            if chunk < (1 << 20):
                chunk *= 2
        starts[i: i + t] = bs[:t]
        b = float(bs[t])
        i += t
    return starts, b


def run_tiered(cluster, plan: _TieredPlan, breakdown: Dict[str, float]):
    """Solve the compiled tiered plan; mutates cluster state only in the
    final write-back (a mid-solve failure falls back to the timeline engine
    cleanly)."""
    t0 = time.perf_counter()
    cfg = cluster.cfg
    n = cfg.n_devices
    clock = cfg.clock_ghz
    poll = cfg.poll_interval_cycles
    check = cfg.flag_check_cycles
    xgmi_lat = cfg.xgmi_enact_latency_ns
    include_dw = cfg.include_data_writes
    fab = cluster.fabric
    ports = plan.ports
    groups = plan.groups
    ar_n = np.arange(n, dtype=np.int64)

    P = ports.P
    port_busy = np.array(
        [fab._busy_until_ns.get(t, 0.0) for t in ports.tuples]
    )
    port_used = np.zeros(P, bool)
    port_cnt = np.zeros(P, np.int64)
    port_byt = np.zeros(P, np.int64)
    port_qd = np.zeros(P)
    port_bw = ports.cls_bw[ports.port_cls]
    port_lat = ports.cls_lat[ports.port_cls]
    C = len(ports.cls_names)
    cls_msgs = np.zeros(C, np.int64)
    cls_bytes = np.zeros(C, np.int64)
    cls_q = np.zeros(C)
    g_msgs = 0
    g_bytes = 0
    g_q = 0.0
    seq_add = 0
    max_set = 0

    a_fr = np.zeros(n, np.int64)
    a_rb = np.zeros(n, np.int64)
    a_nfr = np.zeros(n, np.int64)
    a_lw = np.zeros(n, np.int64)
    a_wb = np.zeros(n, np.int64)
    a_xo = np.zeros(n, np.int64)
    a_xob = np.zeros(n, np.int64)
    a_xi = np.zeros(n, np.int64)
    a_xib = np.zeros(n, np.int64)
    a_reg = np.zeros(n, np.int64)
    a_marks = np.zeros(n, np.int64)

    T = [np.tile(g.dispatch, (len(g.devs), 1)) for g in groups]
    sc_store: Dict[int, np.ndarray] = {}
    refs = plan.refs.copy()

    def spin(gi, V):
        """The interpreter's unified spin closed form over one group's
        cursor matrix (one wait address per rank)."""
        grp = groups[gi]
        nt = V[:, None] - T[gi]
        nt += poll - 1
        nt //= poll
        np.maximum(nt, 0, out=nt)
        m = nt @ grp.counts
        m += grp.total
        a_fr[grp.devs] += m
        a_rb[grp.devs] += 8 * m
        nt *= poll
        nt += check
        T[gi] += nt

    def tdapply(gi, d):
        if d is None:
            return
        grp = groups[gi]
        tot = grp.total
        devs = grp.devs
        if d[0]:
            a_nfr[devs] += d[0] * tot
        if d[1]:
            a_rb[devs] += d[1] * tot
        if d[2]:
            a_lw[devs] += d[2] * tot
        if d[3]:
            a_wb[devs] += d[3] * tot
        if d[4]:
            a_xo[devs] += d[4] * tot
        if d[5]:
            a_xob[devs] += d[5] * tot

    def price_elem(fam, issue):
        """Leg-by-leg elementwise pricing; valid because no two messages of
        the instance share a port (checked at compile)."""
        nonlocal g_q
        nb = fam.nb
        arr = issue.copy()
        for mi, prt, hops, cls in fam.leg_slots:
            rdy = arr[mi]
            st = np.maximum(rdy, port_busy[prt])
            ser = nb / port_bw[prt]
            fin = st + ser
            port_busy[prt] = fin
            port_used[prt] = True
            q = st - rdy
            port_cnt[prt] += 1
            port_byt[prt] += nb
            port_qd[prt] += q
            g_q += float(q.sum())
            np.add.at(cls_q, cls, q)
            arr[mi] = fin + hops * port_lat[prt]
        return arr

    def price_ordered(fam, issue, E_msg, legs):
        """Port-wavefront pricing in the event engine's global message
        order; each sweep extends every port's priced prefix with the
        touches whose upstream legs have resolved arrivals."""
        nonlocal g_q
        nb = fam.nb
        m = len(issue)
        msg = legs["msg"]
        L = len(msg)
        if np.all(E_msg == E_msg[0]):
            tmsg = msg
            tprt = legs["port"]
            thops = legs["hops"]
        else:
            morder = np.argsort(E_msg, kind="stable")
            inv = np.empty(m, np.int64)
            inv[morder] = np.arange(m, dtype=np.int64)
            tord = np.lexsort((np.arange(L), inv[msg]))
            tmsg = msg[tord]
            tprt = legs["port"][tord]
            thops = legs["hops"][tord]
        first = np.ones(L, bool)
        first[1:] = tmsg[1:] != tmsg[:-1]
        ready = np.full(L, np.nan)
        ready[first] = issue[tmsg[first]]
        nxt = np.full(L, -1, np.int32)
        cont = np.flatnonzero(~first[1:])
        nxt[cont] = cont + 1
        last = np.ones(L, bool)
        last[:-1] = first[1:]
        tsort = np.argsort(tprt, kind="stable")
        # tsort groups legs by ascending port id; per-port extents come from
        # a bincount (no gather of the sorted keys, no diff pass)
        pcnt = np.bincount(tprt, minlength=ports.P)
        plist = np.flatnonzero(pcnt)
        pend = np.cumsum(pcnt[plist])
        pstart = pend - pcnt[plist]
        cursor = np.zeros(len(plist), np.int64)
        arr_out = np.empty(m)
        done = 0
        while done < L:
            moved = False
            for pi in range(len(plist)):
                s = int(pstart[pi] + cursor[pi])
                e = int(pend[pi])
                if s >= e:
                    continue
                tl = tsort[s:e]
                rdy = ready[tl]
                isn = np.isnan(rdy)
                cnt = int(isn.argmax())
                if cnt == 0:
                    if isn[0]:
                        continue
                    cnt = len(tl)
                tl = tl[:cnt]
                rdy = rdy[:cnt]
                p = int(plist[pi])
                ser = nb / port_bw[p]
                sts, bfin = _chain(port_busy[p], rdy, ser)
                port_busy[p] = bfin
                port_used[p] = True
                fin = sts + ser
                q = sts - rdy
                port_cnt[p] += cnt
                port_byt[p] += cnt * nb
                port_qd[p] = float(
                    np.cumsum(np.concatenate(([port_qd[p]], q)))[-1]
                )
                qs = float(q.sum())
                g_q += qs
                cls_q[ports.port_cls[p]] += qs
                a = fin + thops[tl] * port_lat[p]
                nx = nxt[tl]
                has = nx >= 0
                ready[nx[has]] = a[has]
                lm = last[tl]
                arr_out[tmsg[tl[lm]]] = a[lm]
                cursor[pi] += cnt
                done += cnt
                moved = True
            if not moved:  # pragma: no cover - leg classes form a DAG
                raise _unsupported(
                    "link-port pricing stalled (non-DAG port order)"
                )
        return arr_out

    def account(fam, nmsg_per_rank, devs):
        nonlocal seq_add, g_msgs, g_bytes
        nonlocal a_xi, a_xib, a_reg, a_marks
        dw = fam.dw if include_dw and fam.dw > 0 else 0
        regs = 1 + dw
        a_xo[devs] += nmsg_per_rank
        a_xob[devs] += nmsg_per_rank * fam.size
        if fam.kind == "fanout_all":
            a_xi += nmsg_per_rank * regs
            a_xib += nmsg_per_rank * (fam.size + 8 * dw)
            a_reg += nmsg_per_rank * regs
            if dw:
                a_marks += nmsg_per_rank * dw
        elif fam.dst_unique:
            a_xi[fam.dst] += regs
            a_xib[fam.dst] += fam.size + 8 * dw
            a_reg[fam.dst] += regs
            if dw:
                a_marks[fam.dst] += dw
        else:
            np.add.at(a_xi, fam.dst, regs)
            np.add.at(a_xib, fam.dst, fam.size + 8 * dw)
            np.add.at(a_reg, fam.dst, regs)
            if dw:
                np.add.at(a_marks, fam.dst, dw)
        seq_add += fam.m * regs
        g_msgs += fam.m
        g_bytes += fam.m * fam.nb

    def emit_family(fam, uid):
        nonlocal max_set, cls_msgs, cls_bytes
        gi = fam.gi
        grp = groups[gi]
        E = T[gi].max(axis=1)
        issue_r = E / clock
        minns_r = (E + 1) / clock
        issue = issue_r[fam.src_row]
        if fam.pricing == "elem":
            arr = price_elem(fam, issue)
        else:
            arr = price_ordered(fam, issue, E[fam.src_row], fam.legs)
        wake = arr + xgmi_lat
        np.maximum(wake, minns_r[fam.src_row], out=wake)
        sc = np.rint(wake * clock).astype(np.int64)
        ms = int(sc.max())
        if ms > max_set:
            max_set = ms
        if refs[uid] > 0:
            sc_store[uid] = sc
        account(fam, fam.cnt, grp.devs)
        cls_msgs += fam.cls_legs
        cls_bytes += fam.cls_legs * fam.nb

    def emit_fanout(fam, uid):
        nonlocal max_set, cls_msgs, cls_bytes
        gi = fam.gi
        E = T[gi].max(axis=1)
        src = np.repeat(np.arange(n, dtype=np.int32), n - 1)
        dstm = np.tile(np.arange(n - 1, dtype=np.int32), (n, 1))
        dstm += dstm >= ar_n[:, None]
        dst = dstm.ravel()
        legs = _legs_csr(ports, src, dst)
        _spot_check(ports, fab, src, dst, legs)
        issue = (E / clock)[src]
        arr = price_ordered(fam, issue, E[src], legs)
        minns = ((E + 1) / clock)[src]
        wake = arr + xgmi_lat
        np.maximum(wake, minns, out=wake)
        sc = np.rint(wake * clock).astype(np.int64)
        ms = int(sc.max())
        if ms > max_set:
            max_set = ms
        if refs[uid] > 0:
            M = np.zeros((n, n), np.int64)
            M[src, dst] = sc
            sc_store[uid] = M
        account(fam, n - 1, ar_n)
        cls_msgs += np.bincount(legs["cls"], minlength=C)
        cls_bytes += np.bincount(legs["cls"], minlength=C) * fam.nb

    for ins in plan.instrs:
        tag = ins[0]
        if tag == "p":
            _, gi, dur, td, fam, uid, _k = ins
            if dur:
                T[gi] += dur
            if fam is not None:
                if fam.kind == "fanout_all":
                    emit_fanout(fam, uid)
                else:
                    emit_family(fam, uid)
            tdapply(gi, td)
        elif tag == "w":
            _, gi, cols, td = ins
            g = len(groups[gi].devs)
            for col in cols:
                V = np.empty(g, np.int64)
                for uid, idx, rows in col:
                    V[idx] = sc_store[uid][rows]
                    refs[uid] -= 1
                    if refs[uid] == 0:
                        del sc_store[uid]
                spin(gi, V)
            tdapply(gi, td)
        else:  # "aw"
            _, gi, uid, td = ins
            M = sc_store[uid]
            for j in range(n - 1):
                gidx = np.where(ar_n > j, j, j + 1)
                spin(gi, M[gidx, ar_n])
            refs[uid] -= 1
            if refs[uid] == 0:
                del sc_store[uid]
            tdapply(gi, td)

    solve_done = time.perf_counter()

    # ---- write-back -----------------------------------------------------
    kend = np.zeros(n, np.int64)
    for gi, grp in enumerate(groups):
        kend[grp.devs] = T[gi].max(axis=1)
    sim_cycles = max(int(kend.max()), max_set)
    for r, node in enumerate(cluster.nodes):
        t = node.memory.traffic
        t.flag_reads += int(a_fr[r])
        t.nonflag_reads += int(a_nfr[r])
        t.read_bytes += int(a_rb[r])
        t.local_writes += int(a_lw[r])
        t.write_bytes += int(a_wb[r])
        t.xgmi_writes_out += int(a_xo[r])
        t.xgmi_bytes_out += int(a_xob[r])
        t.xgmi_writes_in += int(a_xi[r])
        t.xgmi_bytes_in += int(a_xib[r])
        tgt = node.target
        tgt.done_count = tgt.n_wgs
        tgt.kernel_end_cycle = int(kend[r])
        ws = node.wtt.stats
        ws.registered += int(a_reg[r])
        ws.enacted += int(a_reg[r])
        if a_marks[r]:
            cluster._data_marks[r] = (
                cluster._data_marks.get(r, 0) + int(a_marks[r])
            )
    cluster._seq += seq_add
    st = fab.stats
    st["messages"] += g_msgs
    st["bytes"] += g_bytes
    st["queued_ns"] += g_q
    for ci, cname in enumerate(ports.cls_names):
        if cls_msgs[ci]:
            st[f"{cname}_messages"] = (
                st.get(f"{cname}_messages", 0) + int(cls_msgs[ci])
            )
            st[f"{cname}_bytes"] = (
                st.get(f"{cname}_bytes", 0) + int(cls_bytes[ci])
            )
            st[f"{cname}_queued_ns"] = (
                st.get(f"{cname}_queued_ns", 0.0) + float(cls_q[ci])
            )
    for p in np.flatnonzero(port_used):
        p = int(p)
        port = ports.tuples[p]
        fab._busy_until_ns[port] = float(port_busy[p])
        ps = fab.port_stats.get(port)
        if ps is None:
            ps = fab.port_stats[port] = [0, 0, 0.0]
        ps[0] += int(port_cnt[p])
        ps[1] += int(port_byt[p])
        ps[2] += float(port_qd[p])
    run_wall = time.perf_counter() - t0
    breakdown.update(
        solve_s=solve_done - t0,
        writeback_s=run_wall - (solve_done - t0),
    )
    return EngineResult(
        sim_cycles=sim_cycles,
        wall_time_s=run_wall + breakdown.get("compile_s", 0.0),
        head_polls=0,
        breakdown=breakdown,
    )
