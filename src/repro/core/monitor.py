"""SyncMon-inspired Monitor Log (paper §5, Fig. 7).

Implements the ``monitor()`` / ``mwait()`` pseudo-op semantics as a
simulator-side structure, exactly as the case study does: entries are keyed by
coherence-*line* address and hold a compare value, a monitor mask (derived from
the monitored byte range, accommodating padded flags), and the list of waiting
wavefront/workgroup ids.  Every write that completes at the directory is
compared (masked) against matching entries; on a hit all waiters are woken.

Two wake-up granularities are supported, as discussed in the paper:

* ``mesa``  — wake on *any* masked change of the line; the waiter must re-check
  its predicate (mwait sits inside the while loop).  This is the default and
  matches Mesa-style condition semantics.
* ``hoare`` — wake only when the masked comparison equals the registered
  wake value; the waiter may assume the predicate holds.

On TPU, the native analogue of SyncMon is the DMA-completion semaphore wait
(a stalled core consumes no memory bandwidth while waiting); the Monitor Log
therefore doubles as our model of semaphore-gated remote-DMA completion when
Eidola replays collective traffic captured from compiled JAX programs.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Literal, Optional, Set, Tuple

from .memory import LINE_BYTES, DirectoryMemory

__all__ = ["MonitorEntry", "MonitorLog"]


@dataclass
class MonitorEntry:
    """One row of the Monitor Log (paper Fig. 7)."""

    line_addr: int
    compare_value: int  # full-line-width integer (little-endian byte order)
    monitor_mask: int   # full-line-width mask covering the monitored bytes
    waiting_wfs: Set[int] = field(default_factory=set)

    def matches(self, line_value: int, semantics: str) -> bool:
        if semantics == "hoare":
            return (line_value & self.monitor_mask) == (
                self.compare_value & self.monitor_mask
            )
        # mesa: any write that touches the monitored bytes is a wake event;
        # the match test happens in the waiter's re-check.
        return True


class MonitorLog:
    """Simulator-side Monitor Log with masked compare-on-write wake."""

    def __init__(
        self,
        memory: DirectoryMemory,
        *,
        semantics: Literal["mesa", "hoare"] = "mesa",
        wake_latency_cycles: int = 32,
    ):
        self.memory = memory
        self.semantics = semantics
        self.wake_latency_cycles = int(wake_latency_cycles)
        self._entries: Dict[int, List[MonitorEntry]] = {}
        # wf id -> cycle at which it becomes schedulable again
        self._pending_wakes: Dict[int, int] = {}
        self.stats = {
            "monitors_armed": 0,
            "mwaits": 0,
            "wakes": 0,
            "immediate_mwait_returns": 0,
            "writes_checked": 0,
        }
        memory.add_write_observer(self._on_directory_write)

    # -- pseudo-op: monitor(addr, numBytes, wakeValue) -------------------------

    def monitor(self, addr: int, num_bytes: int, wake_value: int) -> MonitorEntry:
        """Arm a monitor on ``num_bytes`` at ``addr`` with wake predicate.

        The mask covers [addr, addr+num_bytes) within the 64-byte line; the
        compare value is positioned at the same byte offsets.  Flexible sizes
        accommodate padded flags (paper: "size flexibility accommodates padded
        flags used to prevent false sharing").
        """
        if num_bytes <= 0 or num_bytes > LINE_BYTES:
            raise ValueError("monitored range must fit within one line")
        line = addr & ~(LINE_BYTES - 1)
        off = addr - line
        if off + num_bytes > LINE_BYTES:
            raise ValueError("monitored range may not straddle a line")
        mask = ((1 << (8 * num_bytes)) - 1) << (8 * off)
        cval = (wake_value & ((1 << (8 * num_bytes)) - 1)) << (8 * off)
        entry = MonitorEntry(line_addr=line, compare_value=cval, monitor_mask=mask)
        self._entries.setdefault(line, []).append(entry)
        self.stats["monitors_armed"] += 1
        return entry

    # -- pseudo-op: mwait(addr) -------------------------------------------------

    def mwait(self, entry: MonitorEntry, wf_id: int, now_cycle: int) -> bool:
        """Suspend ``wf_id`` until the entry's condition fires.

        Returns True if the condition ALREADY holds at call time (the classic
        monitor/mwait race window): the wavefront is not descheduled and the
        caller proceeds immediately.  Otherwise the wf is recorded as waiting
        and will be marked schedulable ``wake_latency_cycles`` after a matching
        directory write.
        """
        self.stats["mwaits"] += 1
        line_value = self._line_value(entry.line_addr)
        if (line_value & entry.monitor_mask) == (
            entry.compare_value & entry.monitor_mask
        ):
            self.stats["immediate_mwait_returns"] += 1
            return True
        entry.waiting_wfs.add(wf_id)
        return False

    # -- directory write hook -----------------------------------------------------

    def _on_directory_write(self, addr: int, data: int, size: int, cycle: int) -> None:
        line = addr & ~(LINE_BYTES - 1)
        entries = self._entries.get(line)
        if not entries:
            return
        self.stats["writes_checked"] += 1
        line_value = self._line_value(line)
        fired: List[MonitorEntry] = []
        for e in entries:
            if not e.waiting_wfs:
                continue
            if self.semantics == "hoare":
                hit = (line_value & e.monitor_mask) == (
                    e.compare_value & e.monitor_mask
                )
            else:
                # mesa: wake if the write overlapped the monitored bytes
                w_mask = ((1 << (8 * size)) - 1) << (8 * (addr - line))
                hit = bool(w_mask & e.monitor_mask)
            if hit:
                fired.append(e)
        for e in fired:
            for wf in e.waiting_wfs:
                wake_at = cycle + self.wake_latency_cycles
                prev = self._pending_wakes.get(wf)
                self._pending_wakes[wf] = min(prev, wake_at) if prev else wake_at
                self.stats["wakes"] += 1
            e.waiting_wfs.clear()

    # -- scheduler interface --------------------------------------------------------

    def pop_wakes_until(self, cycle: int) -> List[Tuple[int, int]]:
        """All (wf_id, wake_cycle) that become schedulable by ``cycle``."""
        due = [(wf, c) for wf, c in self._pending_wakes.items() if c <= cycle]
        for wf, _ in due:
            del self._pending_wakes[wf]
        return sorted(due, key=lambda t: (t[1], t[0]))

    def next_wake_cycle(self) -> Optional[int]:
        if not self._pending_wakes:
            return None
        return min(self._pending_wakes.values())

    def waiting_count(self) -> int:
        return sum(
            len(e.waiting_wfs) for lst in self._entries.values() for e in lst
        )

    # -- helpers -----------------------------------------------------------------------

    def _line_value(self, line_addr: int) -> int:
        return self.memory.peek(line_addr, LINE_BYTES)
