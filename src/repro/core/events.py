"""Event and trace schema for the Eidola simulator.

The paper's central data object is the *registered write*: a timestamped,
one-sided peer-to-peer write ``(addr, data, size, wakeupTime)`` registered by a
functional-mode setup kernel (``register_write`` pseudo-op, Fig. 5) and enacted
by the simulator when detailed time reaches ``wakeupTime``.  We reproduce that
schema exactly, plus a ``src`` device id (the eidolon that issues the write) and
a ``seq`` registration counter used only as a deterministic tie-break.

A :class:`TraceBundle` is the unit of profile ingestion: the set of registered
writes for one simulated kernel launch, together with enough metadata to
reconstruct the communication pattern.  Bundles can come from

* real profiles (JSON, one record per write — the paper's "annotated timing
  profiles from real applications"),
* synthetic generators (``repro.core.egpu``), or
* compiled-HLO capture of a JAX program's collective schedule
  (``repro.core.hlo_capture``), which is this framework's bridge between the
  production training stack and the simulator.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "RegisteredWrite",
    "effective_writes",
    "TraceBundle",
    "Segment",
    "PHASES",
    "PHASE_COLORS",
    "PHASE_GLYPHS",
    "register_phase",
]

# ---------------------------------------------------------------------------
# Registered writes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RegisteredWrite:
    """One emulated peer-to-peer (xGMI-analogue) write.

    Attributes mirror the ``register_write`` pseudo-op of the paper:

    addr        destination byte address in the target device's memory space.
    data        value to be written (interpreted at ``size`` bytes).
    size        write width in bytes, 1..8 per the paper.
    wakeup_ns   offset after kernel launch, in nanoseconds, at which the write
                is issued.  Converted to cycles by the engine using the device
                clock from the simulator config.
    src         issuing device id (eidolon).  ``-1`` means "unattributed".
    seq         registration order; used only to keep pops deterministic when
                two writes share a timestamp.  The paper explicitly allows
                registration in arbitrary order ("sequential calls ... need not
                correspond to the chronological order of their execution").
    """

    wakeup_ns: float
    addr: int
    data: int
    size: int = 4
    src: int = -1
    seq: int = 0

    def __post_init__(self) -> None:
        if not (1 <= self.size <= 8):
            raise ValueError(f"write size must be in [1, 8] bytes, got {self.size}")
        if self.wakeup_ns < 0:
            raise ValueError(f"wakeup_ns must be >= 0, got {self.wakeup_ns}")
        if self.addr < 0:
            raise ValueError("addr must be non-negative")

    def sort_key(self) -> Tuple[float, int]:
        return (self.wakeup_ns, self.seq)


def effective_writes(
    writes: Sequence[RegisteredWrite],
    *,
    latency_ns: float = 0.0,
    perturb=None,
) -> List[RegisteredWrite]:
    """Trace writes as the engine will see them: enact latency + jitter.

    The shared no-perturb fast path: when ``perturb is None`` and
    ``latency_ns == 0`` the input writes are already effective and are
    returned as-is (one list copy, no dataclass churn) — previously both the
    vectorized engine and the single-device builder materialized a full
    :class:`RegisteredWrite` copy per trace write unconditionally.
    """
    if perturb is None and latency_ns == 0:
        return list(writes)
    out: List[RegisteredWrite] = []
    for w in writes:
        eff = (
            dataclasses.replace(w, wakeup_ns=w.wakeup_ns + latency_ns)
            if latency_ns
            else w
        )
        if perturb is not None:
            eff = perturb.jitter_write(eff)
        out.append(eff)
    return out


# ---------------------------------------------------------------------------
# Trace bundles
# ---------------------------------------------------------------------------


@dataclass
class TraceBundle:
    """A set of registered writes for one kernel launch, plus metadata."""

    writes: List[RegisteredWrite] = field(default_factory=list)
    meta: Dict[str, object] = field(default_factory=dict)

    # -- construction -------------------------------------------------------

    def add(
        self,
        *,
        wakeup_ns: float,
        addr: int,
        data: int,
        size: int = 4,
        src: int = -1,
    ) -> RegisteredWrite:
        w = RegisteredWrite(
            wakeup_ns=wakeup_ns,
            addr=addr,
            data=data,
            size=size,
            src=src,
            seq=len(self.writes),
        )
        self.writes.append(w)
        return w

    def extend(self, writes: Iterable[RegisteredWrite]) -> None:
        for w in writes:
            self.add(
                wakeup_ns=w.wakeup_ns, addr=w.addr, data=w.data, size=w.size, src=w.src
            )

    # -- views --------------------------------------------------------------

    def __len__(self) -> int:
        return len(self.writes)

    def __iter__(self) -> Iterator[RegisteredWrite]:
        return iter(self.writes)

    def sorted(self) -> List[RegisteredWrite]:
        return sorted(self.writes, key=RegisteredWrite.sort_key)

    def by_src(self) -> Dict[int, List[RegisteredWrite]]:
        out: Dict[int, List[RegisteredWrite]] = {}
        for w in self.writes:
            out.setdefault(w.src, []).append(w)
        return out

    def span_ns(self) -> float:
        return max((w.wakeup_ns for w in self.writes), default=0.0)

    def total_bytes(self) -> int:
        return sum(w.size for w in self.writes)

    # -- (de)serialization ---------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(
            {
                "meta": self.meta,
                "writes": [dataclasses.asdict(w) for w in self.writes],
            }
        )

    @classmethod
    def from_json(cls, text: str) -> "TraceBundle":
        obj = json.loads(text)
        bundle = cls(meta=dict(obj.get("meta", {})))
        for rec in obj.get("writes", []):
            bundle.writes.append(
                RegisteredWrite(
                    wakeup_ns=float(rec["wakeup_ns"]),
                    addr=int(rec["addr"]),
                    data=int(rec["data"]),
                    size=int(rec.get("size", 4)),
                    src=int(rec.get("src", -1)),
                    seq=int(rec.get("seq", 0)),
                )
            )
        return bundle

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "TraceBundle":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# Timeline segments (Figs. 1/2 reproduction)
# ---------------------------------------------------------------------------

# Phase names of the fused GEMV+AllReduce pseudocode (paper Fig. 3) — the
# *canonical* gemv vocabulary only, frozen for the paper-figure legends.  The
# full set of valid Segment phases is ``PHASE_COLORS.keys()``, which scenarios
# extend at import time via register_phase(); consumers bucketing arbitrary
# scenarios' segments must iterate PHASE_COLORS, not this tuple.  The colors
# mirror the paper's color coordination: green = tile compute, brown = tile
# completion marker, blue = xGMI flag write, red = spin-wait, and we give the
# final reduce/broadcast its own shades.
PHASES: Tuple[str, ...] = (
    "remote_tiles",  # lines 2-5: compute partial tiles needed by remote GPUs
    "flag_write",    # line 7:    xGMI write to flags[my_gpu] on all peers
    "local_tiles",   # lines 9-12: compute partial tiles reduced locally
    "wait_flags",    # lines 14-15: spin on peer flags (red in Figs. 1/2)
    "reduce",        # line 17
    "broadcast",     # line 18
    "descheduled",   # SyncMon: wavefront yielded, not occupying the CU
)

PHASE_COLORS: Dict[str, str] = {
    "remote_tiles": "green",
    "flag_write": "blue",
    "local_tiles": "green",
    "wait_flags": "red",
    "reduce": "brown",
    "broadcast": "brown",
    "descheduled": "grey",
}

PHASE_GLYPHS: Dict[str, str] = {
    "remote_tiles": "g",
    "flag_write": "B",
    "local_tiles": "G",
    "wait_flags": "r",
    "reduce": "b",
    "broadcast": "^",
    "descheduled": ".",
}


def register_phase(name: str, *, color: str = "grey", glyph: str = "?") -> str:
    """Register a phase name so :class:`Segment` accepts it.

    The canonical fused-kernel phases above are pre-registered; scenarios
    (``repro.core.scenarios``) register their own phase vocabularies at import
    time.  Re-registering an existing name is a no-op that keeps the original
    color/glyph (the gemv palette mirrors the paper and must stay stable).
    """
    if name not in PHASE_COLORS:
        PHASE_COLORS[name] = color
        PHASE_GLYPHS[name] = glyph
    return name


@dataclass(frozen=True)
class Segment:
    """One phase interval on one workgroup's timeline row.

    ``device`` identifies which simulated device the workgroup ran on; it is 0
    for single-detailed-device (open-loop) runs and meaningful in closed-loop
    :class:`repro.core.cluster.Cluster` simulations.
    """

    wg: int
    phase: str
    start_ns: float
    end_ns: float
    device: int = 0

    def __post_init__(self) -> None:
        if self.phase not in PHASE_COLORS:
            raise ValueError(
                f"unknown phase {self.phase!r} (register it with register_phase)"
            )
        if self.end_ns < self.start_ns:
            raise ValueError("segment ends before it starts")

    @property
    def dur_ns(self) -> float:
        return self.end_ns - self.start_ns
