"""Trip-count-aware HLO cost analyzer.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE regardless of
trip count, so any scanned-layers model under-reports FLOPs, bytes and
collective traffic by ~n_layers.  This analyzer parses post-optimization HLO
text, builds the computation call graph (fusion ``calls=``, while ``body=``/
``condition=``, reduce ``to_apply=``), infers while trip counts from the
condition's loop-bound constants, and multiplies every op's cost by the
product of trip counts along its call chain.

Costs:
  flops            2 * prod(result) * prod(contracting dims) per dot;
                   elementwise/reduce ops contribute prod(result).
  bytes            operand + result buffer sizes per op, fusion interiors
                   excluded (their traffic is the fusion op's operands and
                   results at the call site) — an HBM-traffic proxy.
  collective bytes per-device operand size per cross-device collective.

Validated against ``cost_analysis()`` on unscanned modules (tests) and used
as the primary source for §Roofline.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HloModule", "analyze_hlo", "OpCost"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_OP_LINE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_SHAPE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_CALL_ATTR = re.compile(r"\b(calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_CONSTANT_VAL = re.compile(r"constant\((\-?\d+)\)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_IOTA_RG = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[")
_BRACE_RG = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
}
_POINTWISE = {
    "add", "multiply", "subtract", "divide", "maximum", "minimum",
    "exponential", "tanh", "rsqrt", "power", "select", "compare", "and",
    "or", "negate", "abs", "log", "sqrt", "floor", "convert", "reduce",
    "exponential-minus-one", "logistic",
}
_NO_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "reshape", "broadcast", "transpose", "copy",
    # control-flow boundaries: loop state lives in place; the body ops are
    # already charged per iteration — charging the while's operand tuple per
    # entry double-counts ~65% on scan-heavy models (measured, gemma3-1b)
    "while", "conditional", "call",
}
# layout/shape ops are free on TPU (fused or relaid); for fusion-island
# tracking they alias their first operand
_TRANSPARENT = {
    "get-tuple-element", "bitcast", "reshape", "broadcast", "transpose",
    "copy", "tuple",
}


def _type_bytes_elems(type_str: str) -> Tuple[int, int]:
    tb = te = 0
    for m in _SHAPE.finditer(type_str):
        nb = _DTYPE_BYTES.get(m.group(1))
        if nb is None:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d.strip():
                n *= int(d)
        tb += n * nb
        te += n
    return tb, te


def _first_dims(type_str: str) -> List[int]:
    m = _SHAPE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


@dataclass
class OpCost:
    name: str
    opcode: str
    result_bytes: int
    result_elems: int
    flops: float = 0.0
    operand_bytes: int = 0
    collective_kind: Optional[str] = None
    collective_bytes: int = 0
    group_size: int = 1
    operands: Tuple[str, ...] = ()
    hbm_result: bool = True  # False: pointwise output consumed only pointwise


@dataclass
class _Computation:
    name: str
    ops: List[OpCost] = field(default_factory=list)
    callees: List[Tuple[str, str]] = field(default_factory=list)
    bytes_of: Dict[str, int] = field(default_factory=dict)
    dims_of: Dict[str, List[int]] = field(default_factory=dict)
    producer_of: Dict[str, str] = field(default_factory=dict)
    alias_of: Dict[str, str] = field(default_factory=dict)
    constants: List[int] = field(default_factory=list)

    def base(self, name: str) -> str:
        seen = 0
        while name in self.alias_of and seen < 64:
            name = self.alias_of[name]
            seen += 1
        return name

    def base_producer(self, name: str) -> str:
        return self.producer_of.get(self.base(name), "")


class HloModule:
    def __init__(self, comps: Dict[str, _Computation], entry: Optional[str]):
        self.comps = comps
        self.entry = entry
        self.fusion_bodies = {
            callee
            for comp in comps.values()
            for kind, callee in comp.callees
            if kind in ("calls", "to_apply")
        }
        self._mult = self._compute_multipliers()
        # fusion islands: a pointwise result stays in registers unless a
        # non-pointwise, non-transparent op (or the root) consumes it —
        # consumption is resolved through transparent aliases
        for comp in comps.values():
            escaping: set = set()
            consumed: set = set()
            for op in comp.ops:
                for o in op.operands:
                    b = comp.base(o)
                    consumed.add(b)
                    if op.opcode not in _POINTWISE and \
                            op.opcode not in _TRANSPARENT:
                        escaping.add(b)
            for op in comp.ops:
                if op.opcode in _POINTWISE and op.name in consumed and \
                        op.name not in escaping:
                    op.hbm_result = False

    # -- call-graph multipliers ------------------------------------------------

    def _trip_count(self, cond_name: str) -> int:
        cond = self.comps.get(cond_name)
        if cond is None or not cond.constants:
            return 1
        bounds = [c for c in cond.constants if 0 < c < 1_000_000]
        return max(bounds) if bounds else 1

    def _cond_for(self, caller: _Computation, body: str) -> str:
        conds = [n for k, n in caller.callees if k == "condition"]
        bodies = [n for k, n in caller.callees if k == "body"]
        if body in bodies:
            i = bodies.index(body)
            if i < len(conds):
                return conds[i]
        return conds[0] if conds else ""

    def _compute_multipliers(self) -> Dict[str, float]:
        if self.entry is None:
            return {c: 1.0 for c in self.comps}
        mult: Dict[str, float] = {c: 0.0 for c in self.comps}
        mult[self.entry] = 1.0
        for _ in range(len(self.comps) + 2):
            changed = False
            for cname, comp in self.comps.items():
                m = mult.get(cname, 0.0)
                if m == 0.0:
                    continue
                for kind, callee in comp.callees:
                    if callee not in mult:
                        continue
                    factor = m
                    if kind == "body":
                        factor = m * self._trip_count(self._cond_for(comp, callee))
                    if factor > mult[callee]:
                        mult[callee] = factor
                        changed = True
            if not changed:
                break
        return {c: (m if m > 0 else 1.0) for c, m in mult.items()}

    def multiplier(self, comp: str) -> float:
        return self._mult.get(comp, 1.0)

    # -- aggregates ---------------------------------------------------------------

    def total_flops(self) -> float:
        return sum(
            op.flops * self._mult[c]
            for c, comp in self.comps.items()
            for op in comp.ops
        )

    def dot_flops(self) -> float:
        return sum(
            op.flops * self._mult[c]
            for c, comp in self.comps.items()
            for op in comp.ops
            if op.opcode in ("dot", "ragged-dot", "convolution")
        )

    def total_bytes(self) -> float:
        return sum(
            ((op.result_bytes if op.hbm_result else 0) + op.operand_bytes)
            * self._mult[c]
            for c, comp in self.comps.items()
            if c not in self.fusion_bodies
            for op in comp.ops
            if op.opcode not in _NO_BYTES
        )

    def collective_bytes(self) -> float:
        return sum(
            op.collective_bytes * self._mult[c]
            for c, comp in self.comps.items()
            for op in comp.ops
            if op.collective_kind and op.group_size != 1
        )

    def collectives_by_kind(self) -> Dict[str, Tuple[float, float]]:
        out: Dict[str, Tuple[float, float]] = {}
        for c, comp in self.comps.items():
            for op in comp.ops:
                if not op.collective_kind or op.group_size == 1:
                    continue
                cnt, byt = out.get(op.collective_kind, (0.0, 0.0))
                out[op.collective_kind] = (
                    cnt + self._mult[c],
                    byt + op.collective_bytes * self._mult[c],
                )
        return out

    def max_while_trip(self) -> int:
        trips = [1]
        for comp in self.comps.values():
            for k, callee in comp.callees:
                if k == "body":
                    trips.append(self._trip_count(self._cond_for(comp, callee)))
        return max(trips)

    def top_collectives(self, n: int = 10):
        """Largest collective contributors: (total_bytes, mult, op)."""
        rows = []
        for c, comp in self.comps.items():
            for op in comp.ops:
                if op.collective_kind and op.group_size != 1:
                    rows.append(
                        (op.collective_bytes * self._mult[c], self._mult[c], op)
                    )
        return sorted(rows, key=lambda r: -r[0])[:n]

    def top_flops(self, n: int = 10):
        rows = []
        for c, comp in self.comps.items():
            for op in comp.ops:
                if op.flops > 0:
                    rows.append((op.flops * self._mult[c], self._mult[c], op))
        return sorted(rows, key=lambda r: -r[0])[:n]


def analyze_hlo(text: str) -> HloModule:
    comps: Dict[str, _Computation] = {}
    entry: Optional[str] = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        if "=" not in stripped.split("(")[0]:
            hm = _COMP_HEADER.match(stripped)
            if hm and stripped.endswith("{"):
                cur = _Computation(name=hm.group(2))
                comps[cur.name] = cur
                if hm.group(1):
                    entry = cur.name
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_LINE.match(line)
        if not om:
            continue
        name, type_str, opcode, rest = om.groups()
        rbytes, relems = _type_bytes_elems(type_str)
        cur.bytes_of[name] = rbytes
        cur.dims_of[name] = _first_dims(type_str)
        op = OpCost(
            name=name, opcode=opcode, result_bytes=rbytes, result_elems=relems
        )
        for cm in _CALL_ATTR.finditer(rest):
            cur.callees.append((cm.group(1), cm.group(2)))
        bm = _BRANCHES.search(rest)
        if bm:
            for b in bm.group(1).split(","):
                b = b.strip().lstrip("%")
                if b:
                    cur.callees.append(("branch", b))
        if opcode == "constant":
            km = _CONSTANT_VAL.search(stripped)
            if km:
                try:
                    cur.constants.append(int(km.group(1)))
                except ValueError:
                    pass
        arglist = rest.split(")", 1)[0]
        operand_names = _OPERAND.findall(arglist)
        op.operand_bytes = sum(cur.bytes_of.get(o, 0) for o in operand_names)
        # fusion-island HBM model: on TPU, Mosaic/XLA fuses pointwise chains,
        # so a pointwise op consuming another pointwise op's output reads it
        # from registers, not HBM.  The CPU backend fuses far less, so without
        # this the byte proxy overcounts recurrent scan bodies ~10x.
        if opcode in _TRANSPARENT and operand_names:
            cur.alias_of[name] = operand_names[0]
        # slice-driven reads touch only what they emit, not the whole array
        if opcode in ("dynamic-slice", "slice", "gather"):
            op.operand_bytes = 0
        elif opcode in ("dynamic-update-slice", "scatter"):
            # in-place on TPU: read+write of the update region only
            upd = (
                cur.bytes_of.get(operand_names[1], 0)
                if len(operand_names) > 1 else 0
            )
            op.operand_bytes = 2 * upd
            op.hbm_result = False
        elif opcode == "fusion":
            # kLoop fusions are elementwise-rooted: interior slices mean the
            # operands are only partially read; bound traffic by fanin x out.
            # kInput/kOutput (reduce-rooted) fusions stream operands fully.
            if "kind=kLoop" in rest:
                op.operand_bytes = min(op.operand_bytes, 4 * op.result_bytes)
        if opcode in _POINTWISE:
            fused_in = sum(
                cur.bytes_of.get(o, 0)
                for o in operand_names
                if cur.base_producer(o) in _POINTWISE
            )
            op.operand_bytes -= fused_in
        op.operands = tuple(operand_names)
        cur.producer_of[name] = opcode
        if opcode in ("dot", "ragged-dot"):
            contract = 1
            cm2 = _CONTRACT.search(rest)
            lhs_dims: List[int] = []
            # prefer inline operand shape, else the def-site dims
            if operand_names:
                m = re.search(
                    r"([a-z][a-z0-9]*)\[([0-9,]*)\][^%]*%"
                    + re.escape(operand_names[0]) + r"\b",
                    arglist,
                )
                if m:
                    lhs_dims = [int(d) for d in m.group(2).split(",") if d.strip()]
                else:
                    lhs_dims = cur.dims_of.get(operand_names[0], [])
            if cm2 and lhs_dims:
                for d in (int(x) for x in cm2.group(1).split(",") if x.strip()):
                    if d < len(lhs_dims):
                        contract *= lhs_dims[d]
            op.flops = 2.0 * relems * contract
        elif opcode == "convolution":
            op.flops = 2.0 * relems
        elif opcode in _POINTWISE:
            op.flops = float(relems)
        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in _COLLECTIVES and not opcode.endswith("-done"):
            gsz = 1
            gm = _IOTA_RG.search(rest)
            if gm:
                gsz = int(gm.group(2))
            else:
                bm2 = _BRACE_RG.search(rest)
                if bm2:
                    gsz = len([x for x in bm2.group(1).split(",") if x.strip()])
            op.collective_kind = base
            op.group_size = gsz
            if base == "all-gather":
                op.collective_bytes = rbytes // max(gsz, 1)
            elif base == "reduce-scatter":
                op.collective_bytes = rbytes * gsz
            else:
                op.collective_bytes = max(op.operand_bytes, rbytes)
        cur.ops.append(op)
    return HloModule(comps, entry)
