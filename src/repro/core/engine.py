"""Simulation engines.

Three interchangeable engines drive the same :class:`TargetDevice` model and
must produce bit-identical traffic counts and timelines (tested):

* :class:`CyclePollEngine` — the paper's §3.1 design: advance one cycle at a
  time and poll the WTT head every cycle (an O(1) comparison in the common
  case).  Faithful, transparent, and the paper's measured configuration.
* :class:`EventQueueEngine` — the paper's §3.2.2 *proposed* design (future
  work there; built here): WTT enactments and device transitions are events;
  simulation jumps between event times, eliminating idle per-cycle polling.
* ``VectorEngine`` lives in ``vector_engine.py`` — a closed-form, vectorized
  batch replay exploiting the fact that eidolons are replay-only (their
  traffic is independent of target state), our TPU-idiomatic rethink.
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Optional

from .config import SimConfig
from .target import EidolaDeadlock, TargetDevice
from .wtt import WriteTrackingTable

__all__ = ["CyclePollEngine", "EventQueueEngine", "EngineResult"]

_MAX_CYCLES = 2_000_000_000  # runaway guard


@dataclass
class EngineResult:
    sim_cycles: int
    wall_time_s: float
    head_polls: int


class CyclePollEngine:
    """Per-cycle WTT head polling, exactly as the paper describes."""

    name = "cycle"

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        t0 = time.perf_counter()
        cycle = -1
        while not (device.all_done and wtt.empty):
            cycle += 1
            if cycle > _MAX_CYCLES:
                raise EidolaDeadlock(
                    f"exceeded {_MAX_CYCLES} cycles; "
                    f"{device.blocked_count()} workgroups blocked"
                )
            # (1) the per-cycle O(1) head check; enact due writes
            due = wtt.poll(cycle)
            if due:
                for w in due:
                    device.memory.enact_xgmi_write(w, cycle)
                device.on_writes_enacted(due, cycle)
            # (2) fire device transitions scheduled at this cycle
            nxt = device.next_transition_cycle()
            if nxt is not None and nxt <= cycle:
                device.process_until(cycle)
            elif nxt is None and not device.all_done and wtt.empty:
                raise EidolaDeadlock(
                    f"all queues empty at cycle {cycle} with "
                    f"{device.blocked_count()} workgroups blocked "
                    "(missing peer flag writes in the trace?)"
                )
        return EngineResult(
            sim_cycles=max(cycle, 0),
            wall_time_s=time.perf_counter() - t0,
            head_polls=wtt.stats.head_polls,
        )


class EventQueueEngine:
    """Event-driven engine using the WTT as a native event queue."""

    name = "event"

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        t0 = time.perf_counter()
        last_cycle = 0
        while True:
            wtt_next = wtt.peek_wakeup_cycle()
            dev_next = device.next_transition_cycle()
            if wtt_next is None and dev_next is None:
                if device.all_done:
                    break
                raise EidolaDeadlock(
                    f"all queues empty at cycle {last_cycle} with "
                    f"{device.blocked_count()} workgroups blocked "
                    "(missing peer flag writes in the trace?)"
                )
            # writes enact before device transitions at equal cycles, matching
            # the cycle engine's intra-cycle ordering
            if dev_next is None or (wtt_next is not None and wtt_next <= dev_next):
                cycle, group = wtt.pop_next_group()
                assert cycle is not None
                for w in group:
                    device.memory.enact_xgmi_write(w, cycle)
                device.on_writes_enacted(group, cycle)
                last_cycle = max(last_cycle, cycle)
            else:
                device.process_until(dev_next)
                last_cycle = max(last_cycle, dev_next)
        return EngineResult(
            sim_cycles=last_cycle,
            wall_time_s=time.perf_counter() - t0,
            head_polls=wtt.stats.head_polls,
        )
