"""Simulation engines.

Three interchangeable engines drive the same :class:`TargetDevice` model and
must produce bit-identical traffic counts and timelines (tested):

* :class:`CyclePollEngine` — the paper's §3.1 design: advance one cycle at a
  time and poll the WTT head every cycle (an O(1) comparison in the common
  case).  Faithful, transparent, and the paper's measured configuration.
* :class:`EventQueueEngine` — the paper's §3.2.2 *proposed* design (future
  work there; built here): WTT enactments and device transitions are events;
  simulation jumps between event times, eliminating idle per-cycle polling.
* ``VectorEngine`` lives in ``vector_engine.py`` — a closed-form, vectorized
  batch replay exploiting the fact that eidolons are replay-only (their
  traffic is independent of target state), our TPU-idiomatic rethink.

Both cycle and event engines drive *N* devices on one unified loop: a node is
a ``(TargetDevice, WriteTrackingTable)`` pair, and the classic single-device
open-loop run is just the one-node case.  Intra-cycle ordering is fixed —
writes enact before device transitions, devices in id order — which is what
keeps the two engines bit-identical even when devices emit writes into each
other's WTTs mid-run (closed-loop clusters).
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .target import EidolaDeadlock, TargetDevice
from .wtt import WriteTrackingTable

__all__ = ["CyclePollEngine", "EventQueueEngine", "EngineResult"]

_MAX_CYCLES = 2_000_000_000  # runaway guard

Node = Tuple[TargetDevice, WriteTrackingTable]


@dataclass
class EngineResult:
    sim_cycles: int
    wall_time_s: float
    head_polls: int
    # perf_counter section split (interpreter/fabric/WTT seconds); only the
    # timeline engine fills this in — bench rows surface it as wall_breakdown
    breakdown: Optional[Dict[str, float]] = None


def _fmt_ids(ids: Sequence[int]) -> str:
    """Compress sorted ids into range notation: [0,1,2,5] -> '0-2,5'."""
    if not ids:
        return ""
    parts: List[str] = []
    start = prev = ids[0]
    for i in list(ids[1:]) + [None]:  # type: ignore[list-item]
        if i is not None and i == prev + 1:
            prev = i
            continue
        parts.append(str(start) if start == prev else f"{start}-{prev}")
        if i is not None:
            start = prev = i
    return ",".join(parts)


def _deadlock_message(nodes: Sequence[Node], cycle: int) -> str:
    """Actionable deadlock report: scenario, blocked WGs, unsatisfied flags."""
    scenario = nodes[0][0].scenario.name or "<unnamed>"
    total = sum(dev.blocked_count() for dev, _ in nodes)
    details: List[str] = []
    for dev, _ in nodes:
        for addr, wgs in sorted(dev.blocked_waits().items()):
            decoded = dev.amap.decode_flag(addr)
            where = f"flag 0x{addr:x}"
            if decoded is not None:
                where += f" (src_device={decoded[0]}, slot={decoded[1]})"
            details.append(
                f"device {dev.device_id}: wg {_fmt_ids(wgs)} waiting on {where}"
            )
    msg = (
        f"deadlock in scenario {scenario!r}: all queues empty at cycle "
        f"{cycle} with {total} workgroups blocked"
    )
    if details:
        msg += " [" + "; ".join(details) + "]"
    return msg + " (missing peer flag writes in the trace, or an EmitOp never fired?)"


def _deadlock_error(nodes: Sequence[Node], cycle: int) -> EidolaDeadlock:
    """Build the empty-queue deadlock error, with the static analyzer's
    blame-chain diagnosis embedded when one can be computed."""
    msg = _deadlock_message(nodes, cycle)
    diagnosis = None
    try:
        # late import: repro.analysis imports core modules
        from repro.analysis import diagnose_deadlock

        diagnosis = diagnose_deadlock(nodes[0][0].scenario)
    except Exception:  # diagnosis is best-effort; never mask the deadlock
        diagnosis = None
    return EidolaDeadlock(msg, diagnosis=diagnosis)


def _all_idle(nodes: Sequence[Node]) -> bool:
    return all(dev.all_done and wtt.empty for dev, wtt in nodes)


class CyclePollEngine:
    """Per-cycle WTT head polling, exactly as the paper describes."""

    name = "cycle"

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        return self.run_nodes([(device, wtt)])

    def run_nodes(self, nodes: Sequence[Node]) -> EngineResult:
        t0 = time.perf_counter()
        cycle = -1
        while not _all_idle(nodes):
            cycle += 1
            if cycle > _MAX_CYCLES:
                # not the empty-queue deadlock: queues still hold work, the
                # simulation just ran away — report what is pending instead
                scenario = nodes[0][0].scenario.name or "<unnamed>"
                pending = sum(len(wtt) for _, wtt in nodes)
                blocked = sum(dev.blocked_count() for dev, _ in nodes)
                raise EidolaDeadlock(
                    f"scenario {scenario!r} exceeded {_MAX_CYCLES} cycles with "
                    f"{pending} WTT writes pending and {blocked} workgroups "
                    "blocked (runaway span or livelock, not an empty-queue "
                    "deadlock)"
                )
            # (1) the per-cycle O(1) head check on every device; enact due
            # writes everywhere before any device transition fires
            for dev, wtt in nodes:
                due = wtt.poll(cycle)
                if due:
                    for w in due:
                        dev.memory.enact_xgmi_write(w, cycle)
                    dev.on_writes_enacted(due, cycle)
            # (2) fire device transitions scheduled at this cycle
            any_pending = False
            for dev, wtt in nodes:
                nxt = dev.next_transition_cycle()
                if nxt is not None:
                    any_pending = True
                    if nxt <= cycle:
                        dev.process_until(cycle)
            if (
                not any_pending
                and all(wtt.empty for _, wtt in nodes)
                and not all(dev.all_done for dev, _ in nodes)
            ):
                raise _deadlock_error(nodes, cycle)
        return EngineResult(
            sim_cycles=max(cycle, 0),
            wall_time_s=time.perf_counter() - t0,
            head_polls=sum(wtt.stats.head_polls for _, wtt in nodes),
        )


class EventQueueEngine:
    """Event-driven engine using the WTTs as native event queues.

    The next event time is tracked in one **global calendar**: a heap over
    ``(cycle, kind, node)`` entries (kind 0 = WTT head, 1 = device transition)
    with *lazy invalidation* — entries are validated against the node's actual
    next event on pop, and corrected entries are re-pushed.  Cross-device
    registrations (closed-loop emissions landing in a peer's WTT mid-run) are
    captured by the WTT's ``on_register`` hook, so advancing an N-device
    cluster costs O(log N) per event instead of the former O(N) scan of every
    WTT head and device queue.  Intra-cycle ordering is unchanged: writes
    enact before device transitions at equal cycles, devices in id order.
    """

    name = "event"

    _KIND_WTT, _KIND_DEV = 0, 1

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        return self.run_nodes([(device, wtt)])

    def run_nodes(self, nodes: Sequence[Node]) -> EngineResult:
        t0 = time.perf_counter()
        last_cycle = 0
        K_WTT, K_DEV = self._KIND_WTT, self._KIND_DEV
        cal: List[Tuple[int, int, int]] = []
        push = heapq.heappush
        pop = heapq.heappop

        def push_dev(i: int, dev: TargetDevice) -> None:
            c = dev.next_transition_cycle()
            if c is not None:
                push(cal, (c, K_DEV, i))

        saved_hooks = [wtt.on_register for _, wtt in nodes]
        try:
            for i, (dev, wtt) in enumerate(nodes):
                # every registration (seed traces were registered before the
                # run; these are mid-run cross-device emissions) lands in the
                # calendar the moment it happens
                wtt.on_register = (
                    lambda cyc, i=i: push(cal, (cyc, K_WTT, i))
                )
                c = wtt.peek_wakeup_cycle()
                if c is not None:
                    push(cal, (c, K_WTT, i))
                push_dev(i, dev)

            while True:
                # earliest still-valid calendar entry (lazy invalidation:
                # drained/deferred entries are dropped or re-timed on pop)
                nxt = None
                while cal:
                    c, kind, i = cal[0]
                    dev, wtt = nodes[i]
                    cur = (
                        wtt.peek_wakeup_cycle()
                        if kind == K_WTT
                        else dev.next_transition_cycle()
                    )
                    if cur != c:
                        pop(cal)
                        if cur is not None:
                            push(cal, (cur, kind, i))
                        continue
                    nxt = c
                    break
                if nxt is None:
                    if all(dev.all_done for dev, _ in nodes):
                        break
                    raise _deadlock_error(nodes, last_cycle)

                # gather every node with an event at nxt (dedupe duplicates)
                due_wtt: set = set()
                due_dev: set = set()
                while cal and cal[0][0] == nxt:
                    _, kind, i = pop(cal)
                    (due_wtt if kind == K_WTT else due_dev).add(i)
                # writes enact before device transitions at equal cycles,
                # devices in id order — matching the cycle engine's
                # intra-cycle ordering
                for i in sorted(due_wtt):
                    dev, wtt = nodes[i]
                    if wtt.peek_wakeup_cycle() != nxt:
                        continue  # stale duplicate
                    cycle, group = wtt.pop_next_group()
                    for w in group:
                        dev.memory.enact_xgmi_write(w, cycle)
                    dev.on_writes_enacted(group, cycle)
                    c = wtt.peek_wakeup_cycle()
                    if c is not None:
                        push(cal, (c, K_WTT, i))
                    due_dev.add(i)  # wakes may schedule transitions <= nxt
                for i in sorted(due_dev):
                    dev, _ = nodes[i]
                    c = dev.next_transition_cycle()
                    if c is not None and c <= nxt:
                        dev.process_until(nxt)
                    push_dev(i, dev)
                last_cycle = max(last_cycle, nxt)
        finally:
            for (_, wtt), hook in zip(nodes, saved_hooks):
                wtt.on_register = hook
        return EngineResult(
            sim_cycles=last_cycle,
            wall_time_s=time.perf_counter() - t0,
            head_polls=sum(wtt.stats.head_polls for _, wtt in nodes),
        )
