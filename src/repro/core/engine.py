"""Simulation engines.

Three interchangeable engines drive the same :class:`TargetDevice` model and
must produce bit-identical traffic counts and timelines (tested):

* :class:`CyclePollEngine` — the paper's §3.1 design: advance one cycle at a
  time and poll the WTT head every cycle (an O(1) comparison in the common
  case).  Faithful, transparent, and the paper's measured configuration.
* :class:`EventQueueEngine` — the paper's §3.2.2 *proposed* design (future
  work there; built here): WTT enactments and device transitions are events;
  simulation jumps between event times, eliminating idle per-cycle polling.
* ``VectorEngine`` lives in ``vector_engine.py`` — a closed-form, vectorized
  batch replay exploiting the fact that eidolons are replay-only (their
  traffic is independent of target state), our TPU-idiomatic rethink.

Both cycle and event engines drive *N* devices on one unified loop: a node is
a ``(TargetDevice, WriteTrackingTable)`` pair, and the classic single-device
open-loop run is just the one-node case.  Intra-cycle ordering is fixed —
writes enact before device transitions, devices in id order — which is what
keeps the two engines bit-identical even when devices emit writes into each
other's WTTs mid-run (closed-loop clusters).
"""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import List, Sequence, Tuple

from .target import EidolaDeadlock, TargetDevice
from .wtt import WriteTrackingTable

__all__ = ["CyclePollEngine", "EventQueueEngine", "EngineResult"]

_MAX_CYCLES = 2_000_000_000  # runaway guard

Node = Tuple[TargetDevice, WriteTrackingTable]


@dataclass
class EngineResult:
    sim_cycles: int
    wall_time_s: float
    head_polls: int


def _fmt_ids(ids: Sequence[int]) -> str:
    """Compress sorted ids into range notation: [0,1,2,5] -> '0-2,5'."""
    if not ids:
        return ""
    parts: List[str] = []
    start = prev = ids[0]
    for i in list(ids[1:]) + [None]:  # type: ignore[list-item]
        if i is not None and i == prev + 1:
            prev = i
            continue
        parts.append(str(start) if start == prev else f"{start}-{prev}")
        if i is not None:
            start = prev = i
    return ",".join(parts)


def _deadlock_message(nodes: Sequence[Node], cycle: int) -> str:
    """Actionable deadlock report: scenario, blocked WGs, unsatisfied flags."""
    scenario = nodes[0][0].scenario.name or "<unnamed>"
    total = sum(dev.blocked_count() for dev, _ in nodes)
    details: List[str] = []
    for dev, _ in nodes:
        for addr, wgs in sorted(dev.blocked_waits().items()):
            decoded = dev.amap.decode_flag(addr)
            where = f"flag 0x{addr:x}"
            if decoded is not None:
                where += f" (src_device={decoded[0]}, slot={decoded[1]})"
            details.append(
                f"device {dev.device_id}: wg {_fmt_ids(wgs)} waiting on {where}"
            )
    msg = (
        f"deadlock in scenario {scenario!r}: all queues empty at cycle "
        f"{cycle} with {total} workgroups blocked"
    )
    if details:
        msg += " [" + "; ".join(details) + "]"
    return msg + " (missing peer flag writes in the trace, or an EmitOp never fired?)"


def _all_idle(nodes: Sequence[Node]) -> bool:
    return all(dev.all_done and wtt.empty for dev, wtt in nodes)


class CyclePollEngine:
    """Per-cycle WTT head polling, exactly as the paper describes."""

    name = "cycle"

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        return self.run_nodes([(device, wtt)])

    def run_nodes(self, nodes: Sequence[Node]) -> EngineResult:
        t0 = time.perf_counter()
        cycle = -1
        while not _all_idle(nodes):
            cycle += 1
            if cycle > _MAX_CYCLES:
                # not the empty-queue deadlock: queues still hold work, the
                # simulation just ran away — report what is pending instead
                scenario = nodes[0][0].scenario.name or "<unnamed>"
                pending = sum(len(wtt) for _, wtt in nodes)
                blocked = sum(dev.blocked_count() for dev, _ in nodes)
                raise EidolaDeadlock(
                    f"scenario {scenario!r} exceeded {_MAX_CYCLES} cycles with "
                    f"{pending} WTT writes pending and {blocked} workgroups "
                    "blocked (runaway span or livelock, not an empty-queue "
                    "deadlock)"
                )
            # (1) the per-cycle O(1) head check on every device; enact due
            # writes everywhere before any device transition fires
            for dev, wtt in nodes:
                due = wtt.poll(cycle)
                if due:
                    for w in due:
                        dev.memory.enact_xgmi_write(w, cycle)
                    dev.on_writes_enacted(due, cycle)
            # (2) fire device transitions scheduled at this cycle
            any_pending = False
            for dev, wtt in nodes:
                nxt = dev.next_transition_cycle()
                if nxt is not None:
                    any_pending = True
                    if nxt <= cycle:
                        dev.process_until(cycle)
            if (
                not any_pending
                and all(wtt.empty for _, wtt in nodes)
                and not all(dev.all_done for dev, _ in nodes)
            ):
                raise EidolaDeadlock(_deadlock_message(nodes, cycle))
        return EngineResult(
            sim_cycles=max(cycle, 0),
            wall_time_s=time.perf_counter() - t0,
            head_polls=sum(wtt.stats.head_polls for _, wtt in nodes),
        )


class EventQueueEngine:
    """Event-driven engine using the WTTs as native event queues."""

    name = "event"

    def run(self, device: TargetDevice, wtt: WriteTrackingTable) -> EngineResult:
        return self.run_nodes([(device, wtt)])

    def run_nodes(self, nodes: Sequence[Node]) -> EngineResult:
        t0 = time.perf_counter()
        last_cycle = 0
        while True:
            # global next event time across every WTT and device queue
            nxt = None
            for dev, wtt in nodes:
                for c in (wtt.peek_wakeup_cycle(), dev.next_transition_cycle()):
                    if c is not None and (nxt is None or c < nxt):
                        nxt = c
            if nxt is None:
                if all(dev.all_done for dev, _ in nodes):
                    break
                raise EidolaDeadlock(_deadlock_message(nodes, last_cycle))
            # writes enact before device transitions at equal cycles, devices
            # in id order — matching the cycle engine's intra-cycle ordering
            for dev, wtt in nodes:
                if wtt.peek_wakeup_cycle() == nxt:
                    cycle, group = wtt.pop_next_group()
                    for w in group:
                        dev.memory.enact_xgmi_write(w, cycle)
                    dev.on_writes_enacted(group, cycle)
            for dev, _ in nodes:
                c = dev.next_transition_cycle()
                if c is not None and c <= nxt:
                    dev.process_until(nxt)
            last_cycle = max(last_cycle, nxt)
        return EngineResult(
            sim_cycles=last_cycle,
            wall_time_s=time.perf_counter() - t0,
            head_polls=sum(wtt.stats.head_polls for _, wtt in nodes),
        )
