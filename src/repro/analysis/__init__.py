"""EidolaSan: static verification and runtime sanitization of scenarios.

Two halves that cross-check each other:

* :func:`verify_scenario` lowers a scenario's phase programs into an
  inter-rank wait/emit graph (:class:`ProgramGraph`) and checks it — deadlock
  cycles with full blame chains, unmatched synchronization, flag-slot write
  races, fabric reachability — in milliseconds, before any simulation.
* :class:`TrafficSanitizer` (enabled via ``Cluster(sanitize=True)`` or
  ``simulate(..., sanitize=True)``) shadows a closed-loop run and asserts
  byte conservation, calendar monotonicity, and exactly-once flag delivery.

``python -m repro.analysis`` verifies every registered scenario against every
fabric preset (the CI gate).
"""

from .program_graph import EmitSite, Lane, ProgramGraph, WaitSite
from .sanitize import SanitizerError, TrafficSanitizer
from .verify import (
    Finding,
    Verdict,
    diagnose_deadlock,
    verify_graph,
    verify_scenario,
)

__all__ = [
    "EmitSite",
    "Lane",
    "ProgramGraph",
    "WaitSite",
    "SanitizerError",
    "TrafficSanitizer",
    "Finding",
    "Verdict",
    "diagnose_deadlock",
    "verify_graph",
    "verify_scenario",
]
