"""EidolaSan: static verification and runtime sanitization of scenarios.

Two halves that cross-check each other:

* :func:`verify_scenario` lowers a scenario's phase programs into an
  inter-rank wait/emit graph (:class:`ProgramGraph`) and checks it — deadlock
  cycles with full blame chains, unmatched synchronization, flag-slot write
  races, fabric reachability — in milliseconds, before any simulation.
* :class:`TrafficSanitizer` (enabled via ``Cluster(sanitize=True)`` or
  ``simulate(..., sanitize=True)``) shadows a closed-loop run and asserts
  byte conservation, calendar monotonicity, and exactly-once flag delivery.

A third leg quantifies over device counts instead of instances:
:func:`prove_layout` lowers a scenario's :class:`SymbolicProgram` +
:class:`AddressMap` into affine address families and proves flag/partial/
marker disjointness, unique flag writers, and wait/emit ordering for *all*
device counts up to the scenario's ``max_devices`` bound — without expanding
a single program (:mod:`repro.analysis.layout`).

``python -m repro.analysis`` verifies every registered scenario against every
fabric preset and runs the layout prover over the closed-loop registry (the
CI gate).
"""

from .layout import (
    LayoutFinding,
    LayoutProof,
    check_layout,
    check_programs,
    prove_layout,
    prove_registry,
)
from .program_graph import EmitSite, Lane, ProgramGraph, WaitSite
from .sanitize import SanitizerError, TrafficSanitizer
from .verify import (
    Finding,
    Verdict,
    diagnose_deadlock,
    verify_graph,
    verify_scenario,
)

__all__ = [
    "EmitSite",
    "Lane",
    "ProgramGraph",
    "WaitSite",
    "SanitizerError",
    "TrafficSanitizer",
    "Finding",
    "Verdict",
    "LayoutFinding",
    "LayoutProof",
    "check_layout",
    "check_programs",
    "prove_layout",
    "prove_registry",
    "diagnose_deadlock",
    "verify_graph",
    "verify_scenario",
]
