"""Verify every registered scenario against every fabric preset.

The CI gate: ``python -m repro.analysis`` statically checks all built-in
(and any registered) scenarios on the flat fabric and on each interconnect
preset, without running a single simulated cycle.  Exits non-zero if any
combination produces an error-severity finding.

It then dynamically verifies the pod-scale **timeline engine path**
(``repro.core.cohort_timeline``): every closed-loop scenario x preset runs
once at small scale through both the event engine and the timeline engine,
and their traffic counters must match bit-for-bit.  A scenario may be
timeline-ineligible only by *declaring why* (a ``timeline_opt_out`` reason
string on the scenario class); an undeclared ineligibility is a failure —
pod-scale coverage must never rot silently.  ``--no-timeline`` skips this
stage (static-only runs).

Finally it verifies **symbolic programs in loop space**: every closed-loop
scenario whose ranks stamp :class:`repro.core.scenario.SymbolicProgram`\\ s
is checked at ``--pod-devices`` scale (default 1024) with one node per
(lane, affine pattern) — O(segments), never the O(devices x steps) sites a
materialized lowering would need — and the loop-space verdict is
cross-checked against the materialized verifier at ``--devices`` scale.
Non-rank-uniform scenarios (e.g. hierarchical stages) are reported as
covered by the materialized path.  ``--no-symbolic`` skips the stage.

Last, the **parametric layout prover** (:mod:`repro.analysis.layout`)
certifies every closed-loop scenario's flag/marker address layout for *all*
device counts up to ``--max-devices`` (default 4096) on the flat shape and
re-attests each fabric preset — flag pool / partial region / marker-window
disjointness, unique flag writers per value epoch, and wait-before-emit
ordering, without expanding a single program.  ``--no-layout`` skips it.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.core.interconnect import list_fabrics
from repro.core.scenario import list_scenarios

from .verify import verify_scenario

# the physics outputs the timeline engine must reproduce bit-for-bit
_TIMELINE_KEYS = (
    "flag_reads",
    "nonflag_reads",
    "local_writes",
    "xgmi_writes_in",
    "xgmi_writes_out",
    "xgmi_bytes_in",
    "xgmi_bytes_out",
    "read_bytes",
    "write_bytes",
)


def _verify_timeline_path(devices: int, dpn: int, quiet: bool) -> int:
    """Run every closed-loop scenario x fabric preset through both engine
    implementations and compare counters.  Returns the failure count."""
    from repro.core import simulate
    from repro.core.scenario import get_scenario

    failures = 0
    combos = 0
    for name in list_scenarios():
        for fabric in [None, *list_fabrics()]:
            kw = dict(
                devices=devices, closed_loop=True, collect_segments=False
            )
            if fabric is not None:
                kw.update(fabric=fabric, devices_per_node=dpn)
            try:
                a = simulate(name, timeline=False, **kw)
            except TypeError:
                break  # open-loop-only scenario: no timeline path to verify
            combos += 1
            where = f"{name} [{fabric or 'flat'}]"
            try:
                b = simulate(name, timeline=True, **kw)
            except ValueError as e:
                declared = getattr(
                    get_scenario(name), "timeline_opt_out", None
                )
                if declared:
                    if not quiet:
                        print(f"{where}: timeline opt-out declared: "
                              f"{declared}")
                    continue
                failures += 1
                print(f"{where}: FAIL timeline-ineligible without a "
                      f"declared timeline_opt_out: {e}")
                continue
            if b.meta.get("engine_impl") != "timeline":
                failures += 1
                print(f"{where}: FAIL timeline engine did not engage "
                      f"(engine_impl={b.meta.get('engine_impl')!r})")
                continue
            drift = [
                f"{k} {a.traffic.get(k)} != {b.traffic.get(k)}"
                for k in _TIMELINE_KEYS
                if a.traffic.get(k) != b.traffic.get(k)
            ]
            if a.sim_cycles != b.sim_cycles:
                drift.append(f"sim_cycles {a.sim_cycles} != {b.sim_cycles}")
            if drift:
                failures += 1
                print(f"{where}: FAIL timeline counters drifted: "
                      + "; ".join(drift))
            elif not quiet:
                print(f"{where}: timeline path ok")
    tag = "FAILED" if failures else "ok"
    print(f"verified {combos} timeline-path combinations: {tag}"
          + (f" ({failures} with errors)" if failures else ""))
    return failures


def _verify_symbolic_path(
    small_devices: int, pod_devices: int, quiet: bool
) -> int:
    """Loop-space verification at pod scale + materialized cross-check at
    small scale.  Returns the failure count."""
    from .verify import verify_scenario, verify_symbolic

    failures = 0
    combos = 0
    for name in list_scenarios():
        try:
            v = verify_symbolic(name, devices=pod_devices, closed_loop=True)
        except TypeError:
            continue  # open-loop-only scenario
        combos += 1
        shape = [f for f in v.findings if f.kind == "symbolic-shape"]
        if shape:
            if not quiet:
                print(f"{name}: symbolic verify n/a (materialized path "
                      f"covers it): {shape[0].message}")
            continue
        if not v.ok:
            failures += 1
            print(v.render())
            continue
        # the loop-space verdict must agree with the exact per-step graph
        # at a scale where materializing is affordable
        vm = verify_scenario(name, devices=small_devices, closed_loop=True)
        vs = verify_symbolic(name, devices=small_devices, closed_loop=True)
        if vs.ok != vm.ok:
            failures += 1
            print(f"{name}: FAIL loop-space verdict ({'ok' if vs.ok else 'error'}) "
                  f"disagrees with the materialized verifier "
                  f"({'ok' if vm.ok else 'error'}) at {small_devices} devices")
        elif not quiet:
            print(f"{name}: symbolic loop-space verify ok at {pod_devices} "
                  f"devices (cross-checked at {small_devices})")
    tag = "FAILED" if failures else "ok"
    print(f"verified {combos} symbolic-program combinations: {tag}"
          + (f" ({failures} with errors)" if failures else ""))
    return failures


def _verify_layout_path(
    max_devices: int, dpn: int, quiet: bool
) -> int:
    """Parametric layout proofs over the closed-loop registry x fabric
    presets — every device count up to ``max_devices``, no simulation.
    Returns the failure count."""
    from .layout import prove_registry

    failures = 0
    proofs = prove_registry(
        max_devices=max_devices, devices_per_node=dpn, quiet=quiet
    )
    for proof in proofs:
        if not proof.ok:
            failures += 1
            print(proof.render())
        elif not quiet:
            print(proof.render())
    tag = "FAILED" if failures else "ok"
    print(f"proved {len(proofs)} layout obligations (registry x fabrics, "
          f"all n <= {max_devices}): {tag}"
          + (f" ({failures} with errors)" if failures else ""))
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify all scenarios x all fabric presets",
    )
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--devices-per-node", type=int, default=2)
    ap.add_argument(
        "-q", "--quiet", action="store_true",
        help="print only failing combinations",
    )
    ap.add_argument(
        "--no-timeline", action="store_true",
        help="skip the dynamic timeline-engine verification stage",
    )
    ap.add_argument(
        "--pod-devices", type=int, default=1024,
        help="device count for the loop-space symbolic verification stage",
    )
    ap.add_argument(
        "--no-symbolic", action="store_true",
        help="skip the loop-space symbolic verification stage",
    )
    ap.add_argument(
        "--max-devices", type=int, default=4096,
        help="device-count bound for the parametric layout-proof stage",
    )
    ap.add_argument(
        "--layout-dpn", type=int, default=4,
        help="devices-per-node used by the layout-proof stage",
    )
    ap.add_argument(
        "--no-layout", action="store_true",
        help="skip the parametric layout-proof stage",
    )
    args = ap.parse_args(argv)

    failures = 0
    combos = 0
    for name in list_scenarios():
        for fabric in [None, *list_fabrics()]:
            params = {"closed_loop": True}
            if fabric is not None:
                params["fabric"] = fabric
            try:
                verdict = verify_scenario(
                    name,
                    devices=args.devices,
                    devices_per_node=args.devices_per_node,
                    **params,
                )
            except TypeError:
                # open-loop-only scenario (no closed_loop/fabric knobs):
                # verify its single modeled rank once, without presets
                if fabric is not None:
                    continue
                verdict = verify_scenario(name, devices=args.devices)
            combos += 1
            if not verdict.ok:
                failures += 1
            if not verdict.ok or not args.quiet:
                print(verdict.render())
    tag = "FAILED" if failures else "ok"
    print(f"verified {combos} scenario x fabric combinations: {tag}"
          + (f" ({failures} with errors)" if failures else ""))
    if not args.no_timeline:
        failures += _verify_timeline_path(
            args.devices, args.devices_per_node, args.quiet
        )
    if not args.no_symbolic:
        failures += _verify_symbolic_path(
            args.devices, args.pod_devices, args.quiet
        )
    if not args.no_layout:
        failures += _verify_layout_path(
            args.max_devices, args.layout_dpn, args.quiet
        )
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
