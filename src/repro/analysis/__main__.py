"""Verify every registered scenario against every fabric preset.

The CI gate: ``python -m repro.analysis`` statically checks all built-in
(and any registered) scenarios on the flat fabric and on each interconnect
preset, without running a single simulated cycle.  Exits non-zero if any
combination produces an error-severity finding.
"""

from __future__ import annotations

import argparse
import sys
from typing import List, Optional

from repro.core.interconnect import list_fabrics
from repro.core.scenario import list_scenarios

from .verify import verify_scenario


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="statically verify all scenarios x all fabric presets",
    )
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--devices-per-node", type=int, default=2)
    ap.add_argument(
        "-q", "--quiet", action="store_true",
        help="print only failing combinations",
    )
    args = ap.parse_args(argv)

    failures = 0
    combos = 0
    for name in list_scenarios():
        for fabric in [None, *list_fabrics()]:
            params = {"closed_loop": True}
            if fabric is not None:
                params["fabric"] = fabric
            try:
                verdict = verify_scenario(
                    name,
                    devices=args.devices,
                    devices_per_node=args.devices_per_node,
                    **params,
                )
            except TypeError:
                # open-loop-only scenario (no closed_loop/fabric knobs):
                # verify its single modeled rank once, without presets
                if fabric is not None:
                    continue
                verdict = verify_scenario(name, devices=args.devices)
            combos += 1
            if not verdict.ok:
                failures += 1
            if not verdict.ok or not args.quiet:
                print(verdict.render())
    tag = "FAILED" if failures else "ok"
    print(f"verified {combos} scenario x fabric combinations: {tag}"
          + (f" ({failures} with errors)" if failures else ""))
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
