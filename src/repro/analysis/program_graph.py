"""Lowering phase programs into an inter-rank wait/emit dependency graph.

A scenario's synchronization structure is fully determined before any cycle is
simulated: every :class:`repro.core.scenario.PhaseSpec` either *waits* on flag
addresses (decodable through the scenario's :class:`AddressMap` back to a
``(src_device, slot)`` pair) or *emits* flags into peer memories
(:class:`repro.core.scenario.EmitOp`, landing at ``flag_addr(src, slot)`` in
the destination's symmetric heap).  This module lowers the per-rank programs
into that graph — lanes, wait sites, emit sites, and externally-scheduled
trace writes — which :mod:`repro.analysis.verify` then checks for deadlock
cycles, unmatched synchronization, write races, and fabric reachability
without running an engine.

Terminology:

* **lane** — all workgroups of one device that share a phase tuple (the same
  grouping the cohort interpreter uses).  Every built-in scenario stamps one
  shared tuple per rank, so a lane is normally "the rank's program"; devices
  with heterogeneous programs get one lane per distinct tuple.
* **flag key** — ``(owner_device, address)``: a flag variable in one device's
  memory.  Wait sites reference keys in their own device's memory; emit sites
  reference keys in the destination's.
* **external flag** — a flag written by a pre-scheduled trace
  (``scenario.traces_for``), i.e. satisfied unconditionally at some time.
  Open-loop scenarios synchronize exclusively through these.

This lowering is *materialized*: iterating ``lane.phases`` expands any
:class:`repro.core.scenario.SymbolicProgram` step by step, so site counts
grow with the step count (O(devices^2) for flat collectives).  At pod scale
use :func:`repro.analysis.verify.verify_symbolic` instead, which checks
rank-uniform symbolic programs in *loop space* — one node per (lane, affine
pattern) via :func:`repro.core.lockstep.plan_stages` — and is cross-checked
against this exact graph at small scale by ``python -m repro.analysis``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.scenario import EmitOp, PhaseSpec, Scenario

__all__ = ["EmitSite", "WaitSite", "Lane", "ProgramGraph"]

FlagKey = Tuple[int, int]  # (owner device, address in its memory)


@dataclass(frozen=True)
class WaitSite:
    """One wait phase observing one flag address."""

    device: int
    lane: int        # index into ProgramGraph.lanes
    phase_idx: int
    phase_name: str
    addr: int        # address in ``device``'s own memory
    src: Optional[int] = None   # decoded writer device, if a flag address
    slot: Optional[int] = None  # decoded flag slot, if a flag address

    def describe(self) -> str:
        what = f"flag 0x{self.addr:x}"
        if self.src is not None:
            what = f"flag(src={self.src}, slot={self.slot})"
        return (
            f"rank {self.device} phase {self.phase_idx} "
            f"{self.phase_name!r} waits on {what}"
        )


@dataclass(frozen=True)
class EmitSite:
    """One :class:`EmitOp` in one phase of one lane."""

    device: int
    lane: int
    phase_idx: int
    phase_name: str
    emit_idx: int    # position within the phase's ``emits`` tuple
    dst: int
    addr: int        # effective address in ``dst``'s memory
    coalesce: str
    slot: Optional[int] = None  # decoded flag slot, if a flag address

    def describe(self) -> str:
        return (
            f"rank {self.device} phase {self.phase_idx} {self.phase_name!r} "
            f"emits to rank {self.dst}"
            + (f" slot {self.slot}" if self.slot is not None else
               f" addr 0x{self.addr:x}")
        )


@dataclass
class Lane:
    """All workgroups of one device sharing a phase tuple."""

    device: int
    index: int                       # global lane id (ProgramGraph.lanes)
    wg_count: int
    phases: Tuple[PhaseSpec, ...]


@dataclass
class ProgramGraph:
    """The lowered wait/emit structure of one scenario instance."""

    scenario_name: str
    n_devices: int
    closed_loop: bool
    lanes: List[Lane] = field(default_factory=list)
    lanes_of: Dict[int, List[int]] = field(default_factory=dict)
    device_wgs: Dict[int, int] = field(default_factory=dict)
    waiters: Dict[FlagKey, List[WaitSite]] = field(default_factory=dict)
    emitters: Dict[FlagKey, List[EmitSite]] = field(default_factory=dict)
    # (device, addr) -> count of pre-scheduled trace writes landing there
    external_flags: Dict[FlagKey, int] = field(default_factory=dict)
    # emit ops whose flag address could not be formed (bad slot/device)
    invalid_emits: List[str] = field(default_factory=list)

    @classmethod
    def from_scenario(cls, scenario: Scenario) -> "ProgramGraph":
        """Lower ``scenario`` (closed loop: every rank's ``programs_for``;
        open loop: device 0's program plus the eidolon trace bundle)."""
        cfg = scenario.cfg
        amap = scenario.amap
        n = cfg.n_devices
        g = cls(
            scenario_name=scenario.name or type(scenario).__name__,
            n_devices=n,
            closed_loop=bool(scenario.closed_loop),
        )
        modeled = range(n) if scenario.closed_loop else range(1)
        for d in modeled:
            programs = scenario.programs_for(d)
            g.device_wgs[d] = len(programs)
            g.lanes_of[d] = []
            seen: Dict[int, Lane] = {}  # id(phases) -> lane
            for p in programs:
                lane = seen.get(id(p.phases))
                if lane is None:
                    lane = Lane(
                        device=d,
                        index=len(g.lanes),
                        wg_count=0,
                        phases=p.phases,
                    )
                    seen[id(p.phases)] = lane
                    g.lanes.append(lane)
                    g.lanes_of[d].append(lane.index)
                lane.wg_count += 1
        for d in modeled:
            for w in scenario.traces_for(d):
                if amap.is_flag(w.addr):
                    key = (d, w.addr)
                    g.external_flags[key] = g.external_flags.get(key, 0) + 1

        for lane in g.lanes:
            for i, ph in enumerate(lane.phases):
                if ph.wait_addrs:
                    for a in ph.wait_addrs:
                        decoded = amap.decode_flag(a)
                        site = WaitSite(
                            device=lane.device,
                            lane=lane.index,
                            phase_idx=i,
                            phase_name=ph.name,
                            addr=a,
                            src=decoded[0] if decoded else None,
                            slot=decoded[1] if decoded else None,
                        )
                        g.waiters.setdefault((lane.device, a), []).append(site)
                for j, op in enumerate(ph.emits):
                    addr = g._effective_addr(amap, lane.device, op)
                    if addr is None:
                        g.invalid_emits.append(
                            f"rank {lane.device} phase {i} {ph.name!r}: "
                            f"EmitOp slot {op.slot} has no address in the "
                            f"scenario's flag layout (flag_slots="
                            f"{amap.flag_slots})"
                        )
                        continue
                    decoded = amap.decode_flag(addr)
                    site = EmitSite(
                        device=lane.device,
                        lane=lane.index,
                        phase_idx=i,
                        phase_name=ph.name,
                        emit_idx=j,
                        dst=op.dst,
                        addr=addr,
                        coalesce=op.coalesce,
                        slot=decoded[1] if decoded else None,
                    )
                    g.emitters.setdefault((op.dst, addr), []).append(site)
        return g

    @staticmethod
    def _effective_addr(amap, src: int, op: EmitOp) -> Optional[int]:
        """The address an emission lands at in ``op.dst``'s memory, or None
        when the flag-slot convention cannot form one (bad slot/device)."""
        if op.addr is not None:
            return op.addr
        try:
            return amap.flag_addr(src, op.slot)
        except ValueError:
            return None

    # ------------------------------------------------------------------
    # derived views used by the checks
    # ------------------------------------------------------------------

    def emit_pairs(self) -> List[Tuple[int, int]]:
        """Sorted distinct ``(src, dst)`` device pairs of all emissions."""
        return sorted({
            (s.device, s.dst) for sites in self.emitters.values()
            for s in sites
        })

    def describe_key(self, key: FlagKey) -> str:
        """Human-readable name of a flag key, decoding the slot convention."""
        device, addr = key
        # decode against any lane's amap-compatible layout: keys were built
        # from one AddressMap, so re-derive (src, slot) from the waiters or
        # emitters that reference the key
        for site in self.waiters.get(key, []):
            if site.src is not None:
                return (
                    f"flag(src={site.src}, slot={site.slot}) "
                    f"in rank {device}'s memory"
                )
        for site in self.emitters.get(key, []):
            if site.slot is not None:
                return (
                    f"flag(src={site.device}, slot={site.slot}) "
                    f"in rank {device}'s memory"
                )
        return f"address 0x{addr:x} in rank {device}'s memory"
