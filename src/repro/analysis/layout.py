"""Parametric layout & happens-before prover.

Every closed-loop scenario synchronizes through three address regions of one
:class:`repro.core.memory.AddressMap`: the *flag pool* (``flag_addr(src,
slot)``), the *partial-tile region* where data-marker writes accumulate
upward from ``partial_base``, and the raw data region.  The engines resolve
waits **by value**, so any aliasing between those regions lets a stale
marker satisfy a flag wait long before the real emission arrives — the bug
class PR 9 found in ``ring_allreduce`` beyond 256 devices.

This module proves the layout safe for *all* device counts, not just the n a
test happened to run.  It lowers each scenario's
:class:`repro.core.scenario.SymbolicProgram` + AddressMap into affine
address families — flag-slot progressions over loop iterations (``k``) and
run members (``j``), data-marker windows ``[partial_base, partial_base +
64*marks[d])``, and region extents as functions of ``n`` — then discharges,
via gcd/lag residues and interval arithmetic over that affine IR and
*without expanding a single program or simulating*:

(a) flag pool, partial region, and marker windows are pairwise disjoint;
(b) every flag address has a unique writer per value epoch (no two emission
    instances rewrite the same ``(writer, slot)`` — cross-writer collisions
    are impossible because ``flag_addr`` is injective over ``slot*n + src``,
    so the check is per-writer local);
(c) every wait family is fed by an emission family (existence statically;
    strict happens-before order via the loop-space planner,
    :func:`repro.analysis.verify.verify_symbolic`, at probe counts).

for every constructible device count up to the scenario's
``max_devices`` bound.  Small counts are checked exhaustively rank-by-rank;
large counts through representative rank classes whose family descriptors
are fitted as exact integer polynomials in n at a handful of probe counts
(verified on held-out probes) and then evaluated over the whole candidate
range with vectorized interval/gcd arithmetic.  Any parametric hit is
re-confirmed concretely at the smallest suspect count so findings name the
exact slot, the writer pair, and the first aliasing n.

The tiered lockstep compiler (:mod:`repro.core.lockstep_tiered`) consumes
the same concrete checker (:func:`check_programs`) instead of re-deriving
its private ``_check_flag_reuse`` — one implementation, two call sites.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from fractions import Fraction
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.config import SimConfig
from repro.core.memory import AddressMap
from repro.core.scenario import (
    Affine,
    AffineRun,
    EmitOp,
    EmitRun,
    LoopEmit,
    LoopPhase,
    LoopSpec,
    PhaseSpec,
    Scenario,
    SymbolicProgram,
    as_symbolic,
    get_scenario,
    list_scenarios,
)

__all__ = [
    "LayoutFinding",
    "LayoutProof",
    "check_layout",
    "check_programs",
    "prove_layout",
    "prove_registry",
]

ScenarioRef = Union[str, type]


def _flag_name(writer: int, slot: int) -> str:
    return f"flag (writer {writer}, slot {slot})"


# ---------------------------------------------------------------------------
# findings / proofs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayoutFinding:
    """One provable layout defect (or modelling limit) with exact blame."""

    kind: str
    severity: str  # "error" | "warning"
    message: str
    n_devices: Optional[int] = None  # smallest device count exhibiting it
    slot: Optional[int] = None
    writers: Tuple[int, ...] = ()
    dst: Optional[int] = None

    def render(self) -> str:
        where = f" [n={self.n_devices}]" if self.n_devices is not None else ""
        return f"[{self.severity}] {self.kind}{where}: {self.message}"


@dataclass
class LayoutProof:
    """Result of a parametric sweep over one scenario's device counts."""

    scenario: str
    devices_per_node: Optional[int]
    fabric: Optional[str]
    max_devices: int
    findings: List[LayoutFinding] = field(default_factory=list)
    checked_counts: Tuple[int, ...] = ()  # exhaustively checked (small n)
    probe_counts: Tuple[int, ...] = ()  # full-rank probes (large n)
    ordering_counts: Tuple[int, ...] = ()  # happens-before probe counts
    parametric: bool = False  # large regime covered by verified models
    notes: Tuple[str, ...] = ()

    @property
    def errors(self) -> List[LayoutFinding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def render(self) -> str:
        dpn = self.devices_per_node
        shape = f" dpn={dpn}" if dpn else ""
        fab = f" fabric={self.fabric}" if self.fabric else ""
        head = (
            f"layout proof: {self.scenario}{shape}{fab} "
            f"n<={self.max_devices}: "
            + ("PROVEN" if self.ok else f"{len(self.errors)} finding(s)")
        )
        lines = [head]
        lines.extend("  " + f.render() for f in self.findings)
        lines.extend("  note: " + n for n in self.notes)
        return "\n".join(lines)


class _Unmodeled(Exception):
    """Program shape outside the affine families the prover lowers."""


# ---------------------------------------------------------------------------
# affine family extraction (no expansion: one record per emission/wait site)
# ---------------------------------------------------------------------------


@dataclass
class _EFam:
    """One emission site: ``m`` members (j) re-emitted over ``epochs`` (k).

    dst(j) = dst0 + j*dstep; slot(j, k) = slot0 + j*sstep_j + k*sstep_k.
    ``raw`` marks an address-override emission (no flag-slot convention);
    its literal target is ``addr0``.
    """

    writer: int
    name: str
    pos: int  # phase ordinal within the rank's program
    m: int
    dst0: int
    dstep: int
    slot0: int
    sstep_j: int
    sstep_k: int
    epochs: int
    dw: int
    raw: bool = False
    addr0: int = 0

    @property
    def site(self) -> str:
        return f"{self.name}#{self.pos}"


@dataclass
class _WFam:
    """One wait site: ``m`` member addresses (j) awaited over ``epochs``."""

    rank: int
    name: str
    pos: int
    m: int
    addr0: int
    astep_j: int
    astep_k: int
    epochs: int

    @property
    def site(self) -> str:
        return f"{self.name}#{self.pos}"


def _extract_program(
    program, rank: int
) -> Tuple[List[_EFam], List[_WFam]]:
    """Lower one rank's program into affine families — O(sites), not
    O(phases): loops contribute one record per body site."""
    efams: List[_EFam] = []
    wfams: List[_WFam] = []
    if isinstance(program, SymbolicProgram):
        segments = program.segments
    else:
        segments = tuple(program)
    pos = 0
    for seg in segments:
        if isinstance(seg, LoopSpec):
            body, count, k0 = seg.body, seg.count, seg.k0
        elif isinstance(seg, (PhaseSpec, LoopPhase)):
            body, count, k0 = (seg,), 1, 0
        else:
            raise _Unmodeled(
                f"rank {rank}: unknown segment type {type(seg).__name__}"
            )
        if count <= 0:
            continue
        for ph in body:
            for w in ph.wait_addrs or ():
                if isinstance(w, AffineRun):
                    wfams.append(_WFam(
                        rank, ph.name, pos, w.count, w.start, w.stride,
                        0, count,
                    ))
                elif isinstance(w, Affine):
                    wfams.append(_WFam(
                        rank, ph.name, pos, 1, w.at(k0),
                        0, w.step if count > 1 else 0, count,
                    ))
                else:
                    wfams.append(_WFam(
                        rank, ph.name, pos, 1, int(w), 0, 0, count,
                    ))
            for e in ph.emits or ():
                if isinstance(e, EmitRun):
                    if e.count <= 0:
                        continue
                    efams.append(_EFam(
                        rank, ph.name, pos, e.count, e.dst0,
                        e.dst_stride if e.count > 1 else 0, e.slot0,
                        e.slot_stride if e.count > 1 else 0, 0, count,
                        e.data_writes,
                    ))
                elif isinstance(e, LoopEmit):
                    if e.dst.step != 0 and count > 1:
                        raise _Unmodeled(
                            f"rank {rank}: emission destination varies "
                            f"across loop iterations in phase {ph.name!r}"
                        )
                    efams.append(_EFam(
                        rank, ph.name, pos, 1, e.dst.at(k0), 0,
                        e.slot.at(k0), 0,
                        e.slot.step if count > 1 else 0, count,
                        e.data_writes,
                    ))
                elif isinstance(e, EmitOp):
                    if e.addr is not None:
                        efams.append(_EFam(
                            rank, ph.name, pos, 1, e.dst, 0, 0, 0, 0,
                            count, e.data_writes, raw=True, addr0=e.addr,
                        ))
                    else:
                        efams.append(_EFam(
                            rank, ph.name, pos, 1, e.dst, 0, e.slot, 0, 0,
                            count, e.data_writes,
                        ))
                else:
                    raise _Unmodeled(
                        f"rank {rank}: unknown emit entry "
                        f"{type(e).__name__} in phase {ph.name!r}"
                    )
            pos += 1
    return efams, wfams


def _flag_linear(amap: AddressMap, n: int) -> Tuple[int, int]:
    """Validated linear form of the flag pool: ``addr = base + unit*(slot*n
    + src)``.  Raises :class:`_Unmodeled` for maps that break the form."""
    base, unit = amap.flag_linear()
    checks = [(0, 0, base)]
    if n > 1:
        checks.append((1, 0, base + unit))
    if amap.flag_slots > 1:
        checks.append((0, 1, base + unit * n))
    for src, slot, want in checks:
        if amap.flag_addr(src, slot) != want:
            raise _Unmodeled(
                "AddressMap flag addressing is not the linear "
                "base + unit*(slot*n + src) family"
            )
    return base, unit


# ---------------------------------------------------------------------------
# concrete checker (shared core: prover + tiered lockstep compiler)
# ---------------------------------------------------------------------------


def _fam_slot_range(f: _EFam) -> Tuple[int, int]:
    dj = (f.m - 1) * f.sstep_j
    dk = (f.epochs - 1) * f.sstep_k
    lo = f.slot0 + min(0, dj) + min(0, dk)
    hi = f.slot0 + max(0, dj) + max(0, dk)
    return lo, hi


def _fam_dst_range(f: _EFam) -> Tuple[int, int, int]:
    """(lo, hi, step) of the destination progression."""
    if f.m == 1 or f.dstep == 0:
        return f.dst0, f.dst0, 0
    last = f.dst0 + (f.m - 1) * f.dstep
    return min(f.dst0, last), max(f.dst0, last), abs(f.dstep)


def _progression_meet(
    lo_a: int, hi_a: int, st_a: int, lo_b: int, hi_b: int, st_b: int
) -> Optional[int]:
    """Smallest common member of two arithmetic progressions, or ``None``.

    Conservative: a gcd-residue test decides intersection; the witness is
    then located by walking the sparser progression (bounded by its count).
    """
    if hi_a < lo_b or hi_b < lo_a:
        return None
    if st_a == 0 and st_b == 0:
        return lo_a if lo_a == lo_b else None
    if st_a == 0:
        hit = lo_b <= lo_a <= hi_b and (lo_a - lo_b) % st_b == 0
        return lo_a if hit else None
    if st_b == 0:
        hit = lo_a <= lo_b <= hi_a and (lo_b - lo_a) % st_a == 0
        return lo_b if hit else None
    if (lo_b - lo_a) % int(np.gcd(st_a, st_b)):
        return None
    # a shared value exists on the infinite lattices; walk A's progression
    # (bounded by st_b steps via CRT) for the first one inside both ranges
    start = max(lo_a, lo_b)
    v = lo_a + -(-(start - lo_a) // st_a) * st_a  # ceil into A's lattice
    while v <= min(hi_a, hi_b):
        if (v - lo_b) % st_b == 0:
            return v
        v += st_a
    return None


def _check_families(
    n: int,
    amap: AddressMap,
    efams: Sequence[_EFam],
    wfams: Sequence[_WFam],
    *,
    include_marks: bool = True,
    region: bool = True,
    capacity: bool = True,
    coverage: bool = True,
    coverage_dsts: Optional[Sequence[int]] = None,
    stop_after: int = 8,
) -> List[LayoutFinding]:
    """Run every layout check over concrete-n affine families.

    Cost is O(sites + members-of-runs) — loop epochs are never expanded.
    This is the single implementation behind both the parametric prover and
    the tiered lockstep compiler's pre-solve gate.
    """
    findings: List[LayoutFinding] = []
    base, unit = _flag_linear(amap, n)
    pbase = amap.partial_base
    fend = amap.flag_region()[1]

    def decode(addr: int) -> Tuple[int, int]:
        idx = (addr - base) // unit
        return int(idx % n), int(idx // n)

    def done() -> bool:
        return len(findings) >= stop_after

    # -- region-level disjointness: flag pool vs partial-tile region
    if region and fend > pbase:
        w, s = decode(pbase + (-(pbase - base) % unit) % unit)
        findings.append(LayoutFinding(
            "layout-overlap", "error",
            f"flag pool overruns the partial-tile region: flag region "
            f"[0x{base:x}, 0x{fend:x}) crosses partial_base 0x{pbase:x} "
            f"by {fend - pbase} bytes; first aliased {_flag_name(w, s)} — "
            f"re-base the map with AddressMap.with_partial_clearance()",
            n_devices=n, slot=s, writers=(w,),
        ))

    # -- slot capacity and destination sanity
    for f in efams:
        if f.raw:
            if base <= f.addr0 < fend:
                w, s = decode(f.addr0)
                findings.append(LayoutFinding(
                    "layout-raw-write", "error",
                    f"raw address emission at {f.site} on rank {f.writer} "
                    f"targets 0x{f.addr0:x} inside the flag pool "
                    f"({_flag_name(w, s)})",
                    n_devices=n, slot=s, writers=(f.writer,), dst=f.dst0,
                ))
            continue
        dlo, dhi, _ = _fam_dst_range(f)
        if dlo < 0 or dhi >= n:
            findings.append(LayoutFinding(
                "layout-bad-dst", "error",
                f"emission {f.site} on rank {f.writer} targets device "
                f"{dlo if dlo < 0 else dhi} outside [0, {n})",
                n_devices=n, writers=(f.writer,),
            ))
            continue
        if not capacity:
            continue
        slo, shi = _fam_slot_range(f)
        if slo < 0 or shi >= amap.flag_slots:
            findings.append(LayoutFinding(
                "layout-capacity", "error",
                f"emission {f.site} on rank {f.writer} uses flag slot "
                f"{slo if slo < 0 else shi} outside the map's capacity "
                f"(flag_slots={amap.flag_slots}); writes would land past "
                f"the reserved flag region",
                n_devices=n, slot=(slo if slo < 0 else shi),
                writers=(f.writer,),
            ))
    if done():
        return findings

    # -- data-marker windows: wend[d] = pbase + 64 * total marker writes
    marks = np.zeros(n, np.int64)
    flag_fams = [f for f in efams if not f.raw]
    for f in efams:
        dlo, dhi, _ = _fam_dst_range(f)
        if f.dw == 0 or dlo < 0 or dhi >= n:
            continue
        if f.dstep == 0:
            marks[f.dst0] += f.m * f.epochs * f.dw
        else:
            marks[f.dst0 + f.dstep * np.arange(f.m)] += f.epochs * f.dw
    wend = pbase + 64 * marks

    if include_marks and marks.any():
        for f in flag_fams:
            dlo, dhi, _ = _fam_dst_range(f)
            if dlo < 0 or dhi >= n:
                continue
            j = np.arange(f.m)
            dvec = f.dst0 + f.dstep * j
            slot_j = f.slot0 + f.sstep_j * j
            dk = (f.epochs - 1) * f.sstep_k
            lo = base + unit * ((slot_j + min(0, dk)) * n + f.writer)
            hi = base + unit * ((slot_j + max(0, dk)) * n + f.writer)
            st = unit * n * abs(f.sstep_k) if f.epochs > 1 else 0
            s = max(st, 1)
            first = lo + ((pbase - lo + s - 1) // s) * s
            first = np.maximum(first, lo)
            bad = (first <= hi) & (first < wend[dvec])
            if bad.any():
                jb = int(np.argmax(bad))
                d = int(dvec[jb])
                w, sl = decode(int(first[jb]))
                findings.append(LayoutFinding(
                    "marker-alias", "error",
                    f"data-marker writes on rank {d} reach "
                    f"{_flag_name(w, sl)}: the flag pool overruns the "
                    f"partial-tile region at this shape",
                    n_devices=n, slot=sl, writers=(w,), dst=d,
                ))
                if done():
                    return findings

    # -- unique writer per flag value epoch (per-writer local: flag_addr is
    #    injective over slot*n + src, so cross-writer collisions can't exist)
    by_writer: Dict[int, List[_EFam]] = {}
    for f in flag_fams:
        dlo, dhi, _ = _fam_dst_range(f)
        if dlo < 0 or dhi >= n:
            continue
        by_writer.setdefault(f.writer, []).append(f)
        # within one site: loop epochs rewriting the same slot, or
        # duplicated members
        rewrite = f.epochs > 1 and f.sstep_k == 0
        dup = f.m > 1 and f.dstep == 0 and f.sstep_j == 0
        if rewrite or dup:
            findings.append(LayoutFinding(
                "flag-reuse", "error",
                f"flag slot reuse: rank {f.dst0} receives "
                f"{_flag_name(f.writer, f.slot0)} from more than one "
                f"emission instance ({f.site} re-emits it "
                + (f"across {f.epochs} loop iterations"
                   if rewrite else f"for {f.m} run members") + ")",
                n_devices=n, slot=f.slot0, writers=(f.writer, f.writer),
                dst=f.dst0,
            ))
            if done():
                return findings
    for w, fams in by_writer.items():
        for i in range(len(fams)):
            for jx in range(i + 1, len(fams)):
                a, b = fams[i], fams[jx]
                da = _fam_dst_range(a)
                db = _fam_dst_range(b)
                d_hit = _progression_meet(*da, *db)
                if d_hit is None:
                    continue
                sa_lo, sa_hi = _fam_slot_range(a)
                sb_lo, sb_hi = _fam_slot_range(b)
                ga = int(np.gcd(
                    abs(a.sstep_j) if a.m > 1 else 0,
                    abs(a.sstep_k) if a.epochs > 1 else 0,
                ))
                gb = int(np.gcd(
                    abs(b.sstep_j) if b.m > 1 else 0,
                    abs(b.sstep_k) if b.epochs > 1 else 0,
                ))
                s_hit = _progression_meet(
                    sa_lo, sa_hi, ga, sb_lo, sb_hi, gb
                )
                if s_hit is None:
                    continue
                findings.append(LayoutFinding(
                    "flag-reuse", "error",
                    f"flag slot reuse: rank {d_hit} receives "
                    f"{_flag_name(w, s_hit)} from more than one emission "
                    f"instance ({a.site} and {b.site})",
                    n_devices=n, slot=s_hit, writers=(w, w), dst=d_hit,
                ))
                if done():
                    return findings

    # -- wait coverage: every awaited flag has an emitting instance
    if coverage and wfams:
        dscope = (
            sorted(set(coverage_dsts))
            if coverage_dsts is not None else range(n)
        )
        want = {int(d) for d in dscope}
        by_dst: Dict[int, List[Tuple[int, int, int]]] = {d: [] for d in want}
        for f in flag_fams:
            dlo, dhi, _ = _fam_dst_range(f)
            if dlo < 0 or dhi >= n:
                continue
            dk = (f.epochs - 1) * f.sstep_k
            st = unit * n * abs(f.sstep_k) if f.epochs > 1 else 0
            for d in want:
                t = d - f.dst0
                if f.dstep == 0:
                    js = range(f.m) if t == 0 else ()
                elif t % f.dstep == 0 and 0 <= t // f.dstep < f.m:
                    js = (t // f.dstep,)
                else:
                    js = ()
                for jm in js:
                    sl = f.slot0 + jm * f.sstep_j
                    lo = base + unit * ((sl + min(0, dk)) * n + f.writer)
                    hi = base + unit * ((sl + max(0, dk)) * n + f.writer)
                    by_dst[d].append((lo, hi, st))
        for wf in wfams:
            if wf.rank not in want:
                continue
            mem = (
                wf.addr0
                + wf.astep_j * np.arange(wf.m)[:, None]
                + wf.astep_k * np.arange(wf.epochs)[None, :]
            ).ravel()
            covered = np.zeros(mem.shape, bool)
            for lo, hi, st in by_dst[wf.rank]:
                if st == 0:
                    covered |= mem == lo
                else:
                    covered |= (
                        (mem >= lo) & (mem <= hi) & ((mem - lo) % st == 0)
                    )
            if not covered.all():
                a = int(mem[int(np.argmin(covered))])
                wtag = (
                    f"{_flag_name(*decode(a))}"
                    if base <= a < max(fend, a + 1) and (a - base) % unit == 0
                    and (a - base) // unit < n * max(amap.flag_slots, 1)
                    else f"address 0x{a:x}"
                )
                findings.append(LayoutFinding(
                    "unmatched-wait-family", "error",
                    f"wait at {wf.site} on rank {wf.rank} polls {wtag} "
                    f"that no emission instance ever writes",
                    n_devices=n, dst=wf.rank,
                ))
                if done():
                    return findings
    return findings


def _extract_all(
    progs: Sequence, n: int
) -> Tuple[List[_EFam], List[_WFam]]:
    efams: List[_EFam] = []
    wfams: List[_WFam] = []
    for rank in range(n):
        e, w = _extract_program(progs[rank], rank)
        efams.extend(e)
        wfams.extend(w)
    return efams, wfams


def check_programs(
    progs: Sequence,
    amap: AddressMap,
    cfg: SimConfig,
    *,
    coverage: bool = False,
    coverage_dsts: Optional[Sequence[int]] = None,
) -> List[LayoutFinding]:
    """Concrete layout check over per-rank programs (symbolic or flat).

    The tiered lockstep compiler's entry point: it passes the same
    ``SymbolicProgram`` list it schedules, and declines the shape when any
    error finding comes back (citing the finding verbatim).  Marker checks
    follow ``cfg.include_data_writes`` — with markers disabled no data write
    ever lands in the partial region, so no alias is reachable.
    """
    n = cfg.n_devices
    try:
        efams, wfams = _extract_all(progs, n)
    except _Unmodeled as e:
        return [LayoutFinding("layout-unmodeled", "error", str(e),
                              n_devices=n)]
    try:
        return _check_families(
            n, amap, efams, wfams,
            include_marks=cfg.include_data_writes,
            region=False,
            coverage=coverage, coverage_dsts=coverage_dsts,
        )
    except _Unmodeled as e:
        return [LayoutFinding("layout-unmodeled", "error", str(e),
                              n_devices=n)]


def check_layout(sc: Scenario) -> List[LayoutFinding]:
    """Full concrete layout check of one scenario instance (all ranks, all
    checks).  Open-loop scenarios have no per-rank programs and return
    no findings."""
    if not sc.closed_loop:
        return []
    n = sc.cfg.n_devices
    progs = []
    for d in range(n):
        programs = sc.programs_for(d)
        if not programs:
            return [LayoutFinding(
                "layout-unmodeled", "warning",
                f"rank {d} has no workgroup programs", n_devices=n,
            )]
        sp = as_symbolic(programs[0].phases)
        progs.append(sp if sp is not None else programs[0].phases)
    try:
        efams, wfams = _extract_all(progs, n)
        return _check_families(
            n, sc.amap, efams, wfams,
            include_marks=sc.cfg.include_data_writes,
        )
    except _Unmodeled as e:
        return [LayoutFinding("layout-unmodeled", "warning", str(e),
                              n_devices=n)]


# ---------------------------------------------------------------------------
# exact polynomial models over n (probe-fitted, holdout-verified)
# ---------------------------------------------------------------------------


def _fit_poly(
    xs: Sequence[int], ys: Sequence[int], max_deg: int = 3
) -> Optional[Tuple[Fraction, ...]]:
    """Exact rational polynomial through the probe points, or ``None``.

    Fits degree d on the first d+1 points and verifies on *all* remaining
    probes — at least two held-out points at the highest degree — so an
    accepted model interpolates every probe exactly."""
    deg_cap = min(max_deg, len(xs) - 2)
    for deg in range(deg_cap + 1):
        pts = deg + 1
        mat = [
            [Fraction(x) ** p for p in range(pts)] + [Fraction(y)]
            for x, y in zip(xs[:pts], ys[:pts])
        ]
        ok = True
        for col in range(pts):
            piv = next(
                (r for r in range(col, pts) if mat[r][col] != 0), None
            )
            if piv is None:
                ok = False
                break
            mat[col], mat[piv] = mat[piv], mat[col]
            inv = 1 / mat[col][col]
            mat[col] = [v * inv for v in mat[col]]
            for r in range(pts):
                if r != col and mat[r][col] != 0:
                    fac = mat[r][col]
                    mat[r] = [
                        v - fac * u for v, u in zip(mat[r], mat[col])
                    ]
        if not ok:
            continue
        coeffs = tuple(mat[r][pts] for r in range(pts))
        if all(
            sum(c * x ** p for p, c in enumerate(coeffs)) == y
            for x, y in zip(xs, ys)
        ):
            return coeffs
    return None


def _eval_poly_vec(
    coeffs: Tuple[Fraction, ...], nvec: np.ndarray
) -> Optional[np.ndarray]:
    """Exact int64 evaluation of a rational polynomial over a vector of
    device counts; ``None`` if any value is non-integral."""
    den = 1
    for c in coeffs:
        den = den * c.denominator // int(np.gcd(den, c.denominator))
    acc = np.zeros(nvec.shape, np.int64)
    for c in reversed(coeffs):
        acc = acc * nvec + int(c * den)
    if den != 1 and (acc % den).any():
        return None
    return acc // den if den != 1 else acc


# ---------------------------------------------------------------------------
# representative rank classes (affine in n; fixed offsets from 0 and n)
# ---------------------------------------------------------------------------


def _rep_rules(step: int) -> List[Tuple[int, int]]:
    """Rank rules ``r = a + b*n`` covering group-class boundaries: the low
    ranks, node boundaries (one and two nodes in), and their mirrors at the
    top.  Distinct and in-range whenever n exceeds the small-regime
    cutoff."""
    s = max(step, 1)
    rules = [
        (0, 0), (1, 0), (2, 0), (3, 0),
        (s - 1, 0), (s, 0), (s + 1, 0),
        (2 * s - 1, 0), (2 * s, 0), (2 * s + 1, 0),
        (-2 * s, 1), (-s - 1, 1), (-s, 1), (-s + 1, 1),
        (-2, 1), (-1, 1),
    ]
    seen = set()
    out = []
    for r in rules:
        # a + 1*n >= n for a >= 0: never a valid rank (hit when step == 1
        # collapses the mirror rules onto the top boundary)
        if r[1] == 1 and r[0] >= 0:
            continue
        if r not in seen:
            seen.add(r)
            out.append(r)
    return out


_EFIELDS = ("m", "dst0", "dstep", "slot0", "sstep_j", "sstep_k", "epochs",
            "dw", "addr0")
_WFIELDS = ("m", "addr0", "astep_j", "astep_k", "epochs")


def _snapshot(
    sc: Scenario, rules: Sequence[Tuple[int, int]]
) -> Tuple[Dict[tuple, int], str, List[_EFam], List[_WFam]]:
    """Full-rank extraction + model snapshot at one concrete device count.

    Returns (field values keyed by (rule, kind, site-index, field), a
    structural signature that must match across probes, and the full-rank
    family lists for the concrete probe check)."""
    n = sc.cfg.n_devices
    progs = []
    for d in range(n):
        programs = sc.programs_for(d)
        sp = as_symbolic(programs[0].phases) if programs else None
        progs.append(
            sp if sp is not None else (programs[0].phases if programs else ())
        )
    efams, wfams = _extract_all(progs, n)
    marks = np.zeros(n, np.int64)
    for f in efams:
        dlo, dhi, _ = _fam_dst_range(f)
        if f.dw == 0 or f.raw or dlo < 0 or dhi >= n:
            continue
        if f.dstep == 0:
            marks[f.dst0] += f.m * f.epochs * f.dw
        else:
            marks[f.dst0 + f.dstep * np.arange(f.m)] += f.epochs * f.dw
    vals: Dict[tuple, int] = {}
    amap = sc.amap
    base, unit = _flag_linear(amap, n)
    vals[("amap", "base")] = base
    vals[("amap", "unit")] = unit
    vals[("amap", "flag_slots")] = amap.flag_slots
    vals[("amap", "partial_base")] = amap.partial_base
    vals[("amap", "flag_end")] = amap.flag_region()[1]
    sig_parts = [f"u{unit}"]
    by_rank_e: Dict[int, List[_EFam]] = {}
    by_rank_w: Dict[int, List[_WFam]] = {}
    for f in efams:
        by_rank_e.setdefault(f.writer, []).append(f)
    for f in wfams:
        by_rank_w.setdefault(f.rank, []).append(f)
    for rule in rules:
        r = rule[0] + rule[1] * n
        if not 0 <= r < n:
            raise _Unmodeled(f"rep rank rule {rule} out of range at n={n}")
        re_ = by_rank_e.get(r, [])
        rw = by_rank_w.get(r, [])
        sig_parts.append(
            f"{rule}:"
            + ",".join(f"{f.site}{'R' if f.raw else ''}" for f in re_)
            + "|" + ",".join(f.site for f in rw)
        )
        vals[(rule, "marks")] = int(marks[r])
        for i, f in enumerate(re_):
            for fld in _EFIELDS:
                vals[(rule, "e", i, fld)] = int(getattr(f, fld))
        for i, f in enumerate(rw):
            for fld in _WFIELDS:
                vals[(rule, "w", i, fld)] = int(getattr(f, fld))
    return vals, ";".join(sig_parts), efams, wfams


# ---------------------------------------------------------------------------
# vectorized parametric scan over all candidate device counts
# ---------------------------------------------------------------------------


def _parametric_scan(
    models: Dict[tuple, np.ndarray],
    shapes: Dict[tuple, dict],
    rules: Sequence[Tuple[int, int]],
    nvec: np.ndarray,
    include_marks: bool,
) -> Optional[Tuple[int, str]]:
    """Evaluate every layout check over the whole candidate range at once.

    ``models`` maps snapshot keys to int64 vectors (one entry per candidate
    n); ``shapes[(rule, kind)]`` records how many sites each rep rank
    carries.  Returns ``(smallest suspect n, hint)`` or ``None`` when every
    check holds everywhere."""
    base = models[("amap", "base")]
    unit = models[("amap", "unit")]
    slots_cap = models[("amap", "flag_slots")]
    pbase = models[("amap", "partial_base")]
    fend = models[("amap", "flag_end")]
    suspect = np.zeros(nvec.shape, bool)
    hints: List[Tuple[int, str]] = []

    def flag(mask: np.ndarray, hint: str) -> None:
        if mask.any():
            hints.append((int(nvec[int(np.argmax(mask))]), hint))
            np.logical_or(suspect, mask, out=suspect)

    flag(fend > pbase, "flag region crosses partial_base")

    def efam_vecs(rule, i):
        return {
            fld: models[(rule, "e", i, fld)] for fld in _EFIELDS
        }

    for rule in rules:
        rank = rule[0] + rule[1] * nvec
        n_e = shapes[(rule, "e")]
        fams = [efam_vecs(rule, i) for i in range(n_e)]
        raws = shapes[(rule, "eraw")]
        for i, f in enumerate(fams):
            if raws[i]:
                flag(
                    (f["addr0"] >= base) & (f["addr0"] < fend),
                    "raw emission inside flag pool",
                )
                continue
            dj = (f["m"] - 1) * f["sstep_j"]
            dk = (f["epochs"] - 1) * f["sstep_k"]
            slo = f["slot0"] + np.minimum(0, dj) + np.minimum(0, dk)
            shi = f["slot0"] + np.maximum(0, dj) + np.maximum(0, dk)
            dlast = f["dst0"] + (f["m"] - 1) * f["dstep"]
            dlo = np.minimum(f["dst0"], dlast)
            dhi = np.maximum(f["dst0"], dlast)
            flag((dlo < 0) | (dhi >= nvec), "emission dst out of range")
            flag((slo < 0) | (shi >= slots_cap), "flag slot capacity")
            flag(
                (f["epochs"] > 1) & (f["sstep_k"] == 0),
                "same flag rewritten across loop epochs",
            )
            flag(
                (f["m"] > 1) & (f["dstep"] == 0) & (f["sstep_j"] == 0),
                "duplicated emission members",
            )
            # marker alias against every representative destination class
            if include_marks:
                for drule in rules:
                    d = drule[0] + drule[1] * nvec
                    t = d - f["dst0"]
                    dstep = f["dstep"]
                    jm = np.where(
                        dstep != 0, t // np.where(dstep == 0, 1, dstep), 0
                    )
                    member = np.where(
                        dstep == 0,
                        t == 0,
                        (t % np.where(dstep == 0, 1, dstep) == 0)
                        & (jm >= 0) & (jm < f["m"]),
                    )
                    if not member.any():
                        continue
                    sl = f["slot0"] + jm * f["sstep_j"]
                    lo = base + unit * ((sl + np.minimum(0, dk)) * nvec
                                        + rank)
                    hi = base + unit * ((sl + np.maximum(0, dk)) * nvec
                                        + rank)
                    st = np.where(
                        f["epochs"] > 1,
                        unit * nvec * np.abs(f["sstep_k"]), 0,
                    )
                    s = np.maximum(st, 1)
                    first = lo + ((pbase - lo + s - 1) // s) * s
                    first = np.maximum(first, lo)
                    wend_d = pbase + 64 * models[(drule, "marks")]
                    flag(
                        member & (first <= hi) & (first < wend_d),
                        "data-marker writes reach the flag pool",
                    )
        # same-writer pairwise slot reuse (representative writer classes)
        for i in range(n_e):
            if raws[i]:
                continue
            for jx in range(i + 1, n_e):
                if raws[jx]:
                    continue
                a, b = fams[i], fams[jx]

                def rng(f):
                    dj = (f["m"] - 1) * f["sstep_j"]
                    dk = (f["epochs"] - 1) * f["sstep_k"]
                    slo = f["slot0"] + np.minimum(0, dj) + np.minimum(0, dk)
                    shi = f["slot0"] + np.maximum(0, dj) + np.maximum(0, dk)
                    g = np.gcd(
                        np.where(f["m"] > 1, np.abs(f["sstep_j"]), 0),
                        np.where(f["epochs"] > 1, np.abs(f["sstep_k"]), 0),
                    )
                    dlast = f["dst0"] + (f["m"] - 1) * f["dstep"]
                    return (
                        slo, shi, g,
                        np.minimum(f["dst0"], dlast),
                        np.maximum(f["dst0"], dlast),
                        np.where(f["m"] > 1, np.abs(f["dstep"]), 0),
                    )

                sa_lo, sa_hi, ga, da_lo, da_hi, gda = rng(a)
                sb_lo, sb_hi, gb, db_lo, db_hi, gdb = rng(b)
                d_int = (da_hi >= db_lo) & (db_hi >= da_lo)
                gd = np.gcd(gda, gdb)
                d_hit = d_int & np.where(
                    gd == 0, da_lo == db_lo,
                    (db_lo - da_lo) % np.maximum(gd, 1) == 0,
                )
                s_int = (sa_hi >= sb_lo) & (sb_hi >= sa_lo)
                gs = np.gcd(ga, gb)
                s_hit = s_int & np.where(
                    gs == 0, sa_lo == sb_lo,
                    (sb_lo - sa_lo) % np.maximum(gs, 1) == 0,
                )
                flag(d_hit & s_hit, "two emission instances share a slot")
    if not suspect.any():
        return None
    n_hat = int(nvec[int(np.argmax(suspect))])
    hint = min(hints, key=lambda h: h[0])[1]
    return n_hat, hint


# ---------------------------------------------------------------------------
# the prover
# ---------------------------------------------------------------------------


def _resolve_class(scenario: ScenarioRef) -> type:
    if isinstance(scenario, str):
        return get_scenario(scenario)
    if isinstance(scenario, type) and issubclass(scenario, Scenario):
        return scenario
    raise TypeError(
        "prove_layout needs a registered scenario name or Scenario subclass"
    )


def _construct(cls: type, n: int, params: dict) -> Scenario:
    cfg = SimConfig().with_devices(n)
    return cls(cfg, **params)


def _probe_counts(cands: List[int], cutoff: int) -> List[int]:
    """Geometric ladder of probe counts through the large regime, densified
    to at least six points so cubic models keep two held-out probes."""
    large = [c for c in cands if c > cutoff]
    if not large:
        return []
    probes = []
    target = large[0]
    while target <= large[-1]:
        idx = min(
            range(len(large)), key=lambda i: abs(large[i] - target)
        )
        probes.append(large[idx])
        target *= 2
    probes.append(large[-1])
    probes = sorted(set(probes))
    while len(probes) < min(6, len(large)):
        gaps = [
            (large.index(b) - large.index(a), a, b)
            for a, b in zip(probes, probes[1:])
        ]
        width, a, b = max(gaps)
        if width < 2:
            extra = [c for c in large if c not in probes]
            if not extra:
                break
            probes.append(extra[0])
        else:
            probes.append(large[(large.index(a) + large.index(b)) // 2])
        probes = sorted(set(probes))
    return probes


def prove_layout(
    scenario: ScenarioRef,
    *,
    devices_per_node: Optional[int] = None,
    fabric: Optional[str] = None,
    max_devices: Optional[int] = None,
    ordering: bool = True,
    **params,
) -> LayoutProof:
    """Prove one scenario's layout for every constructible device count.

    Sweeps n over multiples of ``devices_per_node`` (all counts when no node
    shape is given) up to ``max_devices`` (default: the scenario class's
    declared bound).  Small counts are checked exhaustively; the large
    regime goes through representative-rank polynomial models evaluated
    vectorized over every candidate, with full-rank concrete checks at the
    probe counts the models are fitted from.  Any parametric suspicion is
    re-confirmed concretely so findings carry exact blame and the smallest
    failing n.  Ordering (obligation (c)) is discharged statically for
    existence and via the loop-space planner at probe counts.
    """
    cls = _resolve_class(scenario)
    name = getattr(cls, "name", "") or cls.__name__
    bound = int(max_devices or getattr(cls, "max_devices", 4096))
    step = int(devices_per_node) if devices_per_node else 1
    kw = dict(params)
    kw.setdefault("closed_loop", True)
    if devices_per_node is not None:
        kw.setdefault("devices_per_node", devices_per_node)
    if fabric is not None:
        kw.setdefault("fabric", fabric)
    proof = LayoutProof(
        scenario=name, devices_per_node=devices_per_node, fabric=fabric,
        max_devices=bound,
    )
    notes: List[str] = []

    def build(n: int) -> Optional[Scenario]:
        try:
            return _construct(cls, n, kw)
        except TypeError as e:
            raise ValueError(
                f"{name} does not accept the closed-loop parameters the "
                f"layout prover sweeps ({e})"
            ) from e
        except (ValueError, NotImplementedError):
            return None

    cands = [n for n in range(max(step, 2), bound + 1, step)]
    if step == 1 and cands and cands[0] < 2:
        cands = [n for n in cands if n >= 2]
    built = []
    for n in cands[:64]:
        sc = build(n)
        if sc is not None:
            built.append((n, sc))
            break
    if not built:
        proof.findings.append(LayoutFinding(
            "layout-shape", "warning",
            f"no constructible device count in the first 64 candidates "
            f"(step {step}); nothing to prove",
        ))
        proof.notes = tuple(notes)
        return proof

    cutoff = min(bound, max(48, 6 * step))
    checked: List[int] = []
    ordered: List[int] = []
    seen_warn: set = set()

    def fold(fs: List[LayoutFinding]) -> bool:
        """Collect findings (warnings deduped across counts); True on
        error."""
        err = False
        for f in fs:
            if f.severity == "error":
                proof.findings.append(f)
                err = True
            elif (f.kind, f.message) not in seen_warn:
                seen_warn.add((f.kind, f.message))
                proof.findings.append(f)
        return err

    def concrete(n: int, sc: Optional[Scenario] = None) -> bool:
        """Full exhaustive check at one count; True when errors found."""
        sc = sc or build(n)
        if sc is None:
            return False
        checked.append(n)
        return fold(check_layout(sc))

    first_n, first_sc = built[0]
    for n in cands:
        if n > cutoff:
            break
        sc = first_sc if n == first_n else None
        if concrete(n, sc):
            proof.checked_counts = tuple(checked)
            proof.notes = tuple(notes)
            return proof

    large = [c for c in cands if c > cutoff]
    if large:
        rules = _rep_rules(step)
        probes = _probe_counts(cands, cutoff)
        snaps: List[Tuple[int, Dict[tuple, int]]] = []
        sig0: Optional[str] = None
        modeled = True
        last_clean = max((c for c in cands if c <= cutoff), default=None)

        def first_failure(lo_n: Optional[int], hi_n: int) -> None:
            """Bisect (lo_n, hi_n] for the smallest failing count (layout
            violations grow monotonically with the flag pool) and fold its
            findings, so blame always carries the first aliasing n."""
            span = [
                c for c in cands
                if (lo_n is None or c > lo_n) and c <= hi_n
            ]
            lo, hi = 0, len(span) - 1  # span[hi] is known-failing
            while lo < hi:
                mid = (lo + hi) // 2
                sc_m = build(span[mid])
                fs_m = check_layout(sc_m) if sc_m is not None else []
                checked.append(span[mid])
                if any(f.severity == "error" for f in fs_m):
                    hi = mid
                else:
                    lo = mid + 1
            sc_b = build(span[hi])
            fold(check_layout(sc_b) if sc_b is not None else [])

        for pn in probes:
            sc = build(pn)
            if sc is None:
                notes.append(f"probe n={pn}: shape not constructible")
                continue
            try:
                vals, sig, efams, wfams = _snapshot(sc, rules)
            except _Unmodeled as e:
                proof.findings.append(LayoutFinding(
                    "layout-unmodeled", "warning", str(e), n_devices=pn,
                ))
                modeled = False
                break
            reps = sorted({
                r[0] + r[1] * pn for r in rules if 0 <= r[0] + r[1] * pn < pn
            })
            fs = _check_families(
                pn, sc.amap, efams, wfams,
                include_marks=sc.cfg.include_data_writes,
                coverage_dsts=reps,
            )
            checked.append(pn)
            if any(f.severity == "error" for f in fs):
                first_failure(last_clean, pn)
                proof.checked_counts = tuple(sorted(set(checked)))
                proof.probe_counts = tuple(p for p, _ in snaps)
                proof.notes = tuple(notes)
                return proof
            fold(fs)
            last_clean = pn
            if sig0 is None:
                sig0 = sig
            elif sig != sig0:
                notes.append(
                    f"program structure changes shape at n={pn}; "
                    "falling back to dense concrete checks"
                )
                modeled = False
                break
            snaps.append((pn, vals))
        include_marks = first_sc.cfg.include_data_writes
        if modeled and len(snaps) >= 4:
            xs = [p for p, _ in snaps]
            keys = set(snaps[0][1])
            if any(set(v) != keys for _, v in snaps):
                modeled = False
            if modeled:
                nvec = np.array(large, np.int64)
                models: Dict[tuple, np.ndarray] = {}
                pb_key = ("amap", "partial_base")
                for key in keys:
                    if key == pb_key:
                        continue
                    ys = [v[key] for _, v in snaps]
                    coeffs = _fit_poly(xs, ys)
                    vec = (
                        _eval_poly_vec(coeffs, nvec)
                        if coeffs is not None else None
                    )
                    if vec is None:
                        notes.append(
                            f"descriptor {key} does not interpolate as a "
                            "polynomial in n; falling back to dense checks"
                        )
                        modeled = False
                        break
                    models[key] = vec
            if modeled:
                # partial_base is piecewise, not polynomial, on cleared
                # maps: max(default base, flag region end rounded up to a
                # page) — verify that clearance form at every probe, and
                # fall back to a plain polynomial (legacy constant maps)
                pb_ys = [v[pb_key] for _, v in snaps]
                fend_ys = [v[("amap", "flag_end")] for _, v in snaps]
                page = 0x1000
                floor_pb = min(pb_ys)

                def pageup(x):
                    return (x + page - 1) // page * page

                if all(
                    pb == max(floor_pb, pageup(fe))
                    for pb, fe in zip(pb_ys, fend_ys)
                ):
                    models[pb_key] = np.maximum(
                        floor_pb,
                        (models[("amap", "flag_end")] + page - 1)
                        // page * page,
                    )
                else:
                    coeffs = _fit_poly(xs, pb_ys)
                    vec = (
                        _eval_poly_vec(coeffs, nvec)
                        if coeffs is not None else None
                    )
                    if vec is None:
                        notes.append(
                            "partial_base follows neither the clearance "
                            "form nor a polynomial; falling back to dense "
                            "checks"
                        )
                        modeled = False
                    else:
                        models[pb_key] = vec
            if modeled:
                # clearance-form sanity: a with_partial_clearance() map must
                # keep partial_base at/above the flag region end everywhere
                shapes: Dict[tuple, object] = {}
                for rule in rules:
                    sites = [
                        k for k in keys
                        if k[0] == rule and len(k) == 4 and k[1] == "e"
                        and k[3] == "m"
                    ]
                    n_e = len(sites)
                    shapes[(rule, "e")] = n_e
                    shapes[(rule, "eraw")] = [
                        bool(models[(rule, "e", i, "addr0")].any())
                        for i in range(n_e)
                    ]
                hit = _parametric_scan(
                    models, shapes, rules, nvec, include_marks
                )
                proof.parametric = True
                proof.probe_counts = tuple(xs)
                if hit is not None:
                    n_hat, hint = hit
                    confirm = [c for c in large if c >= n_hat][:16]
                    for cn in confirm:
                        sc = build(cn)
                        if sc is not None and concrete(cn, sc):
                            break
                    else:
                        proof.findings.append(LayoutFinding(
                            "layout-overlap", "error",
                            f"parametric models flag a layout violation "
                            f"({hint}) starting at n={n_hat}, but the "
                            f"concrete checker could not localize it — "
                            f"treat the layout as unproven at pod scale",
                            n_devices=n_hat,
                        ))
        if not modeled:
            proof.parametric = False
            dense = [
                large[min(len(large) - 1, round(i * (len(large) - 1) / 11))]
                for i in range(12)
            ]
            prev = last_clean
            for dn in sorted(set(dense)):
                sc = build(dn)
                if sc is None:
                    continue
                fs = check_layout(sc)
                checked.append(dn)
                if any(f.severity == "error" for f in fs):
                    first_failure(prev, dn)
                    break
                fold(fs)
                prev = dn
            notes.append(
                "large regime covered by dense concrete checks only "
                f"(at {sorted(set(dense))}); no parametric certificate"
            )

    # happens-before: the loop-space planner proves every wait family is
    # consumed by a strictly-earlier emission family (total order)
    if ordering and not any(f.severity == "error" for f in proof.findings):
        from .verify import verify_symbolic

        order_ns = [first_n]
        mid = [c for c in cands if c >= min(cutoff, bound)]
        if mid and mid[0] != first_n:
            order_ns.append(mid[0])
        for on in order_ns:
            sc = build(on)
            if sc is None:
                continue
            v = verify_symbolic(sc)
            ordered.append(on)
            for f in v.findings:
                if f.severity == "error":
                    proof.findings.append(LayoutFinding(
                        "unmatched-wait-family", "error", f.message,
                        n_devices=on,
                    ))

    proof.checked_counts = tuple(sorted(set(checked)))
    proof.ordering_counts = tuple(ordered)
    proof.notes = tuple(notes)
    return proof


# ---------------------------------------------------------------------------
# registry driver (the registration-time obligation's discharge point)
# ---------------------------------------------------------------------------


def prove_registry(
    *,
    max_devices: int = 4096,
    devices_per_node: int = 4,
    fabrics: Optional[Sequence[Optional[str]]] = None,
    quiet: bool = True,
) -> List[LayoutProof]:
    """Discharge every registered closed-loop scenario's layout obligation.

    Runs the full parametric proof once per scenario (layout depends on the
    address map and programs, not the fabric), then re-attests each fabric
    preset cheaply: the family snapshot at one probe count must be identical
    to the fabric-less one, which it records as a note.  A preset that
    cannot construct the probe shape is noted and skipped.
    """
    from repro.core.interconnect import list_fabrics
    from repro.core.scenario import LAYOUT_PROOF_OBLIGATIONS

    list_scenarios()  # load builtins so obligations are recorded
    if fabrics is None:
        fabrics = [None, *list_fabrics()]
    proofs: List[LayoutProof] = []
    step = max(devices_per_node, 1)
    fp_n = min(max_devices, max(64, 8 * step))
    fp_n -= fp_n % step
    rules = _rep_rules(step)
    for name in list(LAYOUT_PROOF_OBLIGATIONS):
        cls = get_scenario(name)
        base_proof = prove_layout(
            name, devices_per_node=devices_per_node,
            max_devices=max_devices,
        )
        proofs.append(base_proof)
        if not quiet:
            print(base_proof.render())
        fp0 = None
        try:
            sc = _construct(cls, fp_n, {
                "closed_loop": True, "devices_per_node": devices_per_node,
            })
            fp0 = _snapshot(sc, rules)[:2]  # numeric fields + structure
        except (ValueError, NotImplementedError, _Unmodeled):
            fp0 = None
        for fab in fabrics:
            if fab is None:
                continue
            try:
                sc = _construct(cls, fp_n, {
                    "closed_loop": True,
                    "devices_per_node": devices_per_node,
                    "fabric": fab,
                })
                fp = _snapshot(sc, rules)[:2]
            except (ValueError, NotImplementedError, _Unmodeled) as e:
                proofs.append(LayoutProof(
                    scenario=name, devices_per_node=devices_per_node,
                    fabric=fab, max_devices=max_devices,
                    notes=(
                        f"fabric {fab}: probe shape n={fp_n} not "
                        f"constructible ({e}); layout is fabric-independent",
                    ),
                ))
                continue
            if fp0 is not None and fp == fp0:
                att = replace_proof_fabric(base_proof, fab, fp_n)
            else:
                att = prove_layout(
                    name, devices_per_node=devices_per_node, fabric=fab,
                    max_devices=max_devices,
                )
            proofs.append(att)
            if not quiet and not att.ok:
                print(att.render())
    return proofs


def replace_proof_fabric(
    base: LayoutProof, fabric: str, probe_n: int
) -> LayoutProof:
    """Re-attest a fabric preset against the fabric-less proof: identical
    family snapshot at the probe count means identical layout everywhere."""
    att = LayoutProof(
        scenario=base.scenario, devices_per_node=base.devices_per_node,
        fabric=fabric, max_devices=base.max_devices,
        findings=list(base.findings),
        checked_counts=base.checked_counts,
        probe_counts=base.probe_counts,
        ordering_counts=base.ordering_counts,
        parametric=base.parametric,
    )
    att.notes = (*base.notes, (
        f"fabric {fabric}: family snapshot at n={probe_n} is identical to "
        "the fabric-less layout; proof re-attested without a second sweep"
    ))
    return att
