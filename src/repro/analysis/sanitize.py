"""Runtime traffic sanitizer: physical-consistency checks on a closed loop.

The static verifier (:mod:`repro.analysis.verify`) reasons about programs;
this module cross-checks the *engines*: with ``Cluster(sanitize=True)`` a
:class:`TrafficSanitizer` shadows every emission and every enacted directory
write and, at the end of the run, asserts three invariants the fabric and
calendar accounting must uphold:

* **byte conservation** — the fabric's global and per-link-class
  ``*_messages`` / ``*_bytes`` counters equal an independent re-walk of each
  emission over :meth:`FabricModel.legs` (catching divergence between the
  sequential and the vectorized ``transfer_batch`` pricing paths);
* **monotonic calendar cycles** — no device ever enacts a write at an earlier
  cycle than a previous one (the engines' intra-cycle ordering contract);
* **exactly-once flag delivery** — every emitted or seeded flag write is
  enacted at its destination directory exactly once, no more, no fewer.

The shadow state is append-only and the hooks never touch simulated state, so
a sanitized run stays bit-identical to an unsanitized one (asserted against
the committed bench rows in the tests).  Violations raise
:class:`SanitizerError` listing every broken invariant.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

__all__ = ["SanitizerError", "TrafficSanitizer"]


class SanitizerError(RuntimeError):
    """One or more physical-consistency invariants failed after a run."""


class TrafficSanitizer:
    """Shadow accounting for one :class:`repro.core.cluster.Cluster` run."""

    def __init__(self, amap, fabric, n_devices: int):
        self.amap = amap
        self.fabric = fabric
        self.n_devices = n_devices
        # mirrors FabricModel.stats' integer keys (queued_ns is timing, not
        # conservation — the fabric owns it)
        self.expected: Dict[str, int] = {"messages": 0, "bytes": 0}
        for name in fabric.spec.link_classes:
            self.expected[name + "_messages"] = 0
            self.expected[name + "_bytes"] = 0
        # (dst device, addr) -> flag writes put in flight / enacted
        self.expected_flags: Dict[Tuple[int, int], int] = {}
        self.enacted_flags: Dict[Tuple[int, int], int] = {}
        self._last_cycle: List[int] = [-1] * n_devices
        self.violations: List[str] = []

    # ------------------------------------------------------------------
    # hooks (called by the Cluster; must never mutate simulated state)
    # ------------------------------------------------------------------

    def observer_for(self, device: int) -> Callable[[int, int, int, int], None]:
        """A :meth:`DirectoryMemory.add_write_observer` callback for one
        device: checks calendar monotonicity and tallies flag enactments."""

        def observe(addr: int, data: int, size: int, cycle: int) -> None:
            last = self._last_cycle[device]
            if cycle < last:
                self.violations.append(
                    f"calendar ran backwards on device {device}: write at "
                    f"0x{addr:x} enacted at cycle {cycle} after cycle {last}"
                )
            else:
                self._last_cycle[device] = cycle
            if self.amap.is_flag(addr):
                key = (device, addr)
                self.enacted_flags[key] = self.enacted_flags.get(key, 0) + 1

        return observe

    def note_seed_write(self, device: int, addr: int) -> None:
        """A pre-scheduled trace write registered into ``device``'s WTT."""
        if self.amap.is_flag(addr):
            key = (device, addr)
            self.expected_flags[key] = self.expected_flags.get(key, 0) + 1

    def note_emission(
        self,
        src: int,
        dst: int,
        addr: int,
        nbytes: int,
        issue_ns: float,
        arrival_ns: float,
    ) -> None:
        """One routed emission: re-walk its legs and expect its flag."""
        nb = max(0, nbytes)
        self.expected["messages"] += 1
        self.expected["bytes"] += nb
        # legs() is memoized and stat-free, so this re-walk cannot perturb
        # the fabric's own accounting
        for leg in self.fabric.legs(src, dst):
            self.expected[leg.cls + "_messages"] += 1
            self.expected[leg.cls + "_bytes"] += nb
        if arrival_ns < issue_ns:
            self.violations.append(
                f"acausal transfer {src} -> {dst}: issued at {issue_ns}ns "
                f"but arrived at {arrival_ns}ns"
            )
        if self.amap.is_flag(addr):
            key = (dst, addr)
            self.expected_flags[key] = self.expected_flags.get(key, 0) + 1

    # ------------------------------------------------------------------
    # the end-of-run verdict
    # ------------------------------------------------------------------

    def check(self) -> None:
        """Raise :class:`SanitizerError` if any invariant was violated."""
        problems = list(self.violations)
        stats = self.fabric.stats
        for key in sorted(self.expected):
            got = stats.get(key, 0)
            want = self.expected[key]
            if got != want:
                problems.append(
                    f"byte conservation: fabric stat {key!r} is {got} but "
                    f"leg accounting of the emissions expects {want}"
                )
        for key in sorted(set(self.expected_flags) | set(self.enacted_flags)):
            want = self.expected_flags.get(key, 0)
            got = self.enacted_flags.get(key, 0)
            if got != want:
                device, addr = key
                decoded = self.amap.decode_flag(addr)
                what = f"flag 0x{addr:x}"
                if decoded is not None:
                    what = f"flag(src={decoded[0]}, slot={decoded[1]})"
                problems.append(
                    f"flag delivery: {what} on device {device} enacted "
                    f"{got}x but {want} write(s) were put in flight"
                )
        if problems:
            raise SanitizerError(
                "traffic sanitizer found "
                f"{len(problems)} violation(s):\n  " + "\n  ".join(problems)
            )
