"""Static checks over the lowered wait/emit graph.

Four check families, all running in milliseconds and without an engine:

* **deadlock** — a monotone fixpoint over the lanes (flags are sticky, so
  satisfiability is timing-independent): advance every lane while its next
  phase's flags are available, firing emissions as phases complete with the
  cluster's exact coalescing semantics ("each" per lane completion, "last"
  when the whole device's workgroup count passes the phase).  Lanes still
  stuck at the fixpoint are deadlocked; Tarjan's SCC over their wait-for
  graph yields the blame cycles, reported as rank/phase/flag chains.
* **unmatched synchronization** — waits on flags no rank (or trace) ever
  writes; emits into the flag region no rank ever awaits; duplicate emits to
  a flag with a single consuming wait (count mismatch).
* **flag-slot write races** — two emit sites targeting the same flag key with
  no happens-before path between them (program order within a lane, plus
  single-emitter wait edges across lanes).
* **fabric reachability** — every emission's ``(src, dst)`` pair must be
  routable on the resolved :class:`repro.core.interconnect.InterconnectSpec`
  (catches presets whose routing policy cannot serve a scenario's traffic).

:func:`verify_scenario` is the public entry point; it mirrors
:func:`repro.core.scenario.simulate`'s resolution (name/class/instance plus
``devices``/``nodes``/``devices_per_node`` shape sugar) and returns a
:class:`Verdict`.  :func:`diagnose_deadlock` is the runtime hook: the engines
embed its blame-chain rendering into :class:`EidolaDeadlock` messages.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.config import SimConfig
from repro.core.scenario import (
    Scenario,
    ScenarioLike,
    _resolve,
    _resolve_shape,
)

from .program_graph import EmitSite, FlagKey, ProgramGraph, WaitSite

__all__ = [
    "Finding",
    "Verdict",
    "verify_graph",
    "verify_scenario",
    "verify_symbolic",
    "diagnose_deadlock",
]

# finding kinds that predict an EidolaDeadlock at runtime
_DEADLOCK_KINDS = frozenset(
    {"deadlock-cycle", "unmatched-wait", "unsatisfiable-wait"}
)


@dataclass(frozen=True)
class Finding:
    """One verifier diagnosis: a kind tag, a severity, and the blame text."""

    kind: str
    severity: str  # "error" | "warning"
    message: str

    def render(self) -> str:
        return f"[{self.severity}] {self.kind}: {self.message}"


@dataclass
class Verdict:
    """The verifier's result for one scenario instance on one fabric."""

    scenario: str
    n_devices: int
    fabric: Optional[str] = None
    findings: List[Finding] = field(default_factory=list)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warning"]

    @property
    def ok(self) -> bool:
        return not self.errors

    @property
    def deadlock(self) -> bool:
        """True when the program cannot terminate (the runtime engines would
        raise :class:`repro.core.target.EidolaDeadlock`)."""
        return any(f.kind in _DEADLOCK_KINDS for f in self.findings)

    def render(self) -> str:
        head = (
            f"verify {self.scenario!r} ({self.n_devices} devices"
            + (f", fabric {self.fabric!r}" if self.fabric else "")
            + "): "
        )
        if not self.findings:
            return head + "ok"
        lines = [head + f"{len(self.errors)} error(s), "
                 f"{len(self.warnings)} warning(s)"]
        lines.extend("  " + f.render() for f in self.findings)
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# the deadlock fixpoint
# ---------------------------------------------------------------------------


@dataclass
class _Saturation:
    """State after running every lane as far as flag availability allows."""

    cursors: List[int]                       # per-lane next phase index
    flags: Set[FlagKey]                      # flag keys known set
    completions: Dict[Tuple[int, int], int]  # (device, phase_idx) -> wgs done
    stuck: List[int]                         # lane indices not run to the end


def _saturate(g: ProgramGraph) -> _Saturation:
    """Run the timing-free abstraction of the closed loop to its fixpoint.

    Flags are write-once-sticky and waits only observe set-ness, so whether
    every lane terminates is independent of the engines' timing — a monotone
    worklist suffices and is exact for the cluster's semantics.
    """
    flags: Set[FlagKey] = set(g.external_flags)
    cursors = [0] * len(g.lanes)
    completions: Dict[Tuple[int, int], int] = {}

    # emit sites indexed by (lane, phase_idx) so firing a phase is O(sites)
    sites_at: Dict[Tuple[int, int], List[Tuple[FlagKey, EmitSite]]] = {}
    for key, sites in g.emitters.items():
        for s in sites:
            sites_at.setdefault((s.lane, s.phase_idx), []).append((key, s))

    def fire(lane_idx: int, phase_idx: int, last_only: bool) -> None:
        for key, s in sites_at.get((lane_idx, phase_idx), ()):
            if (s.coalesce == "last") == last_only:
                flags.add(key)

    progress = True
    while progress:
        progress = False
        for li, lane in enumerate(g.lanes):
            while cursors[li] < len(lane.phases):
                ph = lane.phases[cursors[li]]
                if ph.wait_addrs and any(
                    (lane.device, a) not in flags for a in ph.wait_addrs
                ):
                    break
                idx = cursors[li]
                cursors[li] += 1
                progress = True
                key = (lane.device, idx)
                completions[key] = completions.get(key, 0) + lane.wg_count
                fire(li, idx, last_only=False)  # "each" emits: on completion
                if completions[key] >= g.device_wgs[lane.device]:
                    # "last" emits fire when the whole device passes the
                    # phase — from every lane of the device long enough to
                    # hold that phase index (matching Cluster._on_emit's
                    # workgroup-count threshold)
                    for lj in g.lanes_of[lane.device]:
                        if len(g.lanes[lj].phases) > idx:
                            fire(lj, idx, last_only=True)
    stuck = [
        li for li, lane in enumerate(g.lanes)
        if cursors[li] < len(lane.phases)
    ]
    return _Saturation(cursors, flags, completions, stuck)


def _site_fired(g: ProgramGraph, sat: _Saturation, s: EmitSite) -> bool:
    if s.coalesce == "each":
        return sat.cursors[s.lane] > s.phase_idx
    done = sat.completions.get((s.device, s.phase_idx), 0)
    return done >= g.device_wgs[s.device]


def _site_dead(g: ProgramGraph, s: EmitSite) -> bool:
    """True when a "last" emit can structurally never fire: some lane of the
    emitting device is too short to ever complete the phase, so the device's
    workgroup completion count cannot reach the threshold."""
    if s.coalesce != "last":
        return False
    reachable = sum(
        g.lanes[lj].wg_count
        for lj in g.lanes_of[s.device]
        if len(g.lanes[lj].phases) > s.phase_idx
    )
    return reachable < g.device_wgs[s.device]


def _tarjan(nodes: Sequence[int], edges: Dict[int, List[int]]) -> List[List[int]]:
    """Tarjan's strongly-connected components, iterative (deep cycles at
    fleet scale must not hit the recursion limit)."""
    index: Dict[int, int] = {}
    low: Dict[int, int] = {}
    on_stack: Set[int] = set()
    stack: List[int] = []
    sccs: List[List[int]] = []
    counter = [0]

    for root in nodes:
        if root in index:
            continue
        work: List[Tuple[int, int]] = [(root, 0)]
        while work:
            v, ei = work[-1]
            if ei == 0:
                index[v] = low[v] = counter[0]
                counter[0] += 1
                stack.append(v)
                on_stack.add(v)
            advanced = False
            succs = edges.get(v, [])
            while ei < len(succs):
                w = succs[ei]
                ei += 1
                if w not in index:
                    work[-1] = (v, ei)
                    work.append((w, 0))
                    advanced = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if low[v] == index[v]:
                scc: List[int] = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(scc)
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
    return sccs


# ---------------------------------------------------------------------------
# the individual checks
# ---------------------------------------------------------------------------


def _check_invalid_emits(g: ProgramGraph, out: List[Finding]) -> None:
    for msg in g.invalid_emits:
        out.append(Finding("invalid-emit", "error", msg))


def _check_unmatched(g: ProgramGraph, out: List[Finding]) -> None:
    for key in sorted(g.waiters):
        if key not in g.emitters and key not in g.external_flags:
            sites = g.waiters[key]
            out.append(Finding(
                "unmatched-wait",
                "error",
                f"{g.describe_key(key)} is never written by any rank or "
                f"trace; blocked: " + "; ".join(
                    s.describe() for s in sites[:4]
                ) + ("" if len(sites) <= 4 else f" (+{len(sites) - 4} more)"),
            ))
    for key in sorted(g.emitters):
        device, addr = key
        sites = g.emitters[key]
        # raw-address emits outside the flag region are data pushes, not
        # synchronization — only unawaited *flags* indicate a program bug
        if sites[0].slot is None:
            continue
        if key not in g.waiters:
            out.append(Finding(
                "unawaited-emit",
                "warning",
                f"{g.describe_key(key)} is emitted but no rank ever waits "
                "on it: " + "; ".join(s.describe() for s in sites[:4]),
            ))
        elif len(sites) > len(g.waiters[key]):
            out.append(Finding(
                "count-mismatch",
                "warning",
                f"{g.describe_key(key)} has {len(sites)} emit sites but "
                f"only {len(g.waiters[key])} wait site(s) — the flag is "
                "sticky, so later emissions are unobservable: "
                + "; ".join(s.describe() for s in sites),
            ))
    # vacuous re-waits: one lane waiting the same sticky flag twice
    for key in sorted(g.waiters):
        by_lane: Dict[int, List[WaitSite]] = {}
        for s in g.waiters[key]:
            by_lane.setdefault(s.lane, []).append(s)
        for sites in by_lane.values():
            idxs = sorted({s.phase_idx for s in sites})
            if len(idxs) > 1:
                out.append(Finding(
                    "count-mismatch",
                    "warning",
                    f"{g.describe_key(key)} is awaited at phases {idxs} of "
                    f"the same rank-{sites[0].device} program; the flag "
                    "stays set after the first wait, so the later waits "
                    "never synchronize",
                ))


def _hb_reachable(
    g: ProgramGraph,
    frm: Tuple[int, int],
    to: Tuple[int, int],
    succ: Dict[Tuple[int, int], List[Tuple[int, int]]],
) -> bool:
    """DFS over the happens-before DAG of (lane, phase_idx) nodes."""
    seen: Set[Tuple[int, int]] = set()
    stack = [frm]
    while stack:
        node = stack.pop()
        if node == to:
            return True
        if node in seen:
            continue
        seen.add(node)
        lane, idx = node
        if idx + 1 < len(g.lanes[lane].phases):
            stack.append((lane, idx + 1))
        stack.extend(succ.get(node, ()))
    return False


def _check_races(g: ProgramGraph, out: List[Finding]) -> None:
    # cross-lane happens-before edges: a wait phase observing a flag with
    # exactly one emit site orders that site before the wait; with several
    # sites any one write satisfies the wait, so no order is guaranteed
    succ: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for key, waits in g.waiters.items():
        sites = g.emitters.get(key, [])
        if len(sites) != 1:
            continue
        e = sites[0]
        for w in waits:
            succ.setdefault((e.lane, e.phase_idx), []).append(
                (w.lane, w.phase_idx)
            )
    for key in sorted(g.emitters):
        sites = g.emitters[key]
        if len(sites) < 2:
            continue
        for i in range(len(sites)):
            for j in range(i + 1, len(sites)):
                a, b = sites[i], sites[j]
                if a.lane == b.lane:
                    continue  # program order within the lane
                na, nb = (a.lane, a.phase_idx), (b.lane, b.phase_idx)
                if _hb_reachable(g, na, nb, succ) or _hb_reachable(
                    g, nb, na, succ
                ):
                    continue
                out.append(Finding(
                    "slot-race",
                    "error",
                    f"unordered writers to {g.describe_key(key)}: "
                    f"{a.describe()} vs {b.describe()} — no happens-before "
                    "path orders them, so the waiting rank may observe "
                    "either write first",
                ))


def _check_reachability(
    g: ProgramGraph, fabric, out: List[Finding]
) -> None:
    if fabric is None:
        return
    for src, dst in g.emit_pairs():
        if src == dst:
            out.append(Finding(
                "unreachable-pair",
                "error",
                f"rank {src} emits to itself; the fabric routes no "
                "self-loops (use a local write, not an EmitOp)",
            ))
            continue
        if not (0 <= dst < g.n_devices):
            out.append(Finding(
                "unreachable-pair",
                "error",
                f"emit destination {dst} is outside the {g.n_devices}-device "
                "fabric",
            ))
            continue
        try:
            legs = fabric.legs(src, dst)
        except Exception as e:  # routing policies raise their own types
            out.append(Finding(
                "unreachable-pair",
                "error",
                f"no route for emission {src} -> {dst} on fabric "
                f"{fabric.spec.name!r}: {e}",
            ))
            continue
        if not legs:
            out.append(Finding(
                "unreachable-pair",
                "error",
                f"fabric {fabric.spec.name!r} routes {src} -> {dst} over "
                "zero legs",
            ))


def _check_deadlock(g: ProgramGraph, out: List[Finding]) -> None:
    sat = _saturate(g)
    if not sat.stuck:
        return
    stuck_set = set(sat.stuck)
    # wait-for graph over stuck lanes: an edge L -> M means L's unsatisfied
    # flag has a pending emit site whose firing is held up by lane M
    edges: Dict[int, List[int]] = {}
    labels: Dict[Tuple[int, int], Tuple[WaitSite, EmitSite]] = {}
    blocked_sites: Dict[int, List[WaitSite]] = {}
    for li in sat.stuck:
        lane = g.lanes[li]
        ph = lane.phases[sat.cursors[li]]
        if not ph.wait_addrs:
            continue  # cannot happen: only waits block
        for a in ph.wait_addrs:
            key = (lane.device, a)
            if key in sat.flags:
                continue
            wsite = next(
                (
                    w for w in g.waiters.get(key, [])
                    if w.lane == li and w.phase_idx == sat.cursors[li]
                ),
                None,
            )
            if wsite is None:
                decoded_sites = g.waiters.get(key, [])
                wsite = decoded_sites[0] if decoded_sites else WaitSite(
                    lane.device, li, sat.cursors[li], ph.name, a
                )
            blocked_sites.setdefault(li, []).append(wsite)
            pending = [
                s for s in g.emitters.get(key, [])
                if not _site_fired(g, sat, s)
            ]
            live = [s for s in pending if not _site_dead(g, s)]
            if not pending and key not in g.emitters:
                continue  # reported by the unmatched-wait check
            if pending and not live:
                out.append(Finding(
                    "unsatisfiable-wait",
                    "error",
                    f"{wsite.describe()}, but every emitter of "
                    f"{g.describe_key(key)} is 'last'-coalesced on a device "
                    "whose workgroups can never all reach the emitting "
                    "phase",
                ))
                continue
            for s in live:
                holders = {s.lane}
                if s.coalesce == "last":
                    # any lane of the emitting device that has not passed
                    # the phase holds up the device-wide completion count
                    holders.update(
                        lj for lj in g.lanes_of[s.device]
                        if len(g.lanes[lj].phases) > s.phase_idx
                        and sat.cursors[lj] <= s.phase_idx
                    )
                for h in holders & stuck_set:
                    edges.setdefault(li, []).append(h)
                    labels.setdefault((li, h), (wsite, s))
    for targets in edges.values():
        targets.sort()
    sccs = _tarjan(sorted(stuck_set), edges)
    reported: Set[int] = set()
    for scc in sccs:
        if len(scc) == 1 and scc[0] not in edges.get(scc[0], []):
            continue
        member = set(scc)
        # walk one concrete cycle through the SCC for the blame chain
        start = min(scc)
        chain: List[Tuple[WaitSite, EmitSite]] = []
        seen_nodes: List[int] = []
        node = start
        while node not in seen_nodes:
            seen_nodes.append(node)
            nxt = next(
                (t for t in edges.get(node, []) if t in member), None
            )
            if nxt is None:
                break
            chain.append(labels[(node, nxt)])
            node = nxt
        if node in seen_nodes:
            # trim to the actual cycle portion
            k = seen_nodes.index(node)
            chain = chain[k:]
        parts = [
            f"{w.describe()} <- emitted by rank {e.device} "
            f"phase {e.phase_idx} {e.phase_name!r}"
            for w, e in chain
        ]
        out.append(Finding(
            "deadlock-cycle",
            "error",
            "wait-for cycle spanning ranks "
            + ",".join(str(g.lanes[li].device) for li in seen_nodes)
            + ": " + "; ".join(parts),
        ))
        reported.update(seen_nodes)
    # stuck lanes outside any cycle: blocked behind the cycle or behind an
    # unmatched flag (the latter already has its own finding)
    collateral = [
        li for li in sat.stuck
        if li not in reported and li in blocked_sites
        and any(
            (g.lanes[li].device, w.addr) in g.emitters
            for w in blocked_sites[li]
        )
        and edges.get(li)
    ]
    if reported and collateral:
        out.append(Finding(
            "deadlock-cycle",
            "warning",
            "additionally blocked behind the cycle: " + "; ".join(
                blocked_sites[li][0].describe() for li in collateral[:6]
            ),
        ))


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def verify_graph(
    g: ProgramGraph, *, fabric=None, scenario_name: Optional[str] = None
) -> Verdict:
    """Run every check over an already-lowered :class:`ProgramGraph`."""
    findings: List[Finding] = []
    _check_invalid_emits(g, findings)
    _check_unmatched(g, findings)
    _check_races(g, findings)
    _check_reachability(g, fabric, findings)
    _check_deadlock(g, findings)
    findings.sort(key=lambda f: (f.severity != "error", f.kind))
    return Verdict(
        scenario=scenario_name or g.scenario_name,
        n_devices=g.n_devices,
        fabric=fabric.spec.name if fabric is not None else None,
        findings=findings,
    )


def verify_scenario(
    scenario: ScenarioLike,
    cfg: Optional[SimConfig] = None,
    *,
    devices: Optional[int] = None,
    nodes: Optional[int] = None,
    devices_per_node: Optional[int] = None,
    **params,
) -> Verdict:
    """Statically verify one scenario instance; no simulation runs.

    Mirrors :func:`repro.core.scenario.simulate`'s resolution: ``scenario``
    may be a registered name, a Scenario subclass, or a ready instance, and
    any two of ``devices``/``nodes``/``devices_per_node`` fix the fabric
    shape.  Closed-loop scenarios additionally get the fabric-reachability
    check against the same resolved fabric the Cluster would route over
    (``fabric=``/``link_bw=`` scenario params included).
    """
    devices, dpn = _resolve_shape(devices, nodes, devices_per_node)
    if dpn is not None:
        params.setdefault("devices_per_node", dpn)
    if devices is not None:
        cfg = (cfg or SimConfig()).with_devices(devices)
    if isinstance(scenario, Scenario):
        if cfg is not None and cfg != scenario.cfg:
            raise ValueError(
                "scenario instance was built with a different SimConfig "
                "than the one passed to verify_scenario(); rebuild the "
                "scenario or drop the cfg/devices arguments"
            )
        cfg = scenario.cfg
    cfg = (cfg or SimConfig()).validate()
    sc = _resolve(scenario, cfg, params)
    g = ProgramGraph.from_scenario(sc)
    fabric = None
    if sc.closed_loop:
        from repro.core.cluster import resolve_cluster_fabric

        try:
            fabric = resolve_cluster_fabric(cfg, sc)
        except ValueError as e:
            v = Verdict(scenario=g.scenario_name, n_devices=g.n_devices)
            v.findings.append(Finding(
                "unreachable-pair",
                "error",
                f"fabric resolution failed: {e}",
            ))
            return v
    verdict = verify_graph(g, fabric=fabric)
    if sc.closed_loop:
        # concrete layout obligations at this instance's exact shape (the
        # all-n parametric form lives in prove_layout); findings merge into
        # the same verdict so the CLI --verify path reports both
        from .layout import check_layout

        for f in check_layout(sc):
            verdict.findings.append(Finding(f.kind, f.severity, f.message))
        verdict.findings.sort(
            key=lambda f: (f.severity != "error", f.kind)
        )
    return verdict


def _try_tiered_plan(cfg, sc) -> Optional[str]:
    """Compile the scenario through the tiered group-uniform lockstep
    planner; None on success (the plan's total instruction order proves
    deadlock freedom), else the compiler's refusal reason."""
    from repro.core.cluster import Cluster
    from repro.core.lockstep import LockstepEngine, lockstep_support

    try:
        cluster = Cluster(cfg, sc, collect_segments=False)
    except (ValueError, NotImplementedError) as e:
        return f"cluster construction failed: {e}"
    reason = lockstep_support(cluster)
    if reason is not None:
        return reason
    return LockstepEngine(cluster).compile()


def verify_symbolic(
    scenario: ScenarioLike,
    cfg: Optional[SimConfig] = None,
    *,
    devices: Optional[int] = None,
    nodes: Optional[int] = None,
    devices_per_node: Optional[int] = None,
    **params,
) -> Verdict:
    """Loop-space verification of rank-uniform symbolic programs.

    Instead of materializing every phase of every rank (O(devices x steps)
    wait/emit sites — 33M at 4096 devices for a flat ring), this lowers each
    rank's :class:`repro.core.scenario.SymbolicProgram` into one node per
    (lane, affine pattern) via :func:`repro.core.lockstep.plan_stages` and
    proves every wait family is consumed by a strictly *earlier* emission
    family (lexicographic order over (segment, iteration, body position)).
    For lockstep programs that is exactly the deadlock-freedom argument: a
    matched plan cannot cycle, because the wait-for relation is embedded in
    a total order.  Work and memory are O(segments x devices).

    Returns a clean :class:`Verdict` on success.  Programs outside the
    globally rank-uniform families get a second chance at *group* level:
    the tiered lockstep compiler (:mod:`repro.core.lockstep_tiered`)
    schedules group-uniform programs (leader/worker splits, per-stage
    groups) into one total instruction order, and a successful compile is
    the same deadlock-freedom argument — every wait column is consumed by
    a strictly earlier emission instance.  A program outside both lowering
    families yields a single ``symbolic-shape`` warning (severity
    "warning": such programs are covered by the materialized
    :func:`verify_scenario` instead); a rank-uniform program whose wait
    has no earlier matching emission is an error (the engines would
    deadlock).
    """
    from repro.core.lockstep import UnsupportedProgram, plan_stages
    from repro.core.scenario import as_symbolic

    devices, dpn = _resolve_shape(devices, nodes, devices_per_node)
    if dpn is not None:
        params.setdefault("devices_per_node", dpn)
    if devices is not None:
        cfg = (cfg or SimConfig()).with_devices(devices)
    if isinstance(scenario, Scenario):
        if cfg is not None and cfg != scenario.cfg:
            raise ValueError(
                "scenario instance was built with a different SimConfig "
                "than the one passed to verify_symbolic(); rebuild the "
                "scenario or drop the cfg/devices arguments"
            )
        cfg = scenario.cfg
    cfg = (cfg or SimConfig()).validate()
    sc = _resolve(scenario, cfg, params)
    name = sc.name or type(sc).__name__
    v = Verdict(scenario=name, n_devices=cfg.n_devices)

    def skip(msg: str) -> Verdict:
        v.findings.append(Finding("symbolic-shape", "warning", msg))
        return v

    if not sc.closed_loop:
        return skip("open-loop scenario: no per-rank programs to align")
    progs = []
    for d in range(cfg.n_devices):
        programs = sc.programs_for(d)
        if not programs:
            return skip(f"rank {d} has no workgroup programs")
        ph = programs[0].phases
        if any(p.phases is not ph for p in programs[1:]):
            return skip(
                f"rank {d} runs multiple lanes; loop-space lowering needs "
                "one shared program per rank"
            )
        sp = as_symbolic(ph)
        if sp is None:
            return skip(
                f"rank {d} runs a flat (non-symbolic) program; covered by "
                "the materialized verifier"
            )
        progs.append(sp)
    try:
        plan_stages(sc.amap, cfg.n_devices, progs)
    except UnsupportedProgram as e:
        msg = str(e)
        # an unmatched wait in a rank-uniform program means no earlier
        # stage ever writes the awaited flags — the engines would deadlock;
        # every other UnsupportedProgram is a shape outside the affine
        # families, which the materialized verifier covers instead
        if "no matching earlier emission" in msg:
            v.findings.append(Finding(
                "unmatched-wait",
                "error",
                f"loop-space matching failed: {msg} — no earlier emission "
                "family writes the awaited flag family, so every engine "
                "would deadlock at this wait",
            ))
            return v
        # outside the flat rank-uniform families: retry at group level
        # through the tiered compiler.  A group-level schedule failure is
        # NOT a deadlock verdict — cross-group pipelined chains are valid
        # programs the timeline engine runs fine — so it stays a warning
        # carrying the compiler's blame (group, rank, phase, flag).
        tiered_msg = _try_tiered_plan(cfg, sc)
        if tiered_msg is None:
            return v
        return skip(
            f"{msg}; group-level lowering also declined: {tiered_msg}"
        )
    except ValueError as e:  # address-map probing (bad slot/device)
        v.findings.append(Finding(
            "invalid-emit",
            "error",
            f"symbolic program probing failed: {e}",
        ))
    return v


def diagnose_deadlock(scenario: Scenario) -> Optional[str]:
    """Blame-chain rendering of the scenario's deadlock findings, or None.

    Called by the engines when they hit an empty-queue deadlock: the static
    analyzer explains *why* the wait-for graph cycled (or which flags are
    unmatched), which the runtime state alone cannot.
    """
    g = ProgramGraph.from_scenario(scenario)
    findings: List[Finding] = []
    _check_unmatched(g, findings)
    _check_deadlock(g, findings)
    blame = [f for f in findings if f.kind in _DEADLOCK_KINDS]
    if not blame:
        return None
    return "static analysis:\n" + "\n".join(
        "  " + f.render() for f in blame
    )
