"""Checkpoint store: msgpack index + zstd-compressed raw tensors.

Layout per step:
    <dir>/step_0000042/
        index.msgpack     # treedef paths, shapes, dtypes, checksums
        data.bin.zst      # concatenated tensor bytes (zstd)
        COMMIT            # written last; absence marks a torn checkpoint

The COMMIT marker makes restores crash-safe: a save interrupted by a node
failure is invisible to ``restore_latest``.  ``CheckpointManager`` adds async
(background-thread) saves, retention, and restart bookkeeping — the
checkpoint/restart half of the fault-tolerance story (see ``repro.ft``).
"""

from __future__ import annotations

import os
import shutil
import threading
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:  # optional: fall back to zlib where the wheel is absent
    import zstandard as zstd
except ImportError:  # pragma: no cover - depends on environment
    zstd = None

__all__ = ["save_pytree", "load_pytree", "restore_latest", "CheckpointManager"]

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(payload: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(payload)
    return zlib.compress(payload, 6)


def _decompress(blob: bytes) -> bytes:
    """Sniff the frame magic so either codec's checkpoints stay readable."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard module "
                "is not installed"
            )
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save_pytree(tree, path: str) -> None:
    os.makedirs(path, exist_ok=True)
    entries = []
    blobs = []
    off = 0
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        raw = np.ascontiguousarray(arr).tobytes()
        entries.append(
            {
                "key": key,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "offset": off,
                "nbytes": len(raw),
                "crc32": zlib.crc32(raw),
            }
        )
        blobs.append(raw)
        off += len(raw)
    payload = b"".join(blobs)
    comp = _compress(payload)
    with open(os.path.join(path, "data.bin.zst"), "wb") as f:
        f.write(comp)
    with open(os.path.join(path, "index.msgpack"), "wb") as f:
        f.write(msgpack.packb({"entries": entries, "total": off}))
    # commit marker last: restores ignore torn checkpoints
    with open(os.path.join(path, "COMMIT"), "w") as f:
        f.write("ok")


def load_pytree(template, path: str, shardings=None):
    """Restore into the structure of ``template`` (arrays or SDStructs)."""
    if not os.path.exists(os.path.join(path, "COMMIT")):
        raise FileNotFoundError(f"no committed checkpoint at {path}")
    with open(os.path.join(path, "index.msgpack"), "rb") as f:
        index = msgpack.unpackb(f.read())
    with open(os.path.join(path, "data.bin.zst"), "rb") as f:
        payload = _decompress(f.read())
    by_key = {e["key"]: e for e in index["entries"]}
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_flat = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat)
    )
    out = []
    for (pathkey, leaf), shard in zip(flat, shard_flat):
        key = jax.tree_util.keystr(pathkey)
        e = by_key.get(key)
        if e is None:
            raise KeyError(f"checkpoint missing tensor {key}")
        raw = payload[e["offset"] : e["offset"] + e["nbytes"]]
        if zlib.crc32(raw) != e["crc32"]:
            raise IOError(f"checksum mismatch for {key}")
        arr = np.frombuffer(raw, dtype=np.dtype(e["dtype"])).reshape(e["shape"])
        want_dtype = getattr(leaf, "dtype", arr.dtype)
        val = jnp.asarray(arr, dtype=want_dtype)
        if shard is not None:
            val = jax.device_put(val, shard)
        out.append(val)
    return jax.tree_util.tree_unflatten(treedef, out)


def _step_dirs(root: str) -> List[Tuple[int, str]]:
    if not os.path.isdir(root):
        return []
    out = []
    for name in os.listdir(root):
        if name.startswith("step_"):
            full = os.path.join(root, name)
            if os.path.exists(os.path.join(full, "COMMIT")):
                try:
                    out.append((int(name.split("_")[1]), full))
                except ValueError:
                    continue
    return sorted(out)


def restore_latest(template, root: str, shardings=None):
    """(step, tree) from the newest committed checkpoint, or (None, None)."""
    dirs = _step_dirs(root)
    if not dirs:
        return None, None
    step, path = dirs[-1]
    return step, load_pytree(template, path, shardings)


class CheckpointManager:
    """Async, retained, crash-safe checkpoints."""

    def __init__(self, root: str, *, keep: int = 3, async_save: bool = True):
        self.root = root
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(root, exist_ok=True)

    def path_for(self, step: int) -> str:
        return os.path.join(self.root, f"step_{step:07d}")

    def save(self, step: int, tree) -> None:
        # snapshot to host BEFORE handing to the writer thread so training can
        # mutate device buffers immediately (async checkpointing)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def do_save():
            save_pytree(host_tree, self.path_for(step))
            self._gc()

        self.wait()
        if self.async_save:
            self._thread = threading.Thread(target=do_save, daemon=True)
            self._thread.start()
        else:
            do_save()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def restore_latest(self, template, shardings=None):
        self.wait()
        return restore_latest(template, self.root, shardings)

    def steps(self) -> List[int]:
        return [s for s, _ in _step_dirs(self.root)]

    def _gc(self) -> None:
        dirs = _step_dirs(self.root)
        for _, path in dirs[: -self.keep] if self.keep > 0 else []:
            shutil.rmtree(path, ignore_errors=True)
