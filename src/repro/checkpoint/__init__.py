"""Checkpointing: zstd-compressed tensor store with async save + restart."""

from .store import (
    CheckpointManager,
    load_pytree,
    restore_latest,
    save_pytree,
)

__all__ = ["CheckpointManager", "save_pytree", "load_pytree", "restore_latest"]
