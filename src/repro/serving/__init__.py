"""Serving substrate: batched prefill/decode engine with slot management."""

from .engine import ServeConfig, ServeEngine

__all__ = ["ServeConfig", "ServeEngine"]
