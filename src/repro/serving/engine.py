"""Batched serving engine: slot-based continuous batching.

Requests prefill individually (their caches are merged into batch slots) and
decode together in one jitted step per token.  The decode step is exactly
what the ``decode_32k``/``long_500k`` dry-run cells lower: one new token for
every active slot against resident caches.  Greedy or temperature sampling.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model

__all__ = ["ServeConfig", "ServeEngine"]


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 512
    temperature: float = 0.0  # 0 => greedy
    eos_token: int = -1       # -1 => never stop early
    seed: int = 0


@dataclass
class _Slot:
    request_id: int
    tokens: List[int]
    prompt_len: int
    done: bool = False


class ServeEngine:
    def __init__(self, model: Model, params, scfg: ServeConfig):
        self.model = model
        self.params = params
        self.scfg = scfg
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos)
        )
        self._next_id = 0
        self.stats = {"prefill_tokens": 0, "decode_steps": 0, "requests": 0}

    # -- single-request generation ------------------------------------------

    def generate(
        self,
        prompts: Sequence[Sequence[int]],
        max_new_tokens: int,
    ) -> List[List[int]]:
        """Continuous-batched generation for a set of prompts."""
        scfg = self.scfg
        out: Dict[int, List[int]] = {}
        pending = list(enumerate(prompts))
        while pending:
            batch = pending[: scfg.max_batch]
            pending = pending[len(batch) :]
            out.update(self._run_batch(batch, max_new_tokens))
        return [out[i] for i in range(len(prompts))]

    def _run_batch(self, batch, max_new_tokens: int):
        scfg = self.scfg
        B = len(batch)
        # left-align prompts to a common length with separator padding; batch
        # prefill is one forward pass
        plen = max(len(p) for _, p in batch)
        toks = np.zeros((B, plen), np.int32)
        for i, (_, p) in enumerate(batch):
            toks[i, plen - len(p) :] = p  # right-aligned so last token is real
        caches = self.model.init_caches(B, plen + max_new_tokens)
        pos = 0
        logits = None
        for t in range(plen):
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(toks[:, t]), jnp.int32(t)
            )
            self.stats["prefill_tokens"] += B
        rng = jax.random.PRNGKey(scfg.seed)
        results = {rid: list(p) for rid, p in batch}
        done = np.zeros(B, bool)
        for k in range(max_new_tokens):
            nxt = self._sample(logits, rng, k)
            for i, (rid, _) in enumerate(batch):
                if not done[i]:
                    tok = int(nxt[i])
                    results[rid].append(tok)
                    if tok == scfg.eos_token:
                        done[i] = True
            if done.all():
                break
            logits, caches = self._decode(
                self.params, caches, jnp.asarray(nxt), jnp.int32(plen + k)
            )
            self.stats["decode_steps"] += 1
        self.stats["requests"] += B
        return results

    def _sample(self, logits, rng, k):
        if self.scfg.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        key = jax.random.fold_in(rng, k)
        return np.asarray(
            jax.random.categorical(key, logits / self.scfg.temperature), np.int32
        )
