"""Pipeline parallelism (GPipe-style) via shard_map collective_permute.

Completes the parallelism matrix (DP/TP/PP/EP/SP).  Layers are split into
``n_stages`` equal groups placed along a ``pipe`` mesh axis; microbatches
stream through the classic GPipe schedule: ``n_micro + n_stages - 1`` ticks,
each tick running one stage-step everywhere (idle ticks compute on zeros and
are masked out) and rotating activations to the next stage with
``collective_permute`` — one-sided neighbour pushes, the paper's xGMI-write
pattern at pipeline granularity.  Eidola models exactly this traffic via
``periodic_stream`` eidolons (see ``repro.core.egpu``).

The forward is numerically identical to the unpipelined stack (tested) and
differentiable (``collective_permute`` transposes to the reverse shift, so
the backward pass is the mirrored pipeline).

Bubble fraction = (n_stages - 1) / (n_micro + n_stages - 1), reported by
``bubble_fraction`` and validated in tests.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .compat import SHARD_MAP_NO_CHECK, shard_map

__all__ = ["pipeline_apply", "bubble_fraction", "stack_stage_params"]


def bubble_fraction(n_stages: int, n_micro: int) -> float:
    """Idle fraction of the GPipe schedule."""
    return (n_stages - 1) / (n_micro + n_stages - 1)


def stack_stage_params(layer_params, n_stages: int):
    """[L, ...] stacked layer params -> [n_stages, L/n_stages, ...]."""

    def re(x):
        L = x.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return x.reshape(n_stages, L // n_stages, *x.shape[1:])

    return jax.tree.map(re, layer_params)


def pipeline_apply(
    mesh: Mesh,
    layer_fn: Callable[[Any, jax.Array], jax.Array],
    *,
    n_micro: int,
    axis: str = "pipe",
):
    """Builds a pipelined stack applier.

    layer_fn(layer_params, x) -> x applies ONE layer.
    Returns ``apply(stage_params, x)`` where ``stage_params`` is a pytree of
    [n_stages, layers_per_stage, ...] arrays (sharded on dim 0 over ``axis``)
    and ``x`` is [n_micro * mb, ...] (replicated).  Output matches running
    all layers sequentially.
    """
    n_stages = mesh.shape[axis]

    def body(stage_p, x):
        # stage_p: [1, L/S, ...] (this stage's layers); x: [n_micro*mb, ...]
        sidx = jax.lax.axis_index(axis)
        B = x.shape[0]
        mb = B // n_micro
        micros = x.reshape(n_micro, mb, *x.shape[1:])
        n_ticks = n_micro + n_stages - 1
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        my_layers = jax.tree.map(lambda a: a[0], stage_p)

        def run_stage(xmb):
            def one(x_c, p_l):
                return layer_fn(p_l, x_c), None

            out, _ = jax.lax.scan(one, xmb, my_layers)
            return out

        def tick(carry, t):
            buf, outs = carry
            # stage 0 injects microbatch t (if within range); others use buf
            inject = jnp.where(
                t < n_micro,
                micros[jnp.clip(t, 0, n_micro - 1)],
                jnp.zeros_like(buf),
            )
            x_in = jnp.where(sidx == 0, inject, buf)
            y = run_stage(x_in)
            # last stage emits microbatch (t - n_stages + 1)
            out_idx = t - (n_stages - 1)
            emit = jnp.logical_and(sidx == n_stages - 1, out_idx >= 0)
            outs = jax.lax.cond(
                emit,
                lambda o: o.at[jnp.clip(out_idx, 0, n_micro - 1)].set(y),
                lambda o: o,
                outs,
            )
            buf_next = jax.lax.ppermute(y, axis, perm)
            return (buf_next, outs), None

        buf0 = jnp.zeros_like(micros[0])
        outs0 = jnp.zeros_like(micros)
        (_, outs), _ = jax.lax.scan(
            tick, (buf0, outs0), jnp.arange(n_ticks)
        )
        # every stage holds zeros except the last; share the result
        outs = jax.lax.psum(outs, axis) if n_stages > 1 else outs
        # psum adds the last stage's outputs to zeros from the others
        return outs.reshape(B, *x.shape[1:])

    stage_spec = jax.tree.map(lambda _: P(axis), {"_": 0})  # placeholder
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        **SHARD_MAP_NO_CHECK,
    )
