"""Activation-checkpoint (remat) policies.

Named policies keep the perf-iteration log readable: EXPERIMENTS.md §Perf
references these by name when a hillclimb step changes the remat policy.
"""

from __future__ import annotations

from typing import Callable, Dict

import jax

__all__ = ["POLICIES", "get_policy"]


def _nothing():
    return jax.checkpoint_policies.nothing_saveable


def _dots():
    return jax.checkpoint_policies.dots_saveable


def _dots_no_batch():
    return jax.checkpoint_policies.dots_with_no_batch_dims_saveable


POLICIES: Dict[str, Callable] = {
    # recompute everything in backward (min memory, max recompute)
    "full": _nothing,
    # save matmul outputs (the usual sweet spot for transformer blocks)
    "dots": _dots,
    "dots_no_batch": _dots_no_batch,
    # no remat at all (max memory, zero recompute)
    "none": None,
}


def get_policy(name: str):
    if name not in POLICIES:
        raise KeyError(f"unknown remat policy {name!r}; one of {sorted(POLICIES)}")
    fn = POLICIES[name]
    return None if fn is None else fn()


def maybe_remat(f, policy_name: str, *, static_argnums=()):
    """Wrap ``f`` in jax.checkpoint under the named policy ('none' = no-op)."""
    if policy_name == "none":
        return f
    return jax.checkpoint(
        f, policy=get_policy(policy_name), static_argnums=static_argnums
    )
