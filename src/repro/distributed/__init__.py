"""Distribution substrate: sharding rules, collectives, ZeRO, remat, PP."""

from .sharding import DEFAULT_RULES, ShardingRules, constrain, param_shardings, resolve_spec, batch_spec
from .zero import zero1_shardings, zero1_spec
from .remat import POLICIES, get_policy, maybe_remat
from .pipeline import bubble_fraction, pipeline_apply, stack_stage_params

__all__ = [
    "DEFAULT_RULES", "ShardingRules", "constrain", "param_shardings",
    "resolve_spec", "batch_spec", "zero1_shardings", "zero1_spec",
    "POLICIES", "get_policy", "maybe_remat",
    "bubble_fraction", "pipeline_apply", "stack_stage_params",
]
