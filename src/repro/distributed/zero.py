"""ZeRO-1 optimizer-state sharding.

Optimizer moments (and the fp32 master copy) are sharded across the
data(-parallel) axis: each leaf is partitioned along its first dim divisible
by the DP world size, falling back to replication for small tensors.  With
the production mesh this cuts optimizer memory 16x (32x multi-pod), which is
what lets kimi-k2-scale training state fit per device (see EXPERIMENTS.md
§Dry-run memory table).
"""

from __future__ import annotations

from typing import Any, List, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["zero1_spec", "zero1_shardings", "zero1_from_params"]


def zero1_spec(
    shape: Tuple[int, ...], mesh: Mesh, axes=("data",), *, model_dim: bool = False
) -> P:
    """Shard the first divisible dim across the (combined) DP axes.

    ``model_dim=True`` additionally shards a second dim over 'model' — a
    measured two-sided tradeoff (EXPERIMENTS.md §Perf H4): it cuts optimizer
    state a further model-axis-fold (essential at 1T params: kimi-k2 233 vs
    1204 GiB/dev) but the update then reshards every fp32 gradient leaf,
    inflating temps ~1.8x on ~1B models (gemma3-1b 25 -> 129 GiB/dev).
    Default off; enable for >=100B-param configs.
    """
    use = tuple(a for a in axes if a in mesh.shape)
    parts: List[Any] = [None] * len(shape)
    if use:
        world = 1
        for a in use:
            world *= mesh.shape[a]
        for d, n in enumerate(shape):
            if n > 0 and n % world == 0:
                parts[d] = use if len(use) > 1 else use[0]
                break
    if model_dim and "model" in mesh.shape:
        msz = mesh.shape["model"]
        for d, n in enumerate(shape):
            if parts[d] is None and n > 0 and n % msz == 0 and msz > 1:
                parts[d] = "model"
                break
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def zero1_shardings(shapes_tree, mesh: Mesh, axes=("data",), *, model_dim=False):
    """NamedSharding tree for optimizer state (same structure as params)."""
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, zero1_spec(s.shape, mesh, axes, model_dim=model_dim)
        ),
        shapes_tree,
    )


def zero1_from_params(param_shardings_tree, shapes_tree, mesh: Mesh,
                      axes=("data",)):
    """Param-layout-aligned ZeRO: extend each PARAM spec with the DP axes.

    States share the parameter's existing layout (so gradient -> state needs
    no transpose/reshard — the SPMD "involuntary full rematerialization"
    warnings disappear) and additionally shard the first still-free divisible
    dim across the combined DP axes.  Strictly dominates both the data-only
    and model-dim variants measured in EXPERIMENTS.md §Perf H4.
    """
    use = tuple(a for a in axes if a in mesh.shape)
    world = 1
    for a in use:
        world *= mesh.shape[a]

    def extend(psh, shp):
        spec = list(psh.spec) + [None] * (len(shp.shape) - len(psh.spec))
        if use:
            used_axes = set()
            for part in spec:
                if part is None:
                    continue
                for a in (part if isinstance(part, tuple) else (part,)):
                    used_axes.add(a)
            if not (set(use) & used_axes):
                for d, n in enumerate(shp.shape):
                    if spec[d] is None and n > 0 and n % world == 0:
                        spec[d] = use if len(use) > 1 else use[0]
                        break
        while spec and spec[-1] is None:
            spec.pop()
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(extend, param_shardings_tree, shapes_tree)
