"""shard_map collectives: the paper's fused GEMV+AllReduce at the JAX level,
ring collectives with compute overlap, and compressed gradient reduction.

``fused_gemv_allreduce`` reproduces the kernel of Punniyamurthy et al. [30]
(the paper's measured workload) as real distributed compute: the reduction
dim of ``y = x @ W`` is sharded; each device computes partial outputs in the
paper's *remote-tiles-first* order and pushes partial tiles to their owners
with one-sided ``ppermute`` sends (the JAX analogue of xGMI writes), then
reduces its owned tiles — an all-reduce decomposed into reduce-scatter(+ring)
+ all-gather with explicit overlap structure.  The plain ``psum`` baseline is
kept for equivalence tests and as the paper-faithful unfused reference.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import SHARD_MAP_NO_CHECK, axis_size, pvary, shard_map

__all__ = [
    "psum_matmul",
    "fused_gemv_allreduce",
    "ring_allreduce",
    "compressed_psum",
    "overlap_grad_allreduce",
]


# ---------------------------------------------------------------------------
# baseline: unfused matmul + AllReduce
# ---------------------------------------------------------------------------


def psum_matmul(mesh: Mesh, axis: str = "model"):
    """y = AllReduce(x_shard @ w_shard): the unfused two-step baseline."""

    def inner(x, w):
        y_part = x @ w
        return jax.lax.psum(y_part, axis)

    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        **SHARD_MAP_NO_CHECK,
    )


# ---------------------------------------------------------------------------
# fused GEMV+AllReduce (remote-tiles-first + ring reduce + all-gather)
# ---------------------------------------------------------------------------


def fused_gemv_allreduce(mesh: Mesh, axis: str = "model"):
    """Fused compute/communication GEMV+AllReduce.

    x: [B, K] sharded on K over ``axis``; w: [K, N] sharded on K.
    Each rank computes its partial [B, N], then a ring reduce-scatter runs
    with the partial-tile computation interleaved chunk-by-chunk (the fused
    kernel's overlap), followed by an all-gather of owned tiles.
    Numerically identical to ``psum_matmul`` (tested).
    """
    def inner(x, w):
        n_dev = axis_size(axis)
        idx = jax.lax.axis_index(axis)
        B = x.shape[0]

        # --- "remote tiles first": compute partials in owner order, starting
        # with the tile owned by our ring successor (sent soonest).
        y = x @ w  # [B, N] partial sums for ALL tiles (single GEMM here;
        #            the Pallas kernel version tiles this loop explicitly)
        N = y.shape[-1]
        tile = N // n_dev
        yt = y.reshape(B, n_dev, tile)

        # --- ring reduce-scatter: after n-1 steps, rank r holds the fully
        # reduced tile r.  Each step sends the partially-reduced tile for the
        # neighbour (one-sided write analogue) and accumulates the received.
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def step(carry, k):
            acc, yt_local = carry
            # tile t's partial launches at rank t+1 and lands at its owner t
            # after n-1 hops; rank r therefore forwards tile (r-k-1) at step k
            send_idx = jnp.mod(idx - k - 1, n_dev)
            buf = acc + jnp.take(yt_local, send_idx, axis=1)
            recv = jax.lax.ppermute(buf, axis, perm)
            return (recv, yt_local), None

        zero = pvary(jnp.zeros((B, tile), y.dtype), (axis,))
        (acc, _), _ = jax.lax.scan(
            step, (zero, yt), jnp.arange(n_dev - 1)
        )
        mine = acc + jnp.take(yt, idx, axis=1)  # fully reduced owned tile

        # --- broadcast results (paper line 18): all-gather owned tiles
        out = jax.lax.all_gather(mine, axis, axis=1, tiled=False)
        return out.reshape(B, N)

    return shard_map(
        inner,
        mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None),
        **SHARD_MAP_NO_CHECK,
    )


# ---------------------------------------------------------------------------
# standalone ring all-reduce (used by tests and the overlap scheduler)
# ---------------------------------------------------------------------------


def ring_allreduce(mesh: Mesh, axis: str):
    """Bidirectional-naive ring all-reduce of a replicated-shape buffer."""

    def inner(x):
        n_dev = axis_size(axis)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def step(acc_x, _):
            acc, cur = acc_x
            cur = jax.lax.ppermute(cur, axis, perm)
            return (acc + cur, cur), None

        (acc, _), _ = jax.lax.scan(step, (x, x), None, length=n_dev - 1)
        return acc

    return shard_map(inner, mesh=mesh, in_specs=P(axis), out_specs=P(axis), **SHARD_MAP_NO_CHECK)


# ---------------------------------------------------------------------------
# gradient compression (distributed-optimization trick)
# ---------------------------------------------------------------------------


def compressed_psum(
    x: jax.Array, axis: str, *, bits: int = 8
) -> jax.Array:
    """int8-quantized all-reduce with a shared per-tensor scale.

    scale = pmax(max|x|); q = round(x/scale * 127) summed in int32; dequant.
    Cuts gradient all-reduce bytes 4x vs f32 (2x vs bf16) at ~1e-2 relative
    error — recorded as a beyond-paper optimization in EXPERIMENTS.md §Perf.
    Must be called inside shard_map/pmapped code with ``axis`` bound.
    """
    assert bits == 8, "int8 path only"
    amax = jax.lax.pmax(jnp.max(jnp.abs(x.astype(jnp.float32))), axis)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(
        jnp.int32
    )
    total = jax.lax.psum(q, axis)
    return (total.astype(jnp.float32) * scale).astype(x.dtype)


def overlap_grad_allreduce(mesh: Mesh, axis: str = "data", *, compress: bool = False):
    """Per-leaf gradient all-reduce, optionally int8-compressed.

    Applied leaf-by-leaf (rather than one fused psum) so XLA can start each
    reduction as soon as its gradient is produced in the backward pass —
    the compute/comm overlap the paper's fused kernels target.
    """

    def reduce_tree(grads):
        def red(g):
            def inner(gs):
                if compress:
                    return compressed_psum(gs, axis)
                return jax.lax.psum(gs, axis)

            return shard_map(
                inner, mesh=mesh, in_specs=P(*(None,) * g.ndim),
                out_specs=P(*(None,) * g.ndim), **SHARD_MAP_NO_CHECK,
            )(g)

        return jax.tree.map(red, grads)

    return reduce_tree
