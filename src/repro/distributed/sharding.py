"""Logical-axis sharding rules with automatic divisibility fallback.

Model parameters declare *logical* axes (``embed``, ``heads``, ``mlp``,
``vocab``, ``experts``, ...); a :class:`ShardingRules` table maps each logical
axis to a mesh axis (or None = replicated).  Resolution checks divisibility:
if a tensor dim is not divisible by its mesh axis size, that dim falls back to
replication and the event is recorded — this is how gemma3-1b's single KV head
runs on a 16-way model axis without per-arch special cases.

Activation sharding uses the same table through ``constrain``.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "resolve_spec",
    "param_shardings",
    "constrain",
    "batch_spec",
]


@dataclass(frozen=True)
class ShardingRules:
    """logical axis -> mesh axis (None = replicate)."""

    rules: Tuple[Tuple[str, Optional[str]], ...] = ()
    # logged (param_path, logical_axis, mesh_axis, dim, size) fallbacks
    strict: bool = False

    def to_dict(self) -> Dict[str, Optional[str]]:
        return dict(self.rules)

    def with_rule(self, logical: str, mesh_axis: Optional[str]) -> "ShardingRules":
        d = self.to_dict()
        d[logical] = mesh_axis
        return ShardingRules(rules=tuple(d.items()), strict=self.strict)


# The production table: model-parallel over heads/mlp/vocab/experts, data-
# parallel over batch, pods pure-DP.  ``experts_logits`` (router) and MLA
# ``rank`` stay replicated; layers stay unsharded (scan dim).
DEFAULT_RULES = ShardingRules(
    rules=(
        ("batch", "data"),
        ("seq", None),
        ("kv_seq", "data"),       # sequence-parallel KV for long_500k
        ("embed", None),
        ("embed2", None),
        ("heads", "model"),
        ("kv", "model"),
        ("mlp", "model"),
        # expert FFN width shards across data: with experts on the model
        # axis this spreads a 1T-param MoE over the full mesh (FSDP-style
        # per-layer weight gathers happen inside the EP shard_map)
        ("expert_mlp", "data"),
        ("vocab", "model"),
        ("experts", "model"),     # expert parallelism on the model axis
        ("experts_logits", None),
        ("rank", None),
        ("layers", None),
        ("conv", None),
        ("state", None),
    )
)


def _mesh_axis_size(mesh: Mesh, axis: Optional[str]) -> int:
    if axis is None:
        return 1
    return mesh.shape[axis]


def resolve_spec(
    shape: Sequence[int],
    axes: Sequence[Optional[str]],
    rules: ShardingRules,
    mesh: Mesh,
    *,
    path: str = "",
    fallbacks: Optional[List[str]] = None,
) -> P:
    """PartitionSpec for one tensor, with divisibility fallback."""
    table = rules.to_dict()
    used: set = set()
    parts: List[Optional[str]] = []
    for dim, ax in zip(shape, axes):
        mesh_ax = table.get(ax) if ax is not None else None
        if mesh_ax is None or mesh_ax not in mesh.shape:
            parts.append(None)
            continue
        size = _mesh_axis_size(mesh, mesh_ax)
        if dim % size != 0 or mesh_ax in used:
            if rules.strict:
                raise ValueError(
                    f"{path}: dim {dim} (logical {ax!r}) not divisible by "
                    f"mesh axis {mesh_ax!r} of size {size}"
                )
            if fallbacks is not None:
                reason = "reused" if mesh_ax in used else f"{dim} % {size} != 0"
                fallbacks.append(f"{path}[{ax}->{mesh_ax}]: replicated ({reason})")
            parts.append(None)
            continue
        used.add(mesh_ax)
        parts.append(mesh_ax)
    # trim trailing Nones for a tidier spec
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_shardings(
    axes_tree,
    shapes_tree,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
) -> Tuple[Any, List[str]]:
    """NamedSharding tree for a parameter pytree.

    ``axes_tree`` holds logical-axis tuples; ``shapes_tree`` anything with
    ``.shape`` per leaf (arrays or ShapeDtypeStructs).  Returns (sharding
    tree, fallback log).
    """
    fallbacks: List[str] = []
    flat_axes, treedef = jax.tree.flatten(
        axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )
    flat_shapes = treedef.flatten_up_to(shapes_tree)
    paths = [str(i) for i in range(len(flat_axes))]
    out = []
    for p, ax, sh in zip(paths, flat_axes, flat_shapes):
        spec = resolve_spec(
            sh.shape, ax, rules, mesh, path=p, fallbacks=fallbacks
        )
        out.append(NamedSharding(mesh, spec))
    return jax.tree.unflatten(treedef, out), fallbacks


def batch_spec(mesh: Mesh, *, pods: bool = False) -> P:
    """Data-parallel batch spec: batch over ('pod','data') when multi-pod."""
    if pods and "pod" in mesh.shape:
        return P(("pod", "data"))
    return P("data")


def constrain(x: jax.Array, mesh: Mesh, *parts) -> jax.Array:
    """Activation sharding hint, skipping axes absent from the mesh."""
    cleaned = []
    for ax in parts:
        if ax is None:
            cleaned.append(None)
        elif isinstance(ax, tuple):
            sub = tuple(a for a in ax if a in mesh.shape)
            cleaned.append(sub if sub else None)
        else:
            cleaned.append(ax if ax in mesh.shape else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*cleaned)))
