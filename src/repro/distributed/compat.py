"""jax version portability shims for the shard_map-based modules.

The training/serving substrate targets both the jax baked into this image
(0.4.x) and current releases:

* ``shard_map`` moved from ``jax.experimental.shard_map`` to the top level;
* its replication-check keyword was renamed ``check_rep`` -> ``check_vma``
  (our all-to-all bodies do not satisfy it, so it is always disabled);
* ``jax.lax.axis_size`` appeared in 0.5 — ``psum(1, axis)`` is the portable
  spelling.
* 0.4's non-partitionable threefry makes ``jit(init, out_shardings=...)``
  produce *different parameter values per mesh shape*; call
  :func:`require_sharding_invariant_rng` from entry points whose contract is
  mesh-shape determinism (the trainer does) — deliberately NOT an import
  side effect here, so merely importing a shard_map helper never changes a
  host application's RNG stream.
"""

from __future__ import annotations

import inspect

import jax


def require_sharding_invariant_rng() -> None:
    """Force partitionable threefry (sharding-invariant random values).

    On jax >= 0.5 this is the default (and eventually the only) behaviour;
    on 0.4 the legacy RNG makes sharded param init depend on the mesh shape,
    which breaks cross-mesh train-step determinism (tested in
    ``test_sharded_train_step_matches_single_device``).
    """
    jax.config.update("jax_threefry_partitionable", True)


try:
    from jax import shard_map
except ImportError:  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map

__all__ = [
    "shard_map",
    "SHARD_MAP_NO_CHECK",
    "axis_size",
    "pvary",
    "require_sharding_invariant_rng",
]

# kwargs that disable shard_map's replicated-collective check on this jax
SHARD_MAP_NO_CHECK = (
    {"check_vma": False}
    if "check_vma" in inspect.signature(shard_map).parameters
    else {"check_rep": False}
)


def axis_size(name: str):
    """Size of a named mesh axis, from inside a shard_map body."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(name)
    return jax.lax.psum(1, name)


def pvary(x, axes):
    """Mark ``x`` as varying over ``axes`` (vma typing, jax >= 0.5).

    On older jax there is no varying-manual-axes type system, so the marker
    is an identity."""
    if hasattr(jax.lax, "pvary"):
        return jax.lax.pvary(x, axes)
    return x
