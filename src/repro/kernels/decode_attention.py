"""Flash-decoding Pallas kernel: one query token vs. a long KV cache.

Grid walks (batch, kv-block); VMEM f32 scratch holds the running
(max, sum, output) triple per GQA group, merged across KV blocks with the
standard log-sum-exp rescaling.  Blocks are sized so K/V slabs stream through
VMEM; on real TPU the sequence axis is the natural split-K axis of
flash-decoding (parallelized across cores / sequence shards — the
sequence-parallel decode path of long_500k).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["decode_attention_pallas"]

_NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref, *, bs):
    s_blk = pl.program_id(1)

    @pl.when(s_blk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]            # [H, D] (one batch element)
    k = k_ref[0]            # [bs, KV, D]
    v = v_ref[0]            # [bs, KV, D]
    H, D = q.shape
    KV = k.shape[1]
    rep = H // KV
    length = len_ref[0]

    qh = q.reshape(KV, rep, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("grd,sgd->grs", qh, k.astype(jnp.float32))  # [KV, rep, bs]
    pos = s_blk * bs + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bs), 2)
    s = jnp.where(pos < length, s, _NEG_INF)

    m_prev = m_ref[...]                      # [KV, rep]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])        # [KV, rep, bs]
    l_new = l_ref[...] * alpha + p.sum(axis=-1)
    acc = acc_ref[...] * alpha[..., None] + jnp.einsum(
        "grs,sgd->grd", p, v.astype(jnp.float32)
    )
    m_ref[...] = m_new
    l_ref[...] = l_new
    acc_ref[...] = acc

    @pl.when(s_blk == pl.num_programs(1) - 1)
    def _final():
        o = acc / jnp.maximum(l_new, 1e-20)[..., None]
        o_ref[0] = o.reshape(H, D).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_pallas(
    q: jax.Array,       # [B, H, D]
    k: jax.Array,       # [B, S, KV, D]
    v: jax.Array,       # [B, S, KV, D]
    length: jax.Array,  # i32[] valid cache prefix
    *,
    bs: int = 256,
    interpret: bool = True,
) -> jax.Array:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    bs = min(bs, S)
    assert S % bs == 0, "kv block must tile the cache"
    rep = H // KV
    lens = jnp.broadcast_to(jnp.asarray(length, jnp.int32)[None], (B,))

    grid = (B, S // bs)
    out = pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
            pl.BlockSpec((1, bs, KV, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1, bs, KV, D), lambda b, s: (b, s, 0, 0)),
            pl.BlockSpec((1,), lambda b, s: (b,)),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, s: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep), jnp.float32),
            pltpu.VMEM((KV, rep, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lens)
    return out
