"""Tiled GEMV Pallas kernel (the fused GEMV+AllReduce's compute hot loop).

TPU-native adaptation of the paper's workgroup tiling: output rows are tiled
``bm`` at a time (MXU-aligned, multiples of 128 at full size); the reduction
dim streams through VMEM in ``bk`` slabs via the grid's second axis with an
f32 accumulator in the output block.  ``N`` (the GEMV's vector width) rides
along as the output block's lane dim padded to the VPU lane width by the
BlockSpec machinery.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gemv_pallas"]


def _gemv_kernel(a_ref, x_ref, o_ref):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]  # [bm, bk]
    x = x_ref[...]  # [bk, N]
    o_ref[...] += jnp.dot(
        a.astype(jnp.float32), x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def gemv_pallas(
    a: jax.Array,          # [M, K]
    x: jax.Array,          # [K, N]
    *,
    bm: int = 128,
    bk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    M, K = a.shape
    K2, N = x.shape
    assert K == K2, (a.shape, x.shape)
    bm = min(bm, M)
    bk = min(bk, K)
    assert M % bm == 0 and K % bk == 0, "block sizes must tile the problem"
    grid = (M // bm, K // bk)
    out = pl.pallas_call(
        _gemv_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, k: (m, k)),   # A tile in VMEM
            pl.BlockSpec((bk, N), lambda m, k: (k, 0)),    # x slab in VMEM
        ],
        out_specs=pl.BlockSpec((bm, N), lambda m, k: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(a, x)
    return out.astype(a.dtype)
