"""jit'd public wrappers for the Pallas kernels (+ dispatch helpers).

``interpret=True`` everywhere in this container (CPU validation of the TPU
kernel bodies); on real TPU hardware pass ``interpret=False`` and the same
BlockSpecs compile to Mosaic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_pallas
from .gemv import gemv_pallas
from .gemv_tiles import gemv_tiles_pallas, remote_first_order
from .rmsnorm import rmsnorm_pallas

__all__ = [
    "gemv",
    "gemv_tiles",
    "decode_attention",
    "rmsnorm",
    "remote_first_order",
]


def gemv(a, x, **kw):
    """y = A @ x with MXU-aligned tiling."""
    return gemv_pallas(a, x, **kw)


def gemv_tiles(a, x, *, n_dev, my_dev, **kw):
    """(y, owner_schedule): fused GEMV+AllReduce tile order on one device."""
    return gemv_tiles_pallas(a, x, n_dev=n_dev, my_dev=my_dev, **kw)


def decode_attention(q, k, v, length, **kw):
    """Flash-decoding: one token vs. a (long) KV cache."""
    return decode_attention_pallas(q, k, v, length, **kw)


def rmsnorm(x, gamma, **kw):
    """Fused RMSNorm."""
    return rmsnorm_pallas(x, gamma, **kw)
