"""Pallas TPU kernels (validated in interpret mode against ref.py oracles)."""

from . import ops, ref
from .ops import decode_attention, gemv, gemv_tiles, remote_first_order, rmsnorm

__all__ = ["ops", "ref", "gemv", "gemv_tiles", "decode_attention", "rmsnorm",
           "remote_first_order"]
