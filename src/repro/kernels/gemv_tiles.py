"""Owner-ordered GEMV partial-tile kernel (fused GEMV+AllReduce schedule).

Implements the paper's Fig. 3 tile ordering on a single device: the grid's
first axis walks output tiles in *owner* order starting at this device's ring
successor — remote-owned partial tiles are produced first (so their xGMI/ICI
pushes can start while local tiles compute), local tiles last.  The tile
permutation arrives via TPU scalar prefetch (``PrefetchScalarGridSpec``), the
idiomatic mechanism for data-dependent BlockSpec index maps.  A progress
output records which owner each grid step serviced, letting tests assert the
remote-first schedule that the Eidola workload model times.  Values are
identical to a plain GEMV.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["gemv_tiles_pallas", "remote_first_order"]


def remote_first_order(n_dev: int, my_dev: int, tiles_per_dev: int):
    """Tile visit order: successor owner's tiles first, own tiles last."""
    order = []
    for step in range(1, n_dev + 1):
        owner = (my_dev + step) % n_dev
        for i in range(tiles_per_dev):
            order.append(owner * tiles_per_dev + i)
    return jnp.asarray(order, jnp.int32)


def _kernel(order_ref, a_ref, x_ref, o_ref, prog_ref, *, tiles_per_dev):
    t = pl.program_id(0)
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        x_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    nk = pl.num_programs(1)

    @pl.when(k == nk - 1)
    def _record():
        # which owner did this grid step service (schedule introspection)
        prog_ref[0] = order_ref[t] // tiles_per_dev


@functools.partial(
    jax.jit, static_argnames=("n_dev", "my_dev", "bm", "bk", "interpret")
)
def gemv_tiles_pallas(
    a: jax.Array,     # [M, K]
    x: jax.Array,     # [K, N]
    *,
    n_dev: int,
    my_dev: int,
    bm: int = 64,
    bk: int = 512,
    interpret: bool = True,
):
    """Returns (y [M,N] in a.dtype, owner_served i32[T]) over T grid tiles."""
    M, K = a.shape
    _, N = x.shape
    bm = min(bm, M // n_dev)
    bk = min(bk, K)
    assert M % (n_dev * bm) == 0 and K % bk == 0
    tiles_per_dev = M // n_dev // bm
    n_tiles = M // bm
    order = remote_first_order(n_dev, my_dev, tiles_per_dev)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda t, k, order: (order[t], k)),
            pl.BlockSpec((bk, N), lambda t, k, order: (k, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, N), lambda t, k, order: (order[t], 0)),
            pl.BlockSpec((1,), lambda t, k, order: (t,)),
        ],
    )
    y, prog = pl.pallas_call(
        functools.partial(_kernel, tiles_per_dev=tiles_per_dev),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, N), jnp.float32),
            jax.ShapeDtypeStruct((n_tiles,), jnp.int32),
        ],
        interpret=interpret,
    )(order, a, x)
    return y.astype(a.dtype), prog
