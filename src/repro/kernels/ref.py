"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gemv_ref",
    "gemv_tiles_ref",
    "decode_attention_ref",
    "rmsnorm_ref",
]


def gemv_ref(a: jax.Array, x: jax.Array) -> jax.Array:
    """y = A @ x. a: [M, K]; x: [K, N] -> [M, N] (f32 accumulation)."""
    return jnp.dot(
        a.astype(jnp.float32), x.astype(jnp.float32)
    ).astype(a.dtype)


def gemv_tiles_ref(a: jax.Array, x: jax.Array, n_dev: int, my_dev: int):
    """Owner-ordered partial-tile GEMV (fused GEMV+AllReduce compute side).

    Output rows are grouped by owner device; tiles for remote owners come
    first (paper Fig. 3 lines 2-5), then local tiles (lines 9-12).  The values
    equal gemv_ref — only the *schedule* differs — so the oracle is the plain
    product; the kernel's tile-issue order is asserted separately via its
    progress-counter output.
    """
    return gemv_ref(a, x)


def decode_attention_ref(
    q: jax.Array,   # [B, H, D]
    k: jax.Array,   # [B, S, KV, D]
    v: jax.Array,   # [B, S, KV, D]
    length: int,    # valid prefix of the cache
) -> jax.Array:
    B, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qh = q.reshape(B, KV, rep, D).astype(jnp.float32) * (D ** -0.5)
    s = jnp.einsum("bgrd,bsgd->bgrs", qh, k.astype(jnp.float32))
    mask = (jnp.arange(S) < length)[None, None, None, :]
    s = jnp.where(mask, s, -2.0e38)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgrs,bsgd->bgrd", w, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def rmsnorm_ref(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + gamma.astype(jnp.float32))).astype(x.dtype)
