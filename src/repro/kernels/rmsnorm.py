"""Fused RMSNorm Pallas kernel.

Row-blocked: each grid step normalizes ``br`` rows entirely in VMEM (load,
reduce, scale, store in one pass), eliminating the separate
square/mean/rsqrt/mul HBM round-trips of the unfused lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_pallas"]


def _kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)            # [br, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    g = g_ref[...].astype(jnp.float32)            # [D]
    o_ref[...] = (y * (1.0 + g)[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "eps", "interpret"))
def rmsnorm_pallas(
    x: jax.Array,       # [..., D]
    gamma: jax.Array,   # [D]
    *,
    br: int = 256,
    eps: float = 1e-6,
    interpret: bool = True,
) -> jax.Array:
    orig_shape = x.shape
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(br, R)
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=((R + pad) // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct(((R + pad), D), x.dtype),
        interpret=interpret,
    )(x2, gamma)
    return out[:R].reshape(orig_shape)
