"""Scenario simulation CLI: run any registered traffic pattern, or sweep it.

Usage:
  PYTHONPATH=src python -m repro.launch.scenario --list
  PYTHONPATH=src python -m repro.launch.scenario --scenario ring_allreduce \
      --engine event --sync syncmon
  PYTHONPATH=src python -m repro.launch.scenario --scenario gemv_allreduce \
      -p flag_delays_ns=20000 --engines cycle,event
  PYTHONPATH=src python -m repro.launch.scenario --scenario all_to_all \
      --sweep skew_ns=0,2000,8000 --sweep n_egpus=3,7 --csv /tmp/sweep.csv
  PYTHONPATH=src python -m repro.launch.scenario --scenario ring_allreduce \
      --devices 8 --detailed all
  PYTHONPATH=src python -m repro.launch.scenario \
      --scenario hierarchical_allreduce --devices 16 --nodes 4 \
      --dci-bw 6.25 --detailed all
  PYTHONPATH=src python -m repro.launch.scenario --scenario all_to_all \
      --devices 16 --nodes 4 --detailed all --fabric rail_optimized
  PYTHONPATH=src python -m repro.launch.scenario --scenario ring_allreduce \
      --devices 8 --nodes 4 --detailed all --fabric fat_tree \
      --link spine=3.125
  PYTHONPATH=src python -m repro.launch.scenario --scenario ring_allreduce \
      --devices 8 --detailed all --verify
  PYTHONPATH=src python -m repro.launch.scenario --scenario all_to_all \
      --devices 8 --detailed all --sanitize

``-p/--param key=value`` sets a scenario constructor parameter or a SimConfig
field for a single run; ``--sweep key=v1,v2,...`` builds a grid handled by
:class:`repro.core.scenario.SweepRunner` (config fields and scenario params
are told apart automatically).  Values are parsed as Python literals when
possible, else kept as strings.

``--devices N`` sets the total device count; ``--detailed all`` promotes every
device to a program-driven detailed device in one closed simulation loop
(``closed_loop=True`` — flags are emitted over the fabric instead of
pre-scheduled), while the default ``--detailed 0`` keeps the open-loop
single-detailed-device replay.

``--nodes K`` splits the devices into K nodes (``devices_per_node = N / K``):
intra-node hops ride the ICI tier, inter-node hops the per-node DCI uplinks.
``--fabric NAME`` selects a registered interconnect preset (``ring``,
``two_tier``, ``fat_tree``, ``rail_optimized``, ``torus2d`` — see
``--list-fabrics``) for the closed-loop fabric; ``--link CLASS=GBPS``
overrides one link class's bandwidth (repeatable; unknown classes raise an
error listing the fabric's valid ones).  ``--ici-bw`` / ``--dci-bw`` remain
as aliases for ``--link ici=…`` / ``--link dci=…`` (and additionally scale
the open-loop arrival schedules derived from the hardware model).
"""

from __future__ import annotations

import argparse
import ast
import sys
from typing import Dict, List

from repro.core import (
    EngineKind,
    SimConfig,
    SweepRunner,
    SyncPolicy,
    get_fabric,
    get_scenario,
    list_fabrics,
    list_scenarios,
    simulate,
)
from repro.core.scenario import SIM_CONFIG_FIELDS as _CFG_FIELDS

__all__ = ["main"]


def _literal(text: str):
    try:
        return ast.literal_eval(text)
    except (ValueError, SyntaxError):
        return text


def _split_top_level(text: str) -> List[str]:
    """Split on commas not nested in (), [] or {} — so sweep values may be
    tuples/lists, e.g. ``flag_delays_ns=(0,8000),(0,16000)``."""
    out, buf, depth = [], [], 0
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(buf))
            buf = []
        else:
            buf.append(ch)
    out.append("".join(buf))
    return out


def _parse_kv(pairs: List[str], *, split_values: bool = False) -> Dict[str, object]:
    out: Dict[str, object] = {}
    for pair in pairs:
        if "=" not in pair:
            raise SystemExit(f"expected key=value, got {pair!r}")
        key, _, val = pair.partition("=")
        if split_values:
            out[key] = [_literal(v) for v in _split_top_level(val)]
        else:
            out[key] = _literal(val)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.scenario", description=__doc__.splitlines()[0]
    )
    ap.add_argument("--scenario", default="gemv_allreduce",
                    help="registered scenario name (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered scenarios and exit")
    ap.add_argument("--list-fabrics", action="store_true",
                    help="list registered interconnect presets and exit")
    ap.add_argument("--engine", default="event",
                    choices=[e.value for e in EngineKind])
    ap.add_argument("--engines", default=None,
                    help="comma-separated engine list (sweeps run each)")
    ap.add_argument("--sync", default="spin",
                    choices=[s.value for s in SyncPolicy])
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="total device count (sets n_egpus = N - 1)")
    ap.add_argument("--nodes", type=int, default=None, metavar="K",
                    help="group the devices into K nodes (devices_per_node = "
                         "N / K); intra-node traffic rides ICI, inter-node "
                         "traffic the per-node DCI uplinks")
    ap.add_argument("--fabric", default=None, metavar="NAME",
                    help="interconnect preset for the closed-loop fabric "
                         "(see --list-fabrics)")
    ap.add_argument("--link", action="append", default=[],
                    metavar="CLASS=GBPS",
                    help="override one link class's bandwidth in GB/s "
                         "(repeatable, e.g. --link spine=3.125); unknown "
                         "classes raise an error listing valid ones")
    ap.add_argument("--ici-bw", type=float, default=None, metavar="GBPS",
                    help="intra-node (ICI) link bandwidth override, GB/s "
                         "(alias for --link ici=GBPS; also scales open-loop "
                         "arrival schedules)")
    ap.add_argument("--dci-bw", type=float, default=None, metavar="GBPS",
                    help="inter-node (DCI) link bandwidth override, GB/s "
                         "(alias for --link dci=GBPS; also scales open-loop "
                         "arrival schedules)")
    ap.add_argument("--detailed", default="0", choices=["0", "all"],
                    help="'all': closed-loop cluster, every device detailed; "
                         "'0': open-loop replay with one detailed device")
    ap.add_argument("--verify", action="store_true",
                    help="statically verify the scenario's phase programs "
                         "(deadlock cycles, unmatched sync, slot races, "
                         "fabric reachability) instead of simulating; exits "
                         "non-zero with the diagnosis on a broken program")
    ap.add_argument("--prove-layout", action="store_true",
                    help="run the parametric layout prover instead of "
                         "simulating: certify flag/partial/marker "
                         "disjointness, unique flag writers, and wait/emit "
                         "ordering for ALL device counts up to the "
                         "scenario's max_devices bound (or --devices when "
                         "given); exits non-zero with the finding and the "
                         "smallest failing device count on a broken layout")
    ap.add_argument("--sanitize", action="store_true",
                    help="run the traffic sanitizer alongside the engines "
                         "(byte conservation, calendar monotonicity, "
                         "exactly-once flag delivery); requires "
                         "--detailed all")
    ap.add_argument("-p", "--param", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="scenario parameter or SimConfig override")
    ap.add_argument("--sweep", action="append", default=[],
                    metavar="KEY=V1,V2,...",
                    help="sweep a parameter over a list of values")
    ap.add_argument("--csv", default=None,
                    help="write sweep results to this CSV file")
    args = ap.parse_args(argv)

    if args.list:
        for name in list_scenarios():
            cls = get_scenario(name)
            doc = (cls.__doc__ or cls.__module__).strip().splitlines()[0]
            print(f"{name:18s} {doc}")
        return 0

    if args.list_fabrics:
        for name in list_fabrics():
            builder = get_fabric(name)
            doc = " ".join(
                (builder.__doc__ or builder.__module__).strip().split()
            )
            print(f"{name:16s} {doc}")
        return 0

    try:
        get_scenario(args.scenario)
    except KeyError as e:
        raise SystemExit(f"error: {e.args[0]}")
    if args.fabric is not None:
        try:
            get_fabric(args.fabric)
        except KeyError as e:
            raise SystemExit(f"error: {e.args[0]}")

    engines = [
        EngineKind(e)
        for e in (args.engines.split(",") if args.engines else [args.engine])
    ]
    params = _parse_kv(args.param)
    cfg_over = {k: v for k, v in params.items() if k in _CFG_FIELDS}
    sc_params = {k: v for k, v in params.items() if k not in _CFG_FIELDS}
    if args.detailed == "all":
        sc_params["closed_loop"] = True
    if args.nodes is not None:
        if args.devices is None or args.devices % args.nodes:
            raise SystemExit(
                f"error: --nodes {args.nodes} needs --devices divisible by it"
            )
        sc_params.setdefault("devices_per_node", args.devices // args.nodes)
    if args.fabric is not None:
        sc_params.setdefault("fabric", args.fabric)
    # per-link-class bandwidth overrides (GB/s == bytes/ns); these flow
    # through InterconnectSpec.with_link_overrides, which *validates* the
    # class names against the fabric instead of silently ignoring them
    link_bw: Dict[str, float] = {}
    for pair in args.link:
        key, sep, val = pair.partition("=")
        if not sep:
            raise SystemExit(f"error: expected --link CLASS=GBPS, got {pair!r}")
        try:
            link_bw[key] = float(val)
        except ValueError:
            raise SystemExit(
                f"error: --link {key} needs a numeric GB/s value, got {val!r}"
            )
    if args.ici_bw is not None:
        link_bw.setdefault("ici", args.ici_bw)
    if args.dci_bw is not None:
        link_bw.setdefault("dci", args.dci_bw)
    if link_bw:
        sc_params.setdefault("link_bw", link_bw)
    if args.ici_bw is not None or args.dci_bw is not None:
        # the legacy aliases also scale the hardware model, so open-loop
        # arrival schedules (derived from hw, not the fabric) shift too
        from dataclasses import replace as _replace

        from repro.core.topology import V5E

        hw = sc_params.get("hw", V5E)
        if args.ici_bw is not None:
            hw = _replace(hw, ici_link_bw=args.ici_bw * 1e9)
        if args.dci_bw is not None:
            hw = _replace(hw, dci_link_bw=args.dci_bw * 1e9)
        sc_params["hw"] = hw
    try:
        base_cfg = SimConfig(sync=SyncPolicy(args.sync), **cfg_over)
        if args.devices is not None:
            base_cfg = base_cfg.with_devices(args.devices)
    except ValueError as e:
        raise SystemExit(f"error: {e}")

    if args.verify:
        from repro.analysis import verify_scenario

        try:
            verdict = verify_scenario(args.scenario, base_cfg, **sc_params)
        except (NotImplementedError, TypeError, ValueError) as e:
            raise SystemExit(f"error: {e}")
        print(verdict.render())
        return 0 if verdict.ok else 1

    if args.prove_layout:
        from repro.analysis import prove_layout

        pl_params = dict(sc_params)
        pl_params.pop("closed_loop", None)
        try:
            proof = prove_layout(
                args.scenario,
                devices_per_node=pl_params.pop("devices_per_node", None),
                fabric=pl_params.pop("fabric", None),
                max_devices=args.devices,
                **pl_params,
            )
        except (NotImplementedError, TypeError, ValueError) as e:
            raise SystemExit(f"error: {e}")
        print(proof.render())
        return 0 if proof.ok else 1

    if args.sanitize and args.detailed != "all":
        raise SystemExit(
            "error: --sanitize requires --detailed all (the sanitizer "
            "shadows the closed-loop cluster)"
        )

    if args.sweep:
        grid = _parse_kv(args.sweep, split_values=True)
        runner = SweepRunner(args.scenario, base_cfg, engines=engines)
        if sc_params:
            # non-swept scenario params become single-value grid axes
            grid.update({k: [v] for k, v in sc_params.items()})
        try:
            points = runner.run(grid)
        except KeyError as e:  # unknown fabric/scenario via -p or --sweep
            raise SystemExit(f"error: {e.args[0]}")
        except (NotImplementedError, TypeError, ValueError) as e:
            raise SystemExit(f"error: {e}")
        csv = SweepRunner.to_csv(points)
        print(csv)
        if args.csv:
            with open(args.csv, "w") as f:
                f.write(csv + "\n")
            print(f"# wrote {len(points)} rows to {args.csv}", file=sys.stderr)
        return 0

    for eng in engines:
        cfg = base_cfg.with_(engine=eng)
        try:
            report = simulate(args.scenario, cfg, collect_segments=False,
                              sanitize=args.sanitize, **sc_params)
        except KeyError as e:  # unknown fabric preset via -p fabric=...
            raise SystemExit(f"error: {e.args[0]}")
        except (NotImplementedError, TypeError, ValueError) as e:
            raise SystemExit(f"error: {e}")
        print(report.summary())
        if report.closed_loop:
            print(report.device_summary())
            ps = report.meta.get("program_stats")
            if ps:
                impl = ("lockstep" if ps.get("lockstep")
                        else report.meta.get("engine_impl", "?"))
                print(
                    f"programs: {ps['symbolic_programs']} symbolic / "
                    f"{ps['flat_programs']} flat | "
                    f"{ps['program_phases']} phases "
                    f"({ps['materialized_phases']} materialized, "
                    f"{ps['segments']} segments) | "
                    f"built in {ps['construct_wall_s'] * 1e3:.1f} ms | "
                    f"advanced by {impl}"
                )
            reason = report.meta.get("lockstep_reason")
            if reason:
                print(f"lockstep: {reason}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
