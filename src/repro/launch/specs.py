"""ShapeDtypeStruct input stand-ins + sharding resolution for dry-run cells.

``input_specs(arch, shape)`` returns weak-type-correct, shardable,
zero-allocation stand-ins for every input of the cell's step function:
train/prefill get token (+stub-embedding) batches; decode gets tokens, the
position scalar, and the full per-layer cache tree sized to the cell's
seq_len.  ``cache_shardings`` places caches: batch over data, KV heads over
model, and — when batch is unshardable (long_500k's batch=1) — the cache
*sequence* dim over data (sequence-parallel flash decoding).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.shapes import ShapeSpec
from repro.distributed import DEFAULT_RULES, param_shardings
from repro.models import Model

__all__ = ["input_specs", "cache_shardings", "batch_shardings", "CellSpec"]


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(model: Model, shape: ShapeSpec) -> Dict[str, Any]:
    """Stand-ins for one cell's step inputs (no device allocation)."""
    cfg = model.cfg
    B, S = shape.global_batch, shape.seq_len
    has_frontend = cfg.frontend != "none"
    if shape.mode == "train":
        out: Dict[str, Any] = {
            "tokens": _tok((B, S)),
            "labels": _tok((B, S)),
        }
        if has_frontend:
            # modality stub: precomputed frame/patch embeddings
            out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.param_dtype)
        return out
    if shape.mode == "prefill":
        out = {"tokens": _tok((B, S))}
        if has_frontend:
            out["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.param_dtype)
        return out
    # decode: one new token against a cache of S resident tokens
    out = {
        "tokens": _tok((B,)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "caches": model.abstract_caches(B, S),
    }
    if has_frontend:
        out["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cfg.param_dtype)
    return out


def _cache_leaf_spec(shape: Tuple[int, ...], mesh: Mesh, batch: int) -> P:
    """Heuristic cache placement (documented in DESIGN.md §Sharding)."""
    dsz = mesh.shape.get("data", 1)
    msz = mesh.shape.get("model", 1)
    parts = [None] * len(shape)
    batch_sharded = False
    if len(shape) >= 1 and shape[0] == batch and batch % dsz == 0 and dsz > 1:
        parts[0] = "data"
        batch_sharded = True
    # model axis: ONLY the heads-like dim (position 2 of 4-D caches: KV heads
    # for attention, head groups for SSM state).  Sharding seq or head_dim on
    # model forces SPMD reshards at every attention contraction (measured:
    # "involuntary full rematerialization" warnings) — replicate instead.
    if len(shape) >= 4 and msz > 1 and shape[2] % msz == 0 and shape[2] >= msz:
        parts[2] = "model"
    elif len(shape) == 3 and msz > 1 and shape[1] % msz == 0 and shape[1] >= msz:
        # MLA latent caches (B, S, r): split-S flash decoding over the model
        # axis — heads are absorbed away, so S is the only parallel dim left
        parts[1] = "model"
    if not batch_sharded and len(shape) >= 3 and dsz > 1:
        # sequence-parallel fallback (long_500k): shard the seq dim on data
        if shape[1] % dsz == 0 and shape[1] >= dsz:
            parts[1] = "data"
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def cache_shardings(model: Model, mesh: Mesh, batch: int, max_len: int):
    ab = model.abstract_caches(batch, max_len)
    return jax.tree.map(
        lambda l: NamedSharding(mesh, _cache_leaf_spec(l.shape, mesh, batch)), ab
    )


def batch_shardings(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    spec = axes if len(axes) > 1 else (axes[0] if axes else None)
    return NamedSharding(mesh, P(spec))
