"""Roofline table generator: reads results/dryrun/*.json, emits §Roofline.

Per (arch x shape x mesh) cell:
  compute_s    = flops_per_device / 197e12        (bf16 peak, v5e)
  memory_s     = bytes_per_device / 819e9         (HBM)
  collective_s = coll_bytes_per_device / 50e9     (ICI link)
plus the dominant term, MODEL_FLOPS/HLO_FLOPs usefulness ratio, HBM fit,
and a one-line "what would move the dominant term" note.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir results/dryrun]
      [--json results/roofline.json] [--md results/roofline.md]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

from repro.core.predictor import roofline
from repro.core.topology import Topology, V5E

__all__ = ["build_table", "load_records", "render_markdown"]

_ADVICE = {
    "compute": (
        "compute-bound: cut recompute (remat policy) or raise per-chip "
        "efficiency (larger matmul tiles / fused kernels)"
    ),
    "memory": (
        "HBM-bound: fuse elementwise chains, keep activations bf16, "
        "shrink optimizer-state traffic (ZeRO already on)"
    ),
    "collective": (
        "collective-bound: reduce-scatter instead of all-reduce for grads, "
        "bf16/int8 gradient compression, overlap collectives under compute"
    ),
}


def _topo_for(mesh_name: str) -> Topology:
    if mesh_name == "multi":
        return Topology((2, 16, 16), ("pod", "data", "model"), V5E)
    if mesh_name == "single":
        return Topology((16, 16), ("data", "model"), V5E)
    dims = tuple(int(x) for x in mesh_name.split("x"))
    names = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[
        len(dims)
    ]
    return Topology(dims, names, V5E)


def load_records(dir_: str, tag: str = "") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        rec_tag = parts[3] if len(parts) > 3 else ""
        if rec_tag != tag:
            continue
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def build_table(recs: List[Dict]) -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("status") != "ok":
            rows.append(
                {
                    "arch": r["arch"],
                    "shape": r["shape"],
                    "mesh": r["mesh"],
                    "status": r.get("status"),
                    "note": r.get("skip_reason", r.get("error", "")),
                }
            )
            continue
        topo = _topo_for(r["mesh"])
        terms = roofline(
            arch=r["arch"],
            shape=r["shape"],
            mesh=r["mesh"],
            topo=topo,
            hlo_flops_per_device=r["flops_per_device"],
            hlo_bytes_per_device=r["bytes_per_device"],
            collective_bytes_per_device=int(r["collective_bytes_per_device"]),
            model_flops_total=r["model_flops"],
            bytes_per_device_hbm=int(r.get("hbm_bytes_per_device", 0)),
        )
        d = terms.as_dict()
        d["status"] = "ok"
        d["note"] = _ADVICE[terms.dominant]
        d["options"] = r.get("options", {})
        rows.append(d)
    return rows


def render_markdown(rows: List[Dict]) -> str:
    hdr = (
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "dominant | useful | roofline_frac | HBM/dev | fits |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        if r.get("status") != "ok":
            lines.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | "
                f"{r.get('status')} | - | - | - | {r.get('note','')[:60]} |"
            )
            continue
        lines.append(
            "| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {k:.4f} | "
            "**{dom}** | {u:.2f} | {rf:.3f} | {gb:.1f} GiB | {fit} |".format(
                arch=r["arch"],
                shape=r["shape"],
                mesh=r["mesh"],
                c=r["compute_s"],
                m=r["memory_s"],
                k=r["collective_s"],
                dom=r["dominant"],
                u=r["useful_flops_ratio"],
                rf=r["roofline_fraction"],
                gb=r["bytes_per_device_hbm"] / 2**30,
                fit="yes" if r["fits_hbm"] else "NO",
            )
        )
    return hdr + "\n".join(lines) + "\n"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--json", dest="json_out", default="results/roofline.json")
    ap.add_argument("--md", dest="md_out", default="results/roofline.md")
    args = ap.parse_args()

    recs = load_records(args.dir, args.tag)
    rows = build_table(recs)
    os.makedirs(os.path.dirname(args.json_out) or ".", exist_ok=True)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    md = render_markdown(rows)
    with open(args.md_out, "w") as f:
        f.write(md)
    print(md)
    ok = [r for r in rows if r.get("status") == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        collb = max(ok, key=lambda r: r["collective_s"])
        print(
            f"worst roofline fraction: {worst['arch']} x {worst['shape']} "
            f"({worst['roofline_fraction']:.3f})"
        )
        print(
            f"most collective-bound: {collb['arch']} x {collb['shape']} "
            f"({collb['collective_s']:.4f}s)"
        )


if __name__ == "__main__":
    main()
