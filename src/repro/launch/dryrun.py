import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with ZERO device allocation:
  - ``compiled.memory_analysis()``  -> bytes/device (does it fit HBM?)
  - ``compiled.cost_analysis()``    -> per-device HLO FLOPs + bytes accessed
  - parsed collective schedule      -> per-device collective bytes by kind
and writes one JSON record per cell to ``results/dryrun/``.  EXPERIMENTS.md
§Dry-run/§Roofline and the Eidola pod-scale replay all read these records.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh single|multi|both] [--out DIR]
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import META, REGISTRY, SHAPES, get_config
from repro.configs.shapes import cells_for
from repro.core.hlo_analyzer import analyze_hlo
from repro.distributed import DEFAULT_RULES
from repro.launch.mesh import make_mesh_by_name
from repro.launch.specs import batch_shardings, cache_shardings, input_specs
from repro.models import Model
from repro.optim import AdamWConfig
from repro.training import TrainConfig, build_train_step

DEFAULT_OUT = "results/dryrun"


# ---------------------------------------------------------------------------
# step builders per mode
# ---------------------------------------------------------------------------


def _lower_train(model: Model, mesh, shape, opts) -> Any:
    tcfg = TrainConfig(
        remat_policy=opts.get("remat", "none"),
        optim=AdamWConfig(master_fp32=not opts.get("no_master", False)),
        microbatches=opts.get("microbatches", 1),
        zero1_model_dim=opts.get("zero1_model_dim",
                                 model.n_params() > 100e9),
        donate_state=True,
    )
    step_fn, shardings, fallbacks = build_train_step(model, mesh, tcfg)
    ins = input_specs(model, shape)
    from repro.optim import adamw_init

    abstract_params = model.abstract_params()
    abstract_state = jax.eval_shape(lambda p: adamw_init(p, tcfg.optim), abstract_params)
    args = [abstract_params, abstract_state, ins["tokens"], ins["labels"]]
    if "embeds" in ins:
        args.append(ins["embeds"])
    with mesh:
        lowered = step_fn.lower(*args)
    return lowered, fallbacks


def _param_shardings(model: Model, mesh):
    from repro.distributed import param_shardings

    return param_shardings(
        model.param_axes(), model.abstract_params(), mesh, DEFAULT_RULES
    )


def _lower_prefill(model: Model, mesh, shape, opts):
    p_shard, fallbacks = _param_shardings(model, mesh)
    b_shard = batch_shardings(mesh)
    ins = input_specs(model, shape)
    kwargs = {}
    if "embeds" in ins:
        fn = lambda p, e: model.prefill(p, None, embeds=e)  # noqa: E731
        in_sh = (p_shard, b_shard)
        args = (model.abstract_params(), ins["embeds"])
    else:
        fn = lambda p, t: model.prefill(p, t)  # noqa: E731
        in_sh = (p_shard, b_shard)
        args = (model.abstract_params(), ins["tokens"])
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
    return lowered, fallbacks


def _lower_decode(model: Model, mesh, shape, opts):
    p_shard, fallbacks = _param_shardings(model, mesh)
    ins = input_specs(model, shape)
    B, S = shape.global_batch, shape.seq_len
    c_shard = cache_shardings(model, mesh, B, S)
    tok_shard = batch_shardings(mesh) if B % mesh.shape.get("data", 1) == 0 and B > 1 else None
    if "embeds" in ins:
        fn = lambda p, c, t, pos, e: model.decode_step(  # noqa: E731
            p, c, t, pos, embeds=e
        )
        in_sh = (p_shard, c_shard, tok_shard, None, None)
        args = (model.abstract_params(), ins["caches"], ins["tokens"], ins["pos"],
                ins["embeds"])
    else:
        fn = lambda p, c, t, pos: model.decode_step(p, c, t, pos)  # noqa: E731
        in_sh = (p_shard, c_shard, tok_shard, None)
        args = (model.abstract_params(), ins["caches"], ins["tokens"], ins["pos"])
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=None).lower(*args)
    return lowered, fallbacks


# ---------------------------------------------------------------------------
# cell runner
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    shape_name: str,
    mesh_name: str,
    opts: Optional[Dict[str, Any]] = None,
    *,
    verbose: bool = True,
) -> Dict[str, Any]:
    opts = opts or {}
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "mode": shape.mode,
        "options": opts,
        "meta": META.get(arch, {}),
        "status": "ok",
    }
    if shape_name == "long_500k" and not cfg.supports_500k:
        rec["status"] = "skipped"
        rec["skip_reason"] = (
            "pure full-attention arch; long_500k skipped per assignment"
        )
        return rec
    if opts.get("attn_constraints"):
        cfg = cfg.with_(attn_sharding_constraints=True)
    if opts.get("mla_absorbed"):
        cfg = cfg.with_(mla_absorbed_decode=True)
    mesh = make_mesh_by_name(mesh_name)
    model = Model(cfg, mesh=mesh)
    rec["n_params"] = model.n_params()
    rec["n_active_params"] = model.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    if shape.mode == "train":
        rec["model_flops"] = 6.0 * model.n_active_params() * tokens
    else:
        rec["model_flops"] = 2.0 * model.n_active_params() * tokens
    try:
        t0 = time.perf_counter()
        if shape.mode == "train":
            lowered, fallbacks = _lower_train(model, mesh, shape, opts)
        elif shape.mode == "prefill":
            lowered, fallbacks = _lower_prefill(model, mesh, shape, opts)
        else:
            lowered, fallbacks = _lower_decode(model, mesh, shape, opts)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis()
        ca0 = ca[0] if isinstance(ca, list) else ca
        hlo = compiled.as_text()
        # trip-count-aware analysis (XLA's cost_analysis counts while bodies
        # once; see core/hlo_analyzer.py) — the primary §Roofline source
        mod = analyze_hlo(hlo)
        colls = mod.collectives_by_kind()
        rec.update(
            {
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "fallbacks": fallbacks,
                "flops_per_device": float(mod.total_flops()),
                "dot_flops_per_device": float(mod.dot_flops()),
                "bytes_per_device": float(mod.total_bytes()),
                "xla_flops_raw": float(ca0.get("flops", 0.0)),
                "xla_bytes_raw": float(ca0.get("bytes accessed", 0.0)),
                "max_scan_trip": mod.max_while_trip(),
                "memory": {
                    "argument_bytes": mem.argument_size_in_bytes,
                    "output_bytes": mem.output_size_in_bytes,
                    "temp_bytes": mem.temp_size_in_bytes,
                    "alias_bytes": mem.alias_size_in_bytes,
                    "code_bytes": mem.generated_code_size_in_bytes,
                },
                "collectives": {
                    k: {"count": c, "bytes": b} for k, (c, b) in colls.items()
                },
                "collective_bytes_per_device": float(mod.collective_bytes()),
                "n_collective_ops": int(sum(c for c, _ in colls.values())),
            }
        )
        # live bytes per device (arguments alias in-place via donation)
        rec["hbm_bytes_per_device"] = (
            mem.argument_size_in_bytes
            + mem.temp_size_in_bytes
            + max(mem.output_size_in_bytes - mem.alias_size_in_bytes, 0)
        )
        if verbose:
            print(
                f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
                f"compile={t_compile:.1f}s "
                f"flops/dev={rec['flops_per_device']:.3e} "
                f"coll_bytes/dev={rec['collective_bytes_per_device']:,} "
                f"hbm/dev={rec['hbm_bytes_per_device'] / 2**30:.2f} GiB"
            )
    except Exception as e:  # noqa: BLE001 - recorded, rerun fails loudly
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: ERROR {e}")
    return rec


def cell_path(out_dir: str, arch: str, shape: str, mesh: str, tag: str = "") -> str:
    suffix = f"__{tag}" if tag else ""
    return os.path.join(out_dir, f"{arch}__{shape}__{mesh}{suffix}.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(REGISTRY), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--mesh", default="both", help="single|multi|both|AxB[xC]")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tag", default="", help="variant tag for perf iterations")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn-constraints", action="store_true")
    ap.add_argument("--mla-absorbed", action="store_true")
    ap.add_argument("--no-master", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    opts = {"remat": args.remat, "microbatches": args.microbatches,
            "attn_constraints": args.attn_constraints,
            "mla_absorbed": args.mla_absorbed,
            "no_master": args.no_master}
    # note: `v not in (1, False)` would drop True since True == 1 in Python
    opts = {
        k: v for k, v in opts.items()
        if not (v is False or v == "none" or (k == "microbatches" and v == 1))
    }

    if args.all:
        cells = []
        for arch in REGISTRY:
            if META.get(arch, {}).get("tier") == "variant":
                continue  # beyond-pool variants run individually, not in --all
            for shape_name, skip in cells_for(get_config(arch)):
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch, shape_name in cells:
            path = cell_path(args.out, arch, shape_name, mesh_name, args.tag)
            if args.skip_existing and os.path.exists(path):
                continue
            rec = run_cell(arch, shape_name, mesh_name, opts)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            n_ok += rec["status"] == "ok"
            n_skip += rec["status"] == "skipped"
            n_err += rec["status"] == "error"
    print(f"[dryrun] done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
