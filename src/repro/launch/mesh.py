"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (device count is locked at first jax init, and only
``dryrun.py`` forces the 512-device placeholder platform).
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh_by_name"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_by_name(name: str):
    """'single' -> 16x16, 'multi' -> 2x16x16, 'AxB[xC]' -> custom."""
    if name == "single":
        return make_production_mesh(multi_pod=False)
    if name == "multi":
        return make_production_mesh(multi_pod=True)
    dims = tuple(int(x) for x in name.split("x"))
    axes = {1: ("data",), 2: ("data", "model"), 3: ("pod", "data", "model")}[
        len(dims)
    ]
    return jax.make_mesh(dims, axes)
