"""Batched-serving driver: prefill + decode with the slot engine.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --requests 8 --prompt-len 32 --new-tokens 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import REGISTRY, get_config, reduced
from repro.models import Model
from repro.serving import ServeConfig, ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(REGISTRY), default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"[serve] {cfg.name}: {model.n_params()/1e6:.1f}M params")

    rng = np.random.default_rng(0)
    prompts = [
        list(rng.integers(1, cfg.vocab, size=args.prompt_len))
        for _ in range(args.requests)
    ]
    eng = ServeEngine(
        model,
        params,
        ServeConfig(max_batch=args.max_batch, temperature=args.temperature),
    )
    t0 = time.perf_counter()
    outs = eng.generate(prompts, args.new_tokens)
    dt = time.perf_counter() - t0
    gen_tokens = sum(len(o) - args.prompt_len for o in outs)
    print(
        f"[serve] {args.requests} requests, {gen_tokens} new tokens "
        f"in {dt:.2f}s ({gen_tokens / dt:.1f} tok/s); stats={eng.stats}"
    )
    print("[serve] sample:", outs[0][: args.prompt_len + 8])


if __name__ == "__main__":
    main()
