"""End-to-end training driver.

Runs real training on this host (reduced or custom configs; the ~100M
quickstart in examples/ uses this).  On a cluster the same entry point runs
the full configs — the step function, sharding rules, checkpointing and
fault-tolerance hooks are identical; only the mesh differs.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --steps 200 \
      --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY, get_config, reduced
from repro.data import DataConfig, SyntheticLMDataset, prefetch
from repro.models import Model
from repro.optim import AdamWConfig
from repro.training import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=sorted(REGISTRY), default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--reduced", action="store_true",
                    help="family-preserving reduced config (CPU-friendly)")
    ap.add_argument("--layers", type=int, default=0, help="override n_layers")
    ap.add_argument("--d-model", type=int, default=0, help="override d_model")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="AxB data x model")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    overrides = {}
    if args.layers:
        overrides["n_layers"] = args.layers
    if args.d_model:
        overrides["d_model"] = args.d_model
    if overrides:
        cfg = cfg.with_(**overrides)
    model = Model(cfg)
    print(f"[train] {cfg.name}: {model.n_params()/1e6:.1f}M params "
          f"({model.n_active_params()/1e6:.1f}M active), mesh={args.mesh}")

    dims = tuple(int(x) for x in args.mesh.split("x"))
    mesh = jax.make_mesh(dims, ("data", "model")[: len(dims)])

    tcfg = TrainConfig(
        microbatches=args.microbatches,
        remat_policy=args.remat,
        optim=AdamWConfig(
            lr=args.lr, warmup_steps=max(args.steps // 20, 5),
            total_steps=args.steps,
        ),
    )
    trainer = Trainer(
        model, mesh, tcfg, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every
    )
    if not trainer.maybe_restore():
        trainer.init_state(jax.random.PRNGKey(0))
        print("[train] fresh init")
    else:
        print(f"[train] restored from step {trainer.step}")

    data = SyntheticLMDataset(
        DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    )
    t0 = time.perf_counter()
    history = trainer.run(
        prefetch(iter(data)), args.steps, log_every=args.log_every
    )
    dt = time.perf_counter() - t0
    if history:
        tokens = args.steps * args.batch * args.seq
        print(
            f"[train] {len(history)} steps in {dt:.1f}s "
            f"({tokens / dt:,.0f} tok/s); "
            f"loss {history[0]['loss']:.4f} -> {history[-1]['loss']:.4f}"
        )
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f)


if __name__ == "__main__":
    main()
