"""Optimizer substrate (raw JAX pytrees — no optax in this environment).

AdamW with fp32 master weights + moments (ZeRO-1-shardable), global-norm
clipping, and linear-warmup cosine decay.
"""

from .adamw import AdamWConfig, adamw_init, adamw_step, cosine_lr, global_norm

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_step",
    "cosine_lr",
    "global_norm",
]
