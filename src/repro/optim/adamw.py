"""AdamW with fp32 master weights, global-norm clipping, warmup+cosine LR."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_step", "cosine_lr", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    master_fp32: bool = True


def cosine_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps)
        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def adamw_init(params, cfg: AdamWConfig) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    state = {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
    }
    if cfg.master_fp32:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_step(
    params, grads, state, cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    masters = state.get("master", params)

    def upd(g, m, v, w):
        g32 = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mh = m / c1
        vh = v / c2
        w32 = w.astype(jnp.float32)
        w32 = w32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * w32)
        return m, v, w32

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["mu"])
    flat_v = treedef.flatten_up_to(state["nu"])
    flat_w = treedef.flatten_up_to(masters)
    new_m, new_v, new_w = [], [], []
    for g, m, v, w in zip(flat_g, flat_m, flat_v, flat_w):
        m2, v2, w2 = upd(g, m, v, w)
        new_m.append(m2)
        new_v.append(v2)
        new_w.append(w2)
    mu = jax.tree.unflatten(treedef, new_m)
    nu = jax.tree.unflatten(treedef, new_v)
    master = jax.tree.unflatten(treedef, new_w)
    old_flat = treedef.flatten_up_to(params)
    new_params = jax.tree.unflatten(
        treedef, [w.astype(p.dtype) for w, p in zip(new_w, old_flat)]
    )
    new_state = {"step": step, "mu": mu, "nu": nu}
    if cfg.master_fp32:
        new_state["master"] = master
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
