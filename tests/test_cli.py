"""CLI entry-point smoke tests (train/serve drivers)."""

import os
import subprocess
import sys

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        env=env, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2500:]
    return out.stdout


def test_train_cli_reduced_runs_and_learns():
    out = _run([
        "repro.launch.train", "--arch", "olmoe-1b-7b", "--reduced",
        "--steps", "20", "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--log-every", "0",
    ])
    assert "loss" in out
    # "loss a -> b" with b < a
    seg = out.split("loss")[-1]
    a, b = (float(x.strip().rstrip(";")) for x in seg.split("->"))
    assert b < a, out


def test_serve_cli_runs():
    out = _run([
        "repro.launch.serve", "--arch", "xlstm-125m", "--requests", "2",
        "--prompt-len", "8", "--new-tokens", "4", "--max-batch", "2",
    ])
    assert "tok/s" in out
