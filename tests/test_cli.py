"""CLI entry-point smoke tests (train/serve drivers)."""

import os
import subprocess
import sys

import pytest

# model-forward-dominated: runs in the separate slow CI job, not the fast
# simulator suite
pytestmark = pytest.mark.slow

REPO = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(REPO, "src")


def _run(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        env=env, timeout=timeout, cwd=REPO,
    )
    assert out.returncode == 0, out.stderr[-2500:]
    return out.stdout


def test_train_cli_reduced_runs_and_learns():
    out = _run([
        "repro.launch.train", "--arch", "olmoe-1b-7b", "--reduced",
        "--steps", "20", "--batch", "4", "--seq", "32", "--lr", "1e-2",
        "--log-every", "0",
    ])
    assert "loss" in out
    # "loss a -> b" with b < a
    seg = out.split("loss")[-1]
    a, b = (float(x.strip().rstrip(";")) for x in seg.split("->"))
    assert b < a, out


def test_serve_cli_runs():
    out = _run([
        "repro.launch.serve", "--arch", "xlstm-125m", "--requests", "2",
        "--prompt-len", "8", "--new-tokens", "4", "--max-batch", "2",
    ])
    assert "tok/s" in out


def test_scenario_cli_lists_and_runs():
    out = _run(["repro.launch.scenario", "--list"])
    for name in ("gemv_allreduce", "ring_allreduce", "all_to_all",
                 "pipeline_p2p"):
        assert name in out
    out = _run([
        "repro.launch.scenario", "--scenario", "ring_allreduce",
        "--engines", "cycle,event", "--sync", "syncmon",
        "-p", "workgroups=16",
    ])
    lines = [l for l in out.strip().splitlines() if l.startswith("[")]
    assert len(lines) == 2
    # both engines printed the same traffic counts
    counts = {
        (l.split("flag_reads=")[1].split()[0],
         l.split("nonflag_reads=")[1].split()[0])
        for l in lines
    }
    assert len(counts) == 1


def test_scenario_cli_listing_flags():
    """Both registry listings: --list (scenarios) and --list-fabrics
    (interconnect presets)."""
    out = _run(["repro.launch.scenario", "--list"])
    for name in ("gemv_allreduce", "ring_allreduce", "all_to_all",
                 "pipeline_p2p", "hierarchical_allreduce"):
        assert name in out
    out = _run(["repro.launch.scenario", "--list-fabrics"])
    for name in ("ring", "two_tier", "fat_tree", "rail_optimized", "torus2d"):
        assert name in out
    assert "oversubscribed" in out  # the gallery one-liners are printed


def test_scenario_cli_fabric_preset_and_link_override():
    out = _run([
        "repro.launch.scenario", "--scenario", "all_to_all",
        "--devices", "8", "--nodes", "4", "--detailed", "all",
        "--fabric", "rail_optimized", "--link", "rail=25",
        "-p", "workgroups=8",
    ])
    assert "8dev closed" in out
    # unknown link classes are rejected with the valid list, not ignored
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    bad = subprocess.run(
        [sys.executable, "-m", "repro.launch.scenario", "--scenario",
         "all_to_all", "--devices", "8", "--nodes", "4", "--detailed", "all",
         "--fabric", "rail_optimized", "--dci-bw", "6.25"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560,
    )
    assert bad.returncode != 0
    assert "dci" in bad.stderr and "rail" in bad.stderr


def test_scenario_cli_closed_loop_devices():
    out = _run([
        "repro.launch.scenario", "--scenario", "ring_allreduce",
        "--devices", "4", "--detailed", "all", "--engines", "cycle,event",
        "-p", "workgroups=12",
    ])
    lines = [l for l in out.strip().splitlines() if l.startswith("[")]
    assert len(lines) == 2
    assert all("4dev closed" in l for l in lines)
    # per-device breakdown printed for each engine, identical counts
    assert out.count("device 0:") == 2
    counts = {
        (l.split("flag_reads=")[1].split()[0],
         l.split("nonflag_reads=")[1].split()[0])
        for l in lines
    }
    assert len(counts) == 1


def test_scenario_cli_sweep_csv(tmp_path):
    csv_path = str(tmp_path / "sweep.csv")
    out = _run([
        "repro.launch.scenario", "--scenario", "gemv_allreduce",
        "--sweep", "flag_delays_ns=0,8000", "-p", "workgroups=16",
        "--csv", csv_path,
    ])
    assert out.splitlines()[0].startswith("scenario,engine")
    with open(csv_path) as f:
        assert len(f.read().strip().splitlines()) == 3  # header + 2 rows


def test_scenario_cli_verify_ok():
    out = _run([
        "repro.launch.scenario", "--scenario", "ring_allreduce",
        "--devices", "8", "--verify",
    ])
    assert "verify 'ring_allreduce'" in out
    assert ": ok" in out


def test_scenario_cli_verify_rejects_broken_program():
    """--verify exits non-zero and prints the analyzer diagnosis, without
    ever starting a simulation."""
    import io
    from contextlib import redirect_stdout

    from repro.core.events import TraceBundle
    from repro.core.scenario import (
        EmitOp,
        PhaseSpec,
        Scenario,
        WGProgram,
        _REGISTRY,
        register_scenario,
    )
    from repro.launch.scenario import main

    @register_scenario
    class _BrokenRing(Scenario):
        name = "broken_ring_cli_test"
        closed_loop = True

        def __init__(self, cfg, amap=None, *, closed_loop=True):
            super().__init__(cfg, amap)
            self.closed_loop = True

        def programs_for(self, device):
            n = self.cfg.n_devices
            shared = (
                PhaseSpec(
                    "wait_flags",
                    wait_addrs=(self.amap.flag_addr((device + 1) % n),),
                ),
                PhaseSpec("drain", duration_cycles=5,
                          emits=(EmitOp((device - 1) % n),)),
            )
            return [
                WGProgram(wg=w, cu=w, dispatch_cycle=0, phases=shared)
                for w in range(self.cfg.workgroups)
            ]

        def programs(self):
            return self.programs_for(0)

        def traces(self):
            return TraceBundle()

    try:
        buf = io.StringIO()
        with redirect_stdout(buf):
            rc = main([
                "--scenario", "broken_ring_cli_test", "--devices", "4",
                "--verify",
            ])
        out = buf.getvalue()
        assert rc == 1
        assert "deadlock-cycle" in out
        assert "waits on flag" in out
    finally:
        _REGISTRY.pop("broken_ring_cli_test", None)


def test_scenario_cli_sanitize():
    out = _run([
        "repro.launch.scenario", "--scenario", "ring_allreduce",
        "--devices", "4", "--detailed", "all", "--sanitize",
        "-p", "workgroups=12",
    ])
    assert "4dev closed" in out
    # --sanitize without a closed-loop run is a usage error
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    bad = subprocess.run(
        [sys.executable, "-m", "repro.launch.scenario", "--scenario",
         "gemv_allreduce", "--sanitize"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=560,
    )
    assert bad.returncode != 0
    assert "--detailed all" in bad.stderr
