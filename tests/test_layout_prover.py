"""Parametric layout prover: seeded mutations must be blamed exactly.

Each test injects one layout fault into a real scenario — a shrunk
flag/partial gap (the legacy pre-clearance map), a duplicated emitter, an
off-by-stride marker window — and asserts the prover names the exact slot,
the writer pair, and (where violations grow monotonically with the flag
pool) the smallest failing device count, all without expanding a single
program.  The hypothesis property at the bottom closes the loop the other
way: layouts the prover calls clean never trip the runtime traffic
sanitizer.
"""

import dataclasses

from repro.analysis import check_layout, prove_layout, verify_scenario
from repro.core import EngineKind, SimConfig
from repro.core.memory import AddressMap
from repro.core.scenario import (
    EmitOp,
    PhaseSpec,
    SymbolicProgram,
    get_scenario,
    simulate,
)
from repro.core.scenarios.all_to_all import AllToAllScenario
from repro.core.scenarios.hierarchical_allreduce import (
    HierarchicalAllReduceScenario,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - optional dependency
    HAVE_HYPOTHESIS = False


def _legacy_amap(n: int, dpn: int) -> AddressMap:
    """The pre-clearance hierarchical map: flag pool sized for the stage
    slots but partial_base left at the class default."""
    nodes = n // dpn
    return AddressMap(n_devices=n, flag_slots=dpn + 2 * (nodes - 1) + 1)


class _LegacyHierarchical(HierarchicalAllReduceScenario):
    """hierarchical_allreduce with the shrunk-gap (legacy) address map."""

    def __init__(self, cfg, amap=None, **kw):
        n = cfg.n_devices
        dpn = kw.get("devices_per_node") or n
        super().__init__(cfg, _legacy_amap(n, dpn), **kw)


class _ShiftedHierarchical(HierarchicalAllReduceScenario):
    """Off-by-one-stride marker window: partial_base re-based 64 bytes
    below the proven clearance, so exactly one flag line can alias."""

    def __init__(self, cfg, amap=None, **kw):
        n = cfg.n_devices
        dpn = kw.get("devices_per_node") or n
        cleared = _legacy_amap(n, dpn).with_partial_clearance()
        super().__init__(
            cfg,
            dataclasses.replace(
                cleared, partial_base=cleared.partial_base - 64
            ),
            **kw,
        )


class _DuplicatedEmitter(AllToAllScenario):
    """all_to_all with one extra emission of an already-written flag."""

    def _symbolic_phases(self, rank, *, emit):
        prog = super()._symbolic_phases(rank, emit=emit)
        if not emit:
            return prog
        n = self.cfg.n_devices
        dup = PhaseSpec(
            "a2a_dispatch",
            1,
            emits=(EmitOp((rank + 1) % n, slot=0, payload_bytes=8),),
        )
        return SymbolicProgram(prog.segments + (dup,), group=prog.group)


# ---------------------------------------------------------------------------
# clean registry
# ---------------------------------------------------------------------------


def test_registry_layouts_proven_parametric():
    # every shipped closed-loop scenario must carry a parametric certificate
    # (not just probe checks) across the whole sweep
    for name in ("ring_allreduce", "all_to_all", "hierarchical_allreduce",
                 "pipeline_p2p"):
        proof = prove_layout(name, devices_per_node=4, max_devices=1024)
        assert proof.ok, proof.render()
        assert proof.parametric, (name, proof.notes)
        assert proof.checked_counts  # concrete anchors really ran


def test_prover_bound_comes_from_scenario_class():
    proof = prove_layout("ring_allreduce", devices_per_node=4)
    assert proof.max_devices == get_scenario("ring_allreduce").max_devices


# ---------------------------------------------------------------------------
# seeded mutations
# ---------------------------------------------------------------------------


def test_shrunk_gap_blamed_with_smallest_failing_count():
    # the legacy hierarchical map first aliases at n=724 (dpn=4): the prover
    # must find that exact count from probe failures by bisection — not the
    # probe count it happened to trip on — and name the first aliased slot
    # and the marker/flag writer pair
    proof = prove_layout(_LegacyHierarchical, devices_per_node=4,
                         max_devices=4096)
    assert not proof.ok
    errors = [f for f in proof.findings if f.severity == "error"]
    assert all(f.n_devices == 724 for f in errors), proof.render()
    overlap = [f for f in errors if f.kind == "layout-overlap"]
    assert overlap and overlap[0].slot == 361 and overlap[0].writers == (464,)
    alias = [f for f in errors if f.kind == "marker-alias"]
    assert alias, proof.render()
    first = alias[0]
    assert (first.dst, first.writers, first.slot) == (4, (0,), 362)
    assert "flag (writer 0, slot 362)" in first.message


def test_duplicated_emitter_names_both_sites():
    proof = prove_layout(_DuplicatedEmitter, max_devices=256)
    assert not proof.ok
    reuse = [f for f in proof.findings
             if f.severity == "error" and f.kind == "flag-reuse"]
    assert reuse, proof.render()
    first = reuse[0]
    assert first.n_devices == 2  # smallest shape that can exhibit it
    assert first.slot == 0
    assert len(first.writers) == 2  # both emission instances named
    assert "a2a_dispatch#" in first.message  # ...with their program sites


def test_off_by_stride_marker_window_caught():
    # 64 bytes below the proven clearance: the overrun only appears at
    # counts where the pool end lands in the last line of a page, so the
    # finding must carry a concrete count, the aliased slot, and the exact
    # 64-byte overrun
    proof = prove_layout(_ShiftedHierarchical, devices_per_node=4,
                         max_devices=4096)
    assert not proof.ok
    errors = [f for f in proof.findings if f.severity == "error"]
    assert errors, proof.render()
    first = errors[0]
    assert first.kind == "layout-overlap"
    assert first.n_devices is not None and first.slot is not None
    assert "by 64 bytes" in first.message


def test_verify_scenario_carries_layout_findings():
    # the concrete half of the prover rides along with the static verifier
    # (and therefore the CLI --verify path) at the instance's exact shape
    n, dpn = 512, 2
    cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(n)
    sc = _LegacyHierarchical(
        cfg, devices_per_node=dpn, fabric="two_tier", closed_loop=True
    )
    assert any(f.severity == "error" for f in check_layout(sc))
    verdict = verify_scenario(sc)
    kinds = {f.kind for f in verdict.findings if f.severity == "error"}
    assert "marker-alias" in kinds or "layout-overlap" in kinds
    assert not verdict.ok


# ---------------------------------------------------------------------------
# prover-clean implies sanitizer-clean
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(
        name=st.sampled_from(
            ["ring_allreduce", "all_to_all", "hierarchical_allreduce",
             "pipeline_p2p"]
        ),
        dpn=st.sampled_from([2, 3, 4]),
        nodes=st.integers(min_value=2, max_value=4),
        fabric=st.sampled_from(["two_tier", "fat_tree", "rail_optimized"]),
    )
    @settings(max_examples=12, deadline=None)
    def test_prover_clean_layouts_never_trip_sanitizer(
        name, dpn, nodes, fabric
    ):
        n = dpn * nodes
        cfg = SimConfig(engine=EngineKind.EVENT, workgroups=4).with_devices(n)
        sc = get_scenario(name)(
            cfg, closed_loop=True, devices_per_node=dpn, fabric=fabric
        )
        assert not [f for f in check_layout(sc) if f.severity == "error"]
        # a clean layout verdict must imply a clean shadowed run: the
        # sanitizer raises on any exactly-once flag-delivery violation
        report = simulate(sc, sanitize=True, collect_segments=False)
        assert report.closed_loop
