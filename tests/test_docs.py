"""ARCHITECTURE.md stays executable: the custom-scenario (halo exchange)
and fabric-gallery (rail_optimized) examples are extracted from the document
and run verbatim, so the public Scenario/EmitOp/Topology/interconnect
surface it teaches cannot drift from the code."""

import os
import re

import pytest

ARCH_MD = os.path.join(os.path.dirname(__file__), "..", "ARCHITECTURE.md")


def _python_blocks(text):
    return re.findall(r"```python\n(.*?)```", text, flags=re.DOTALL)


@pytest.fixture
def clean_registry():
    from repro.core.scenario import _REGISTRY

    yield
    _REGISTRY.pop("halo_exchange", None)


def test_architecture_md_halo_example_executes(clean_registry):
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    halo = [b for b in blocks if "halo_exchange" in b]
    assert len(halo) == 1, "expected exactly one halo-exchange code block"
    # the example's asserts (2-node DCI message count, flat-vs-tiered span)
    # run as written; a failure here means the doc lies about the code
    exec(compile(halo[0], "ARCHITECTURE.md:halo_exchange", "exec"), {})


def test_architecture_md_fabric_gallery_example_executes():
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    rail = [
        b for b in blocks
        if "rail_optimized" in b and "halo_exchange" not in b
    ]
    assert len(rail) == 1, "expected exactly one rail-optimized code block"
    # the gallery's asserts (rail faster than the shared uplink on the
    # incast, per-class stats, rails knob) run as written
    exec(compile(rail[0], "ARCHITECTURE.md:rail_optimized", "exec"), {})


def test_architecture_md_verify_example_executes():
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    verify = [b for b in blocks if "verify_scenario" in b]
    assert len(verify) == 1, "expected exactly one verify code block"
    # the example's asserts (static deadlock verdict, runtime agreement,
    # embedded diagnosis) run as written
    exec(compile(verify[0], "ARCHITECTURE.md:verify_scenario", "exec"), {})


def test_architecture_md_symbolic_example_executes():
    # the 1024-device flat ring snippet: symbolic programs + the lockstep
    # solver finish in seconds what used to be minutes-scale; a failure here
    # means the doc lies about the compressed-IR path
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    sym = [b for b in blocks if "SymbolicProgram" in b]
    assert len(sym) == 1, "expected exactly one symbolic-program code block"
    exec(compile(sym[0], "ARCHITECTURE.md:symbolic_programs", "exec"), {})


def test_architecture_md_tiered_lockstep_example_executes():
    # the 1024-device tiered hierarchical_allreduce snippet: group-uniform
    # bulk solving over the two-tier fabric engages (lockstep_reason ==
    # "engaged") and prices real DCI legs; a failure here means the doc
    # lies about the tiered solver
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    tiered = [b for b in blocks if "lockstep_reason" in b]
    assert len(tiered) == 1, "expected exactly one tiered-lockstep block"
    exec(compile(tiered[0], "ARCHITECTURE.md:tiered_lockstep", "exec"), {})


def test_architecture_md_layout_proving_example_executes():
    # the layout-proving snippet: the shipped all_to_all layout carries a
    # parametric certificate, and a deliberately mis-based 3-rank map is
    # blamed with the smallest failing count; a failure here means the doc
    # lies about the prover
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    layout = [b for b in blocks if "prove_layout" in b]
    assert len(layout) == 1, "expected exactly one layout-proving block"
    exec(compile(layout[0], "ARCHITECTURE.md:layout_proving", "exec"), {})


@pytest.mark.slow
def test_architecture_md_pod_scale_example_executes():
    # the 1024-device timeline-engine snippet runs as written (tens of
    # seconds: a real pod-scale closed loop, hence the slow marker); a
    # failure here means the doc lies about pod scale
    with open(ARCH_MD) as f:
        blocks = _python_blocks(f.read())
    pod = [b for b in blocks if "engine_impl" in b]
    assert len(pod) == 1, "expected exactly one pod-scale code block"
    exec(compile(pod[0], "ARCHITECTURE.md:pod_scale", "exec"), {})
