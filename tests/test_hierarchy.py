"""Tiered intra/inter-node fabric tests: leg composition and per-tier
contention, flat-configuration bit-identity with the classic single-ring
model, vectorized incast pricing, the hierarchical_allreduce scenario
(cycle/event bit-identity at 4 nodes x 4 devices/node, DCI-bandwidth
sensitivity confined to the leader stage), SyncMon jitter-class cohorts, and
the nodes=/devices_per_node= plumbing."""

from dataclasses import replace

import pytest

from repro.core import (
    Cluster,
    EngineKind,
    FabricModel,
    SimConfig,
    SweepRunner,
    SyncPolicy,
    Topology,
    get_scenario,
    simulate,
)
from repro.core.topology import V5E

FAST = SimConfig(workgroups=12, n_cus=4)

# small payload keeps the 16-device cycle-engine runs fast
HIER = dict(payload_bytes=1 << 16, writes_per_step=2)


def _segments_key(report):
    return sorted(
        (s.device, s.wg, s.phase, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
    )


def _phase_segments(report, phase):
    return sorted(
        (s.device, s.wg, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
        if s.phase == phase
    )


def _phase_span(report, phase):
    return sum(
        s.end_ns - s.start_ns for s in report.segments if s.phase == phase
    )


# ---------------------------------------------------------------------------
# Topology tier helpers
# ---------------------------------------------------------------------------


def test_topology_tier_helpers():
    flat = Topology.flat_ring(8)
    assert flat.n_chips == 8 and flat.n_nodes == 1
    assert flat.devices_per_node == 8 and flat.dci_axes == ()

    two = Topology.two_tier(4, 4)
    assert two.n_chips == 16
    assert two.n_nodes == 4 and two.devices_per_node == 4
    assert "DCI" in two.describe()

    assert Topology.for_devices(16, 4).n_nodes == 4
    assert Topology.for_devices(16, None).n_nodes == 1
    assert Topology.for_devices(16, 99).n_nodes == 1  # dpn >= n -> flat
    with pytest.raises(ValueError):
        Topology.for_devices(16, 5)  # not divisible


def test_fabric_from_topology():
    f = FabricModel.from_topology(Topology.two_tier(2, 4))
    assert f.n_devices == 8 and f.n_nodes == 2 and f.devices_per_node == 4
    flat = FabricModel.from_topology(Topology.flat_ring(6))
    assert flat.n_nodes == 1 and flat.devices_per_node == 6


# ---------------------------------------------------------------------------
# tiered routing and contention
# ---------------------------------------------------------------------------


def test_route_legs_composition():
    f = FabricModel(8, devices_per_node=4)
    # same node: one ICI leg
    assert f.route_legs(1, 3) == [("ici", (1, 1), 2)]
    # cross node from a non-gateway to a non-gateway: all three legs
    legs = f.route_legs(1, 6)
    assert [leg[0] for leg in legs] == ["ici", "dci", "ici"]
    (t0, p0, h0), (t1, p1, h1), (t2, p2, h2) = legs
    assert p0 == (1, -1) and h0 == 1          # 1 -> gateway 0
    assert p1 == ("dci", 0, 1) and h1 == 1    # node 0 -> node 1 uplink
    assert p2 == (4, 1) and h2 == 2           # gateway 4 -> 6
    # gateway-to-gateway: pure DCI
    assert f.route_legs(0, 4) == [("dci", ("dci", 0, 1), 1)]
    # route() is the intra-ring helper and rejects cross-node pairs
    assert f.route(1, 3) == (2, +1)
    with pytest.raises(ValueError):
        f.route(1, 6)
    with pytest.raises(ValueError):
        f.route(0, 0)


def test_tiered_transfer_composes_and_queues_on_uplink():
    f = FabricModel(
        8,
        devices_per_node=4,
        hop_latency_ns=10.0,
        link_bw_bytes_per_ns=10.0,
        dci_hop_latency_ns=100.0,
        dci_link_bw_bytes_per_ns=1.0,
    )
    # 1 -> 6: ICI leg (10 ser + 10 lat) -> DCI leg (100 ser + 100 lat)
    #         -> ICI leg (10 ser + 2 x 10 lat)
    assert f.transfer(1, 6, 100, 0.0) == pytest.approx(250.0)
    # 0 -> 4 afterwards: no intra legs, but the node-0 uplink is busy until
    # 120 ns, so the burst queues behind it
    assert f.transfer(0, 4, 100, 0.0) == pytest.approx(320.0)
    assert f.stats["messages"] == 2
    assert f.stats["dci_messages"] == 2
    assert f.stats["ici_messages"] == 2
    assert f.stats["dci_queued_ns"] == pytest.approx(120.0)
    # the opposite uplink direction is a distinct port: no queueing
    f2 = FabricModel(
        12,
        devices_per_node=4,
        hop_latency_ns=10.0,
        link_bw_bytes_per_ns=10.0,
        dci_hop_latency_ns=100.0,
        dci_link_bw_bytes_per_ns=1.0,
    )
    a = f2.transfer(0, 4, 100, 0.0)   # node 0 -> 1, +1 uplink
    b = f2.transfer(0, 8, 100, 0.0)   # node 0 -> 2, -1 uplink (shortest)
    assert a == pytest.approx(200.0)  # 100 ser + 100 lat, no intra legs
    assert b == pytest.approx(200.0)  # no queue: other uplink direction


def test_flat_configuration_is_the_classic_ring():
    """devices_per_node >= n_devices must reproduce the single-ring model
    exactly — same routes, same arrivals, same contention."""
    import random

    rng = random.Random(42)
    f_default = FabricModel(6, hop_latency_ns=100.0, link_bw_bytes_per_ns=1.0)
    f_flat = FabricModel(
        6, devices_per_node=6, hop_latency_ns=100.0, link_bw_bytes_per_ns=1.0
    )
    assert f_default.n_nodes == f_flat.n_nodes == 1
    for _ in range(500):
        s, d = rng.randrange(6), rng.randrange(6)
        if s == d:
            continue
        nb = rng.randrange(0, 4096)
        t = rng.random() * 1e4
        assert f_default.transfer(s, d, nb, t) == f_flat.transfer(s, d, nb, t)
    assert f_default.stats == f_flat.stats
    # DCI knobs are inert in the flat configuration
    f_slow_dci = FabricModel(
        6,
        hop_latency_ns=100.0,
        link_bw_bytes_per_ns=1.0,
        dci_link_bw_bytes_per_ns=1e-6,
    )
    f_ref = FabricModel(6, hop_latency_ns=100.0, link_bw_bytes_per_ns=1.0)
    assert f_slow_dci.transfer(0, 3, 300, 0.0) == f_ref.transfer(0, 3, 300, 0.0)


def test_transfer_batch_bit_identical_to_sequential():
    """The vectorized same-issue incast pricing must match per-message calls
    exactly — arrivals and stats — in flat and tiered shapes, above and below
    the numpy cutoff."""
    import random

    rng = random.Random(7)
    for n, dpn in ((24, None), (8, None), (24, 6), (24, 1)):
        kw = dict(
            devices_per_node=dpn,
            hop_latency_ns=3.0,
            link_bw_bytes_per_ns=0.25,
            dci_hop_latency_ns=55.0,
            dci_link_bw_bytes_per_ns=0.03,
        )
        f_seq, f_bat = FabricModel(n, **kw), FabricModel(n, **kw)
        for _ in range(20):
            src = rng.randrange(n)
            dsts = [d for d in range(n) if d != src]
            rng.shuffle(dsts)
            nbs = [rng.randrange(0, 8192) for _ in dsts]
            t = rng.random() * 1e5
            seq = [f_seq.transfer(src, d, nb, t) for d, nb in zip(dsts, nbs)]
            assert f_bat.transfer_batch(src, dsts, nbs, t) == seq
        assert f_seq.stats == f_bat.stats, (n, dpn)


# ---------------------------------------------------------------------------
# closed-loop scenarios on a tiered fabric
# ---------------------------------------------------------------------------


def test_flat_closed_loop_unchanged_by_explicit_devices_per_node():
    """A closed-loop run with devices_per_node == n_devices is the committed
    flat behaviour, bit for bit."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    base = simulate("ring_allreduce", cfg, devices=4, closed_loop=True)
    flat = simulate(
        "ring_allreduce", cfg, devices=4, closed_loop=True, devices_per_node=4
    )
    assert base.traffic == flat.traffic
    assert base.kernel_span_ns == flat.kernel_span_ns
    assert _segments_key(base) == _segments_key(flat)


def test_tiered_ring_allreduce_crosses_the_uplinks():
    """Grouping a closed-loop ring into nodes routes the node-boundary steps
    over DCI: slower uplinks stretch the kernel, and the DCI tier carries
    exactly the boundary messages."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    flat = simulate("ring_allreduce", cfg, devices=8, closed_loop=True)
    tier = simulate(
        "ring_allreduce", cfg, devices=8, closed_loop=True, devices_per_node=4
    )
    assert tier.meta["n_nodes"] == 2 and tier.meta["devices_per_node"] == 4
    assert tier.meta["fabric"]["dci_messages"] > 0
    # structural counters can't move: same programs, same flags
    assert tier.traffic["nonflag_reads"] == flat.traffic["nonflag_reads"]
    assert tier.wtt_enacted == flat.wtt_enacted
    # the DCI tier is slower than ICI, so the closed loop takes longer
    assert tier.kernel_span_ns > flat.kernel_span_ns


@pytest.mark.parametrize("sync", [SyncPolicy.SPIN, SyncPolicy.SYNCMON])
def test_hierarchical_allreduce_bit_identical_at_4x4(sync):
    """The acceptance case: 4 nodes x 4 devices/node, cycle and event engines
    bit-for-bit."""
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(sync=sync, engine=eng)
        reports[eng] = simulate(
            "hierarchical_allreduce", cfg, nodes=4, devices_per_node=4, **HIER
        )
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.n_devices == b.n_devices == 16
    assert a.traffic == b.traffic
    assert a.per_device == b.per_device
    assert a.kernel_span_ns == pytest.approx(b.kernel_span_ns)
    assert _segments_key(a) == _segments_key(b)
    assert a.monitor_stats == b.monitor_stats


def test_hierarchical_allreduce_stage_roles():
    """Leaders run the inter-node ring; non-leaders hand off and wait for the
    broadcast; everyone reduce-scatters locally."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    r = simulate(
        "hierarchical_allreduce", cfg, nodes=4, devices_per_node=4, **HIER
    )
    by_dev = {}
    for s in r.segments:
        by_dev.setdefault(s.device, set()).add(s.phase)
    leaders = {d for d in range(16) if d % 4 == 0}
    for d in range(16):
        assert "hrs_send" in by_dev[d], d
        assert "hbc_read" in by_dev[d], d
        if d in leaders:
            assert "hir_send" in by_dev[d], d
            assert "hbc_wait" not in by_dev[d], d
        else:
            assert "hrs_handoff" in by_dev[d], d
            assert "hbc_wait" in by_dev[d], d
            assert not any(p.startswith("hir") for p in by_dev[d]), d
    # inter-leader steps ride the DCI uplinks
    assert r.meta["fabric"]["dci_messages"] > 0


def test_halving_dci_bandwidth_moves_only_leader_stage_waits():
    """The headline demonstration: a slower DCI tier lengthens the leader
    ring-stage waits (and the broadcast waits that straddle it) while the
    intra-node reduce-scatter stage is untouched — segments bit-identical,
    structural counters unchanged."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    slow_hw = replace(V5E, dci_link_bw=V5E.dci_link_bw / 2)
    base = simulate(
        "hierarchical_allreduce", cfg, nodes=4, devices_per_node=4, **HIER
    )
    slow = simulate(
        "hierarchical_allreduce",
        cfg,
        nodes=4,
        devices_per_node=4,
        hw=slow_hw,
        **HIER,
    )
    # intra-node stage: identical timelines and counters
    for phase in ("hrs_send", "hrs_reduce", "hrs_handoff", "hrs_wait"):
        assert _phase_segments(base, phase) == _phase_segments(slow, phase), phase
    for d in range(16):
        assert (
            base.per_device[d]["nonflag_reads"]
            == slow.per_device[d]["nonflag_reads"]
        )
    # leader stage: waits lengthen, and with them the whole kernel
    assert _phase_span(slow, "hir_wait") > _phase_span(base, "hir_wait")
    assert _phase_span(slow, "hbc_wait") > _phase_span(base, "hbc_wait")
    assert slow.kernel_span_ns > base.kernel_span_ns
    # under SPIN the longer waits surface as extra flag reads
    assert slow.flag_reads > base.flag_reads


def test_hierarchical_allreduce_flat_degenerates_to_single_node():
    """Without a node split the scenario is intra-node only: no DCI traffic,
    no leader ring."""
    cfg = FAST.with_(engine=EngineKind.EVENT)
    r = simulate("hierarchical_allreduce", cfg, devices=4, **HIER)
    assert r.meta["n_nodes"] == 1
    assert r.meta["fabric"]["dci_messages"] == 0
    assert not any(s.phase.startswith("hir") for s in r.segments)


def test_hierarchical_allreduce_rejects_open_loop_and_bad_shape():
    with pytest.raises(ValueError):
        get_scenario("hierarchical_allreduce")(FAST, closed_loop=False)
    with pytest.raises(ValueError):
        simulate("hierarchical_allreduce", FAST, devices=6, devices_per_node=4)


# ---------------------------------------------------------------------------
# nodes= / devices_per_node= plumbing
# ---------------------------------------------------------------------------


def test_simulate_shape_knobs_resolve_and_validate():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    a = simulate("hierarchical_allreduce", cfg, nodes=2, devices_per_node=4,
                 **HIER)
    b = simulate("hierarchical_allreduce", cfg, devices=8, nodes=2, **HIER)
    c = simulate("hierarchical_allreduce", cfg, devices=8, devices_per_node=4,
                 **HIER)
    assert a.n_devices == b.n_devices == c.n_devices == 8
    assert a.traffic == b.traffic == c.traffic
    with pytest.raises(ValueError):
        simulate("hierarchical_allreduce", cfg, devices=8, nodes=3)
    with pytest.raises(ValueError):
        simulate("hierarchical_allreduce", cfg, nodes=2)  # shape underdetermined
    with pytest.raises(ValueError):
        simulate(
            "hierarchical_allreduce", cfg, devices=16, nodes=2,
            devices_per_node=4,  # 2 x 4 != 16
        )


def test_sweep_runner_nodes_axis():
    runner = SweepRunner(
        "hierarchical_allreduce", FAST, engines=(EngineKind.EVENT,)
    )
    points = runner.run(
        devices=[16], nodes=[1, 4], payload_bytes=[1 << 16]
    )
    assert len(points) == 2
    assert [p.params["devices_per_node"] for p in points] == [16, 4]
    flat, tiered = points
    assert (
        tiered.report.meta["fabric"]["dci_messages"]
        > flat.report.meta["fabric"]["dci_messages"] == 0
    )


def test_cluster_rejects_mismatched_topology():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True)
    with pytest.raises(ValueError):
        Cluster(cfg, sc, topology=Topology.two_tier(4, 4))  # 16 != 4 devices


# ---------------------------------------------------------------------------
# SyncMon jitter-class cohorts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mod,stagger",
    [(16, 8), (2, 8), (1, 0), (4, 0)],
)
@pytest.mark.parametrize("name", ["ring_allreduce", "hierarchical_allreduce"])
def test_syncmon_class_cohorts_match_singletons(name, mod, stagger):
    """Jitter-class cohorts must be bit-identical to the per-workgroup
    interpreter: traffic, per-device breakdown, monitor stats, timelines."""
    cfg = FAST.with_(
        sync=SyncPolicy.SYNCMON,
        engine=EngineKind.EVENT,
        requeue_jitter_mod=mod,
        dispatch_stagger_cycles=stagger,
    )
    reports = {}
    n_cohorts = {}
    for cohorts in (True, False):
        sc = get_scenario(name)(cfg, closed_loop=True)
        cluster = Cluster(cfg, sc, cohorts=cohorts)
        n_cohorts[cohorts] = len(cluster.nodes[0].target.cohorts)
        reports[cohorts] = cluster.run()
    a, b = reports[True], reports[False]
    assert a.traffic == b.traffic
    assert a.per_device == b.per_device
    assert a.monitor_stats == b.monitor_stats
    assert a.sim_cycles == b.sim_cycles
    assert _segments_key(a) == _segments_key(b)
    # the class split really batches whenever classes repeat
    expected = min(
        cfg.workgroups,
        len({(w // cfg.n_cus * stagger, w % mod) for w in range(cfg.workgroups)}),
    )
    assert n_cohorts[True] == expected
    assert n_cohorts[False] == cfg.workgroups


def test_syncmon_class_cohorts_group_members_by_class():
    cfg = FAST.with_(
        sync=SyncPolicy.SYNCMON,
        engine=EngineKind.EVENT,
        requeue_jitter_mod=4,
        dispatch_stagger_cycles=0,
    )
    sc = get_scenario("ring_allreduce")(cfg, closed_loop=True)
    dev = Cluster(cfg, sc).nodes[0].target
    assert len(dev.cohorts) == 4  # one per jitter class
    for c in dev.cohorts:
        classes = {wg % 4 for wg in c.members}
        assert len(classes) == 1
        assert c.member_cus == tuple(wg % cfg.n_cus for wg in c.members)
