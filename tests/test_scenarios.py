"""Scenario API tests: registry round-trip, cross-engine equivalence for every
registered scenario, seed-number preservation, sweeps, and program validation."""

import pytest

from repro.core import (
    AddressMap,
    EngineKind,
    PhaseSpec,
    Scenario,
    SimConfig,
    SweepRunner,
    SyncPolicy,
    TraceBundle,
    TrafficOp,
    WGProgram,
    get_scenario,
    list_scenarios,
    register_scenario,
    run_gemv_allreduce,
    simulate,
)
from repro.core.scenario import _REGISTRY
from repro.core.scenarios import GemvAllReduceScenario

# small-but-nontrivial config so the cycle engine stays fast
FAST = SimConfig(workgroups=24, n_cus=4)


def _segments_key(report):
    return sorted(
        (s.wg, s.phase, round(s.start_ns, 6), round(s.end_ns, 6))
        for s in report.segments
    )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_builtins_registered():
    names = list_scenarios()
    assert len(names) >= 4
    for expected in ("gemv_allreduce", "ring_allreduce", "all_to_all",
                     "pipeline_p2p"):
        assert expected in names
        assert get_scenario(expected).name == expected


def test_registry_round_trip_and_duplicate_rejection():
    @register_scenario
    class _Tiny(Scenario):
        name = "_tiny_test_scenario"

        def programs(self):
            return [
                WGProgram(
                    wg=0, cu=0, dispatch_cycle=0,
                    phases=(
                        PhaseSpec("wait_flags",
                                  wait_addrs=(self.amap.flag_addr(1),)),
                        PhaseSpec("reduce", 10,
                                  traffic=(TrafficOp("reads", 5, 32),)),
                    ),
                )
            ]

        def traces(self):
            b = TraceBundle(meta={"scenario": self.name})
            b.add(wakeup_ns=100.0, addr=self.amap.flag_addr(1), data=1,
                  size=8, src=1)
            return b

    try:
        assert get_scenario("_tiny_test_scenario") is _Tiny
        assert "_tiny_test_scenario" in list_scenarios()
        with pytest.raises(ValueError):
            @register_scenario
            class _Clash(Scenario):
                name = "_tiny_test_scenario"

                def programs(self):
                    return []

                def traces(self):
                    return TraceBundle()

        r = simulate("_tiny_test_scenario", FAST, collect_segments=False)
        assert r.nonflag_reads == 5
        assert r.flag_reads >= 1
    finally:
        _REGISTRY.pop("_tiny_test_scenario", None)


def test_unknown_scenario_raises():
    with pytest.raises(KeyError):
        get_scenario("definitely_not_registered")


# ---------------------------------------------------------------------------
# cross-engine equivalence for every registered scenario
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(set(list_scenarios())))
@pytest.mark.parametrize("sync", [SyncPolicy.SPIN, SyncPolicy.SYNCMON])
def test_cycle_event_bit_identical(name, sync):
    reports = {}
    for eng in (EngineKind.CYCLE, EngineKind.EVENT):
        cfg = FAST.with_(sync=sync, engine=eng)
        reports[eng] = simulate(name, cfg)
    a, b = reports[EngineKind.CYCLE], reports[EngineKind.EVENT]
    assert a.traffic == b.traffic
    assert a.flag_reads == b.flag_reads
    assert a.nonflag_reads == b.nonflag_reads
    assert a.kernel_span_ns == pytest.approx(b.kernel_span_ns)
    assert _segments_key(a) == _segments_key(b)
    assert a.monitor_stats == b.monitor_stats


def test_gemv_scenario_matches_vector_engine():
    reports = [
        simulate("gemv_allreduce", FAST.with_(engine=eng),
                 flag_delays_ns=9_000.0, collect_segments=False)
        for eng in (EngineKind.CYCLE, EngineKind.EVENT, EngineKind.VECTOR)
    ]
    assert reports[0].traffic == reports[1].traffic == reports[2].traffic


def test_vector_engine_rejected_for_non_gemv():
    with pytest.raises(NotImplementedError):
        simulate("ring_allreduce", FAST.with_(engine=EngineKind.VECTOR),
                 collect_segments=False)


# ---------------------------------------------------------------------------
# seed-number preservation (Table 1)
# ---------------------------------------------------------------------------


def test_back_compat_wrapper_reproduces_table1():
    r = run_gemv_allreduce(SimConfig(), 10_000.0, collect_segments=False)
    assert r.nonflag_reads == 65_792  # the paper's "approximately 66K"
    assert r.scenario == "gemv_allreduce"


def test_simulate_equals_back_compat_wrapper():
    cfg = SimConfig(sync=SyncPolicy.SPIN, engine=EngineKind.EVENT)
    a = run_gemv_allreduce(cfg, 12_345.0)
    b = simulate("gemv_allreduce", cfg, flag_delays_ns=12_345.0)
    assert a.traffic == b.traffic
    assert _segments_key(a) == _segments_key(b)


# ---------------------------------------------------------------------------
# scenario semantics
# ---------------------------------------------------------------------------


def test_ring_allreduce_has_per_step_flags():
    cfg = FAST
    sc = get_scenario("ring_allreduce")(cfg)
    assert sc.steps == 2 * (cfg.n_devices - 1)
    flags = [w for w in sc.traces() if sc.amap.is_flag(w.addr)]
    assert len(flags) == sc.steps
    assert len({w.addr for w in flags}) == sc.steps  # distinct slot per step


def test_all_to_all_flag_traffic_grows_with_skew_under_spin():
    lo = simulate("all_to_all", FAST.with_(engine=EngineKind.EVENT),
                  skew_ns=0.0, collect_segments=False)
    hi = simulate("all_to_all", FAST.with_(engine=EngineKind.EVENT),
                  skew_ns=20_000.0, collect_segments=False)
    assert hi.flag_reads > lo.flag_reads
    assert hi.nonflag_reads == lo.nonflag_reads


def test_pipeline_waits_once_per_microbatch():
    cfg = FAST.with_(engine=EngineKind.EVENT, sync=SyncPolicy.SYNCMON)
    r = simulate("pipeline_p2p", cfg, n_microbatches=5)
    waits = [s for s in r.segments if s.phase == "wait_flags" and s.wg == 0]
    assert len(waits) == 5


def test_syncmon_cuts_flag_reads_on_every_scenario():
    for name in ("ring_allreduce", "all_to_all", "pipeline_p2p"):
        spin = simulate(name, FAST.with_(sync=SyncPolicy.SPIN,
                                         engine=EngineKind.EVENT),
                        collect_segments=False)
        mon = simulate(name, FAST.with_(sync=SyncPolicy.SYNCMON,
                                        engine=EngineKind.EVENT),
                       collect_segments=False)
        assert mon.flag_reads < spin.flag_reads, name
        assert mon.nonflag_reads == spin.nonflag_reads, name


def test_scenario_instance_and_class_accepted_by_simulate():
    cfg = FAST.with_(engine=EngineKind.EVENT)
    by_name = simulate("gemv_allreduce", cfg, flag_delays_ns=5_000.0,
                       collect_segments=False)
    by_cls = simulate(GemvAllReduceScenario, cfg, flag_delays_ns=5_000.0,
                      collect_segments=False)
    inst = GemvAllReduceScenario(cfg, flag_delays_ns=5_000.0)
    by_inst = simulate(inst, cfg, collect_segments=False)
    assert by_name.traffic == by_cls.traffic == by_inst.traffic
    with pytest.raises(ValueError):
        simulate(inst, cfg, flag_delays_ns=1.0)  # params + instance conflict


def test_simulate_uses_instance_cfg_and_rejects_mismatch():
    cfg = FAST.with_(sync=SyncPolicy.SYNCMON, engine=EngineKind.EVENT)
    inst = GemvAllReduceScenario(cfg, flag_delays_ns=5_000.0)
    r = simulate(inst, collect_segments=False)  # no cfg: instance's is used
    assert r.sync == "syncmon"
    assert len({s.wg for s in simulate(inst).segments}) == cfg.workgroups
    with pytest.raises(ValueError):
        simulate(inst, FAST.with_(workgroups=99))  # different cfg: error


# ---------------------------------------------------------------------------
# sweeps
# ---------------------------------------------------------------------------


def test_sweep_runner_splits_cfg_and_scenario_params():
    runner = SweepRunner(
        "gemv_allreduce",
        FAST,
        engines=(EngineKind.EVENT, EngineKind.VECTOR),
    )
    points = runner.run(
        flag_delays_ns=[0.0, 8_000.0],  # scenario param
        n_egpus=[3, 7],                 # SimConfig field (M stays divisible)
    )
    assert len(points) == 2 * 2 * 2
    for p in points:
        assert set(p.overrides) == {"n_egpus"}
        assert set(p.params) == {"flag_delays_ns"}
    # engines agree pointwise on traffic
    by_key = {}
    for p in points:
        key = (p.overrides["n_egpus"], p.params["flag_delays_ns"])
        by_key.setdefault(key, []).append(p)
    for key, pts in by_key.items():
        assert pts[0].report.traffic == pts[1].report.traffic, key
    csv = SweepRunner.to_csv(points)
    assert csv.splitlines()[0].startswith("scenario,engine")
    assert len(csv.splitlines()) == 1 + len(points)


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def test_traffic_op_validation():
    with pytest.raises(ValueError):
        TrafficOp("warp_drive", 1, 8)
    with pytest.raises(ValueError):
        TrafficOp("reads", -1, 8)


def test_segment_rejects_unregistered_phase():
    from repro.core import Segment

    with pytest.raises(ValueError):
        Segment(wg=0, phase="not_a_phase", start_ns=0.0, end_ns=1.0)


def test_address_map_flag_slots():
    amap = AddressMap(n_devices=4, flag_slots=6)
    addrs = {amap.flag_addr(d, slot=s) for d in range(4) for s in range(6)}
    assert len(addrs) == 24
    lo, hi = amap.flag_region()
    assert all(lo <= a < hi for a in addrs)
    assert all(amap.is_flag(a) for a in addrs)
    # slot 0 keeps the seed layout
    assert amap.flag_addr(2) == AddressMap(n_devices=4).flag_addr(2)
    with pytest.raises(ValueError):
        amap.flag_addr(0, slot=6)


def test_wg_programs_must_be_contiguous():
    class _Bad(Scenario):
        name = "_bad"

        def programs(self):
            return [WGProgram(wg=3, cu=0, dispatch_cycle=0, phases=())]

        def traces(self):
            return TraceBundle()

    from repro.core import Eidola

    sc = _Bad(FAST)
    with pytest.raises(ValueError):
        Eidola(FAST, sc.traces(), scenario=sc).run()
