"""EidolaSan: the static verifier and the runtime traffic sanitizer.

Covers the acceptance bar of the analysis subsystem: every built-in scenario
verifies cleanly on every fabric preset; each seeded mutation class (wait-for
cycle, unmatched emit/wait, slot race, unreachable pair) is detected without
running the simulator; the static deadlock verdict matches the runtime
``EidolaDeadlock`` outcome on deterministic (and, when hypothesis is
installed, randomized) program mutations; and ``sanitize=True`` runs are
bit-identical to the committed multi-device bench rows while still catching
injected accounting violations.
"""

import dataclasses
import json
import os

import pytest

from repro.analysis import (
    ProgramGraph,
    SanitizerError,
    TrafficSanitizer,
    verify_scenario,
)
from repro.core import (
    AddressMap,
    EidolaDeadlock,
    EngineKind,
    FabricModel,
    SimConfig,
    list_fabrics,
    list_scenarios,
    simulate,
)
from repro.core.cluster import Cluster, resolve_cluster_fabric
from repro.core.events import TraceBundle
from repro.core.scenario import (
    EmitOp,
    PhaseSpec,
    Scenario,
    WGProgram,
    get_scenario,
)

REPO = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------------------
# helpers: a tiny closed-loop scenario builder and a program mutator
# ---------------------------------------------------------------------------


class _ProgramScenario(Scenario):
    """Closed-loop scenario whose per-rank phases come from a callback."""

    name = "program_scenario"
    closed_loop = True

    def __init__(self, cfg, phases_fn, amap=None):
        super().__init__(cfg, amap)
        self._phases_fn = phases_fn

    def programs_for(self, device):
        shared = tuple(self._phases_fn(self, device))
        return [
            WGProgram(wg=w, cu=w, dispatch_cycle=0, phases=shared)
            for w in range(self.cfg.workgroups)
        ]

    def programs(self):
        return self.programs_for(0)

    def traces(self):
        return TraceBundle()


class _MutatedScenario(Scenario):
    """Wrap a built scenario, rewriting one rank's shared phase tuple."""

    name = "mutated"

    def __init__(self, inner, mutate):
        self.inner = inner
        self.cfg = inner.cfg
        self.amap = inner.amap
        self.params = dict(inner.params)
        self.closed_loop = inner.closed_loop
        self.topology = inner.topology
        self.interconnect = inner.interconnect
        self.fabric_name = inner.fabric_name
        self.name = inner.name + "+mutated"
        self._mutate = mutate
        self._shared = {}

    def programs_for(self, device):
        progs = self.inner.programs_for(device)
        if not progs:
            return progs
        shared = self._shared.get(device)
        if shared is None:
            shared = tuple(self._mutate(device, progs[0].phases))
            self._shared[device] = shared
        return [dataclasses.replace(p, phases=shared) for p in progs]

    def traces_for(self, device):
        return self.inner.traces_for(device)

    def programs(self):
        return self.inner.programs()

    def traces(self):
        return self.inner.traces()


def _small_ring(n=4, workgroups=4):
    cfg = SimConfig(n_egpus=n - 1, workgroups=workgroups)
    return get_scenario("ring_allreduce")(
        cfg, closed_loop=True, payload_bytes=1 << 12
    )


def _drop_emit(target_rank):
    def mutate(device, phases):
        if device != target_rank:
            return phases
        out = []
        dropped = False
        for ph in phases:
            if ph.emits and not dropped:
                out.append(dataclasses.replace(ph, emits=()))
                dropped = True
            else:
                out.append(ph)
        return out

    return mutate


def _swap_wait(target_rank, amap):
    def mutate(device, phases):
        if device != target_rank:
            return phases
        out = []
        swapped = False
        for ph in phases:
            if ph.wait_addrs and not swapped:
                # repoint at the rank's own flag column, which no peer
                # ever writes (flags are indexed by the writer)
                out.append(
                    dataclasses.replace(
                        ph,
                        wait_addrs=(amap.flag_addr(device, slot=0),),
                    )
                )
                swapped = True
            else:
                out.append(ph)
        return out

    return mutate


def _duplicate_wait(target_rank):
    """Benign: re-wait an already-satisfied sticky flag (no deadlock)."""

    def mutate(device, phases):
        if device != target_rank:
            return phases
        out = []
        duplicated = False
        for ph in phases:
            out.append(ph)
            if ph.wait_addrs and not duplicated:
                out.append(ph)
                duplicated = True
        return out

    return mutate


# ---------------------------------------------------------------------------
# the clean path: every builtin x every preset
# ---------------------------------------------------------------------------


def test_all_builtin_scenarios_verify_clean_on_all_presets():
    for name in list_scenarios():
        for fabric in [None, *list_fabrics()]:
            params = {"closed_loop": True}
            if fabric is not None:
                params["fabric"] = fabric
            try:
                verdict = verify_scenario(
                    name, devices=8, devices_per_node=2, **params
                )
            except TypeError:
                if fabric is not None:
                    continue  # open-loop-only scenario, presets n/a
                verdict = verify_scenario(name, devices=8)
            assert verdict.ok, verdict.render()
            assert not verdict.deadlock


def test_verify_scenario_accepts_instance_and_rejects_cfg_mismatch():
    sc = _small_ring()
    assert verify_scenario(sc).ok
    with pytest.raises(ValueError, match="different SimConfig"):
        verify_scenario(sc, SimConfig(n_egpus=7))


# ---------------------------------------------------------------------------
# seeded mutation classes, detected without simulation
# ---------------------------------------------------------------------------


def test_detects_wait_for_cycle_with_blame_chain():
    def phases(sc, device):
        n = sc.cfg.n_devices
        return [
            PhaseSpec("compute", duration_cycles=50),
            PhaseSpec(
                "wait_flags",
                wait_addrs=(sc.amap.flag_addr((device + 1) % n),),
            ),
            PhaseSpec(
                "drain", duration_cycles=5,
                emits=(EmitOp((device - 1) % n),),
            ),
        ]

    sc = _ProgramScenario(SimConfig(n_egpus=2, workgroups=2), phases)
    verdict = verify_scenario(sc)
    assert not verdict.ok and verdict.deadlock
    [finding] = [f for f in verdict.errors if f.kind == "deadlock-cycle"]
    # the blame chain names every rank and the flag each one is stuck on
    for rank in range(3):
        assert f"rank {rank}" in finding.message
    assert "waits on flag" in finding.message


def test_detects_unmatched_wait_from_dropped_emit():
    sc = _MutatedScenario(_small_ring(), _drop_emit(1))
    verdict = verify_scenario(sc)
    assert not verdict.ok and verdict.deadlock
    kinds = {f.kind for f in verdict.errors}
    assert "unmatched-wait" in kinds or "deadlock-cycle" in kinds


def test_detects_unmatched_wait_from_swapped_target():
    inner = _small_ring()
    sc = _MutatedScenario(inner, _swap_wait(2, inner.amap))
    verdict = verify_scenario(sc)
    assert not verdict.ok and verdict.deadlock
    assert any(f.kind == "unmatched-wait" for f in verdict.errors)


def test_detects_unawaited_emit_as_warning():
    def phases(sc, device):
        out = [PhaseSpec("compute", duration_cycles=10)]
        if device == 0:
            # rank 0 notifies rank 1, which never waits
            out.append(
                PhaseSpec("drain", duration_cycles=5, emits=(EmitOp(1),))
            )
        return out

    sc = _ProgramScenario(SimConfig(n_egpus=1, workgroups=2), phases)
    verdict = verify_scenario(sc)
    assert verdict.ok  # warning, not error: the run still terminates
    assert any(f.kind == "unawaited-emit" for f in verdict.warnings)
    assert not verdict.deadlock


def test_detects_flag_slot_write_race():
    def phases(sc, device):
        shared_addr = sc.amap.flag_addr(1, slot=0)
        if device == 0:
            return [
                PhaseSpec("wait_flags", wait_addrs=(shared_addr,)),
                PhaseSpec("drain", duration_cycles=5),
            ]
        # ranks 1 and 2 both write the same flag address in rank 0's
        # memory, with no ordering between them
        return [
            PhaseSpec("compute", duration_cycles=10 * device),
            PhaseSpec(
                "drain", duration_cycles=5,
                emits=(EmitOp(0, addr=shared_addr),),
            ),
        ]

    sc = _ProgramScenario(SimConfig(n_egpus=2, workgroups=2), phases)
    verdict = verify_scenario(sc)
    races = [f for f in verdict.errors if f.kind == "slot-race"]
    assert races, verdict.render()
    assert "unordered writers" in races[0].message
    assert not verdict.deadlock  # a race is not a hang


def test_no_race_when_wait_orders_the_writers():
    def phases(sc, device):
        shared_addr = sc.amap.flag_addr(1, slot=0)
        if device == 0:
            return [PhaseSpec("wait_flags", wait_addrs=(shared_addr,))]
        if device == 1:
            return [
                PhaseSpec(
                    "drain", duration_cycles=5,
                    emits=(EmitOp(0, addr=shared_addr), EmitOp(2)),
                ),
            ]
        # rank 2 waits for rank 1's handoff before re-writing the flag:
        # a happens-before path orders the two writers
        return [
            PhaseSpec("wait_flags", wait_addrs=(sc.amap.flag_addr(1),)),
            PhaseSpec(
                "drain", duration_cycles=5,
                emits=(EmitOp(0, addr=shared_addr),),
            ),
        ]

    sc = _ProgramScenario(SimConfig(n_egpus=2, workgroups=2), phases)
    verdict = verify_scenario(sc)
    assert not any(f.kind == "slot-race" for f in verdict.findings), (
        verdict.render()
    )


def test_detects_unreachable_pair_self_emit():
    def phases(sc, device):
        return [
            PhaseSpec("compute", duration_cycles=10),
            PhaseSpec("drain", duration_cycles=5, emits=(EmitOp(device),)),
        ]

    sc = _ProgramScenario(SimConfig(n_egpus=1, workgroups=2), phases)
    verdict = verify_scenario(sc)
    pairs = [f for f in verdict.errors if f.kind == "unreachable-pair"]
    assert pairs and "emits to itself" in pairs[0].message


def test_detects_invalid_emit_slot():
    def phases(sc, device):
        return [
            PhaseSpec(
                "drain", duration_cycles=5,
                emits=(EmitOp((device + 1) % 2, slot=99),),
            ),
        ]

    sc = _ProgramScenario(SimConfig(n_egpus=1, workgroups=2), phases)
    verdict = verify_scenario(sc)
    assert any(f.kind == "invalid-emit" for f in verdict.errors)


# ---------------------------------------------------------------------------
# static verdict <=> runtime EidolaDeadlock
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "mutator,expect_deadlock",
    [
        (None, False),
        (_drop_emit(1), True),
        (_drop_emit(3), True),
        (_duplicate_wait(2), False),
    ],
    ids=["identity", "drop-emit-r1", "drop-emit-r3", "dup-wait"],
)
def test_static_verdict_matches_runtime(mutator, expect_deadlock):
    inner = _small_ring()
    sc = _MutatedScenario(inner, mutator) if mutator else inner
    verdict = verify_scenario(sc)
    assert verdict.deadlock == expect_deadlock, verdict.render()
    if expect_deadlock:
        with pytest.raises(EidolaDeadlock):
            simulate(sc, collect_segments=False)
    else:
        report = simulate(sc, collect_segments=False)
        assert report.sim_cycles > 0


def test_swapped_wait_matches_runtime_and_embeds_diagnosis():
    inner = _small_ring()
    sc = _MutatedScenario(inner, _swap_wait(2, inner.amap))
    assert verify_scenario(sc).deadlock
    with pytest.raises(EidolaDeadlock) as exc:
        simulate(sc, collect_segments=False)
    # the engine embeds the analyzer's blame diagnosis into the error
    assert exc.value.diagnosis is not None
    assert "static analysis" in str(exc.value)


def test_property_random_mutations_match_runtime():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @given(
        kind=st.sampled_from(["identity", "drop", "swap", "dup"]),
        rank=st.integers(min_value=0, max_value=3),
        n=st.sampled_from([3, 4]),
    )
    @settings(max_examples=15, deadline=None)
    def run(kind, rank, n):
        rank %= n
        inner = _small_ring(n=n, workgroups=2)
        if kind == "identity":
            sc = inner
        elif kind == "drop":
            sc = _MutatedScenario(inner, _drop_emit(rank))
        elif kind == "swap":
            sc = _MutatedScenario(inner, _swap_wait(rank, inner.amap))
        else:
            sc = _MutatedScenario(inner, _duplicate_wait(rank))
        flagged = verify_scenario(sc).deadlock
        try:
            simulate(sc, collect_segments=False)
            hung = False
        except EidolaDeadlock:
            hung = True
        assert flagged == hung

    run()


# ---------------------------------------------------------------------------
# program-graph lowering details
# ---------------------------------------------------------------------------


def test_program_graph_lanes_and_sites():
    sc = _small_ring(n=4, workgroups=4)
    g = ProgramGraph.from_scenario(sc)
    assert g.n_devices == 4 and g.closed_loop
    assert sorted(g.lanes_of) == [0, 1, 2, 3]
    # all builtins share one phases tuple per rank -> one lane per device
    assert all(len(lanes) == 1 for lanes in g.lanes_of.values())
    assert all(g.lanes[ls[0]].wg_count == 4 for ls in g.lanes_of.values())
    # every wait has a matching emitter (the clean ring)
    assert set(g.waiters) <= set(g.emitters)
    assert g.emit_pairs() == [(d, (d + 1) % 4) for d in range(4)]


def test_open_loop_scenario_lowers_external_flags():
    sc = get_scenario("gemv_allreduce")(SimConfig(n_egpus=3))
    g = ProgramGraph.from_scenario(sc)
    assert not g.closed_loop
    # eidolon trace writes satisfy the waits; nothing is unmatched
    assert g.external_flags
    verdict = verify_scenario(sc)
    assert verdict.ok and not verdict.deadlock


# ---------------------------------------------------------------------------
# runtime sanitizer
# ---------------------------------------------------------------------------


def test_sanitized_runs_bit_identical_to_bench_baseline():
    with open(os.path.join(REPO, "BENCH_multi_device.json")) as f:
        rows = json.load(f)["rows"]
    cfg = SimConfig(workgroups=64, engine=EngineKind.EVENT)
    checked = 0
    for row in rows:
        if row["devices"] != 4 or row["engine"] != "event":
            continue
        r = simulate(
            row["scenario"],
            cfg,
            devices=4,
            closed_loop=True,
            devices_per_node=row["devices_per_node"],
            fabric=row["fabric"],
            collect_segments=False,
            sanitize=True,
        )
        assert r.meta["sanitized"] is True
        got = {
            "flag_reads": r.flag_reads,
            "nonflag_reads": r.nonflag_reads,
            "xgmi_writes_in": r.traffic.get("xgmi_writes_in", 0),
            "wtt_enacted": r.wtt_enacted,
            "sim_cycles": r.sim_cycles,
            "kernel_span_ns": r.kernel_span_ns,
        }
        for k, v in got.items():
            assert v == row[k], (
                f"{row['scenario']} dpn={row['devices_per_node']} "
                f"fabric={row['fabric']}: sanitized run drifted {k}: "
                f"{row[k]} -> {v}"
            )
        checked += 1
    assert checked >= 8  # 4 scenarios x (flat, tiered, 2 presets) at 4 dev


def test_sanitizer_catches_byte_conservation_violation():
    sc = _small_ring()
    cluster = Cluster(sc.cfg, sc, sanitize=True, collect_segments=False)
    # tamper with the fabric's accounting before the run: the independent
    # leg re-walk must notice the books don't balance
    cluster.fabric.stats["bytes"] += 1
    with pytest.raises(SanitizerError, match="byte conservation"):
        cluster.run()


def test_sanitizer_catches_lost_flag_delivery():
    sc = _small_ring()
    cluster = Cluster(sc.cfg, sc, sanitize=True, collect_segments=False)
    key = (1, sc.amap.flag_addr(0, slot=0))
    cluster._san.expected_flags[key] = (
        cluster._san.expected_flags.get(key, 0) + 1
    )
    with pytest.raises(SanitizerError, match="flag delivery"):
        cluster.run()


def test_sanitizer_unit_checks():
    fm = FabricModel(4)
    amap = AddressMap(n_devices=4)
    san = TrafficSanitizer(amap, fm, 4)
    # acausal arrival
    san.note_emission(0, 1, amap.flag_addr(0), 8, 100.0, 50.0)
    obs = san.observer_for(1)
    obs(amap.flag_addr(0), 1, 8, 10)
    obs(amap.flag_addr(0), 1, 8, 5)  # calendar runs backwards
    with pytest.raises(SanitizerError) as exc:
        san.check()
    msg = str(exc.value)
    assert "acausal" in msg and "calendar ran backwards" in msg
    # the doubly-enacted flag (1 expected, 2 enacted) is also flagged
    assert "flag delivery" in msg


def test_sanitize_requires_closed_loop():
    with pytest.raises(ValueError, match="closed-loop"):
        simulate("gemv_allreduce", sanitize=True)


# ---------------------------------------------------------------------------
# satellite: AddressMap flag-slot claims
# ---------------------------------------------------------------------------


def test_claim_flag_slots_rejects_collision():
    amap = AddressMap(n_devices=4, flag_slots=4)
    amap.claim_flag_slots("stage_a", [(d, 0) for d in range(4)])
    amap.claim_flag_slots("stage_a", [(0, 0)])  # same label: idempotent
    with pytest.raises(ValueError, match="flag slot collision"):
        amap.claim_flag_slots("stage_b", [(2, 0)])


def test_claim_flag_slots_validates_ranges():
    amap = AddressMap(n_devices=4, flag_slots=2)
    with pytest.raises(ValueError, match="slot 2 out of range"):
        amap.claim_flag_slots("x", [(0, 2)])
    with pytest.raises(ValueError, match="device 4 out of range"):
        amap.claim_flag_slots("x", [(4, 0)])


def test_scenario_construction_claims_disjoint_ranges():
    # sharing one AddressMap between two scenarios whose stages overlap
    # must fail loudly at construction time
    cfg = SimConfig(n_egpus=3)
    ring = get_scenario("ring_allreduce")
    amap = ring.default_amap(cfg)
    ring(cfg, amap, closed_loop=True)
    with pytest.raises(ValueError, match="flag slot collision"):
        get_scenario("all_to_all")(cfg, amap, closed_loop=True)


# ---------------------------------------------------------------------------
# satellite: deterministic fabric stats ordering
# ---------------------------------------------------------------------------


def test_fabric_stats_and_port_stats_are_deterministically_ordered():
    sc = _small_ring()
    fm = resolve_cluster_fabric(sc.cfg, sc, fabric="fat_tree")
    # per-class stat keys come out sorted (after the three totals)
    keys = list(fm.stats)
    assert keys[:3] == ["messages", "bytes", "queued_ns"]
    classes = sorted(fm.spec.link_classes)
    assert keys[3:] == [
        c + suffix
        for c in classes
        for suffix in ("_messages", "_bytes", "_queued_ns")
    ]
    # every declared port pre-seeded at zero, sorted by repr
    assert list(fm.port_stats) == sorted(fm.spec.ports, key=repr)
    assert all(v == [0, 0, 0.0] for v in fm.port_stats.values())
    fm.transfer(0, 1, 64, 0.0)
    fm.reset()
    assert list(fm.port_stats) == sorted(fm.spec.ports, key=repr)
    assert all(v == [0, 0, 0.0] for v in fm.port_stats.values())
