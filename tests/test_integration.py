"""Integration tests: training loop, checkpoint/restart, elastic remesh,
stragglers, serving, data pipeline, EP MoE equivalence, distributed
collectives (these run on a 1-device mesh; multi-device paths are covered by
tests/test_distributed.py under forced host devices)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.data import DataConfig, SyntheticLMDataset, prefetch
from repro.ft import (
    ElasticMeshManager,
    HeartbeatMonitor,
    SimulatedFailure,
    StragglerMonitor,
)
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig
from repro.serving import ServeConfig, ServeEngine
from repro.training import TrainConfig, Trainer

# model-forward-dominated: runs in the separate slow CI job, not the fast
# simulator suite
pytestmark = pytest.mark.slow


def tiny_model():
    return Model(
        ModelConfig(
            name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
            d_ff=128, vocab=128, max_seq_len=128,
        )
    )


def test_loss_decreases_and_failure_recovery():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    model = tiny_model()
    data = SyntheticLMDataset(
        DataConfig(vocab=128, seq_len=64, global_batch=8, seed=1)
    )
    fails = {12}

    def inject(step):
        if step in fails:
            fails.discard(step)
            raise SimulatedFailure(f"injected at {step}")

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(
            model, mesh,
            TrainConfig(optim=AdamWConfig(lr=1e-2, warmup_steps=5,
                                          total_steps=40)),
            ckpt_dir=d, ckpt_every=10, failure_injector=inject,
        )
        tr.init_state(jax.random.PRNGKey(0))
        hist = tr.run(prefetch(iter(data)), 30, log_every=0)
        losses = [h["loss"] for h in hist]
        assert losses[-1] < losses[0] - 0.3
        # failure at step 12 forced a restart from the step-10 checkpoint:
        # steps 11/12 run twice
        assert len(hist) > 30


def test_checkpoint_roundtrip_and_crash_safety(tmp_path):
    tree = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "b": {"c": jnp.ones((5,), jnp.bfloat16)},
    }
    path = str(tmp_path / "ck")
    save_pytree(tree, path)
    back = load_pytree(jax.eval_shape(lambda: tree), path)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["b"]["c"].dtype == jnp.bfloat16
    # torn checkpoint (no COMMIT) must be invisible
    os.remove(os.path.join(path, "COMMIT"))
    with pytest.raises(FileNotFoundError):
        load_pytree(jax.eval_shape(lambda: tree), path)


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, {"x": jnp.full((4,), s, jnp.float32)})
    assert mgr.steps() == [20, 30]
    step, tree = mgr.restore_latest({"x": jax.ShapeDtypeStruct((4,), jnp.float32)})
    assert step == 30 and float(tree["x"][0]) == 30.0


def test_elastic_mesh_shrinks_on_failure():
    devs = list(range(8))  # device ids stand in for jax devices
    mgr = ElasticMeshManager(devs, model_parallel=2)
    assert mgr.current_mesh().shape["data"] == 4
    mgr.fail_devices([3])
    m = mgr.current_mesh()
    assert m.shape["data"] == 3  # one model-parallel replica lost
    mgr.fail_devices([0, 1, 2, 4, 5])
    assert mgr.current_mesh().shape["data"] == 1  # one replica left
    mgr.fail_devices([6])
    with pytest.raises(SimulatedFailure):
        mgr.current_mesh()  # 1 device < model_parallel=2: no replica fits


def test_elastic_mesh_uses_real_devices():
    devs = jax.devices()
    mgr = ElasticMeshManager(devs, model_parallel=1)
    mesh = mgr.current_mesh()
    assert mesh.shape["data"] == len(devs)


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(threshold=1.5, window=4)
    for _ in range(4):
        rep = mon.record_step({0: 1.0, 1: 1.02, 2: 0.98, 3: 2.5})
    assert rep.stragglers == [3]
    assert rep.worst_ratio > 2.0


def test_heartbeat_monitor_detects_dead_host():
    t = [0.0]
    mon = HeartbeatMonitor([0, 1, 2], timeout_s=5.0, clock=lambda: t[0])
    t[0] = 4.0
    mon.beat(0)
    mon.beat(1)
    t[0] = 7.0
    assert mon.dead_hosts() == [2]
    assert mon.alive_hosts() == [0, 1]


def test_serving_generates_and_batches():
    model = tiny_model()
    params = model.init(jax.random.PRNGKey(0))
    eng = ServeEngine(model, params, ServeConfig(max_batch=2))
    outs = eng.generate([[5, 6, 7], [9, 10], [1, 2, 3, 4]], max_new_tokens=4)
    assert [len(o) for o in outs] == [7, 6, 8]
    assert eng.stats["requests"] == 3
    # greedy decoding is deterministic
    outs2 = eng.generate([[5, 6, 7]], max_new_tokens=4)
    assert outs2[0] == outs[0]


def test_data_pipeline_determinism_and_host_sharding():
    cfg = DataConfig(vocab=64, seq_len=16, global_batch=8, n_hosts=2, seed=3)
    ds = SyntheticLMDataset(cfg)
    a1 = ds.batch(5, host=0)
    a2 = ds.batch(5, host=0)
    b = ds.batch(5, host=1)
    np.testing.assert_array_equal(a1["tokens"], a2["tokens"])
    assert not np.array_equal(a1["tokens"], b["tokens"])
    assert a1["tokens"].shape == (4, 16)
    # labels are next-token shifted
    np.testing.assert_array_equal(a1["labels"][:, :-1], a1["tokens"][:, 1:])


def test_prefetch_preserves_order():
    vals = list(range(20))
    out = list(prefetch(iter(vals), depth=3))
    assert out == vals


def test_remesh_preserves_values():
    from repro.ft import remesh_pytree
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    mesh1 = jax.make_mesh((1,), ("data",))

    def sh_fn(mesh):
        return {"w": NamedSharding(mesh, P())}

    out = remesh_pytree(tree, sh_fn, mesh1)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
